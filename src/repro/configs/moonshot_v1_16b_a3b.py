"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) vocab=163840,
64 routed experts top-6 (expert ff=1408) + 2 shared (Moonlight config).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1408,
    vocab=pad_vocab(163840),  # 163840 (aligned)
    act="swiglu",
    n_experts=64,
    top_k=6,
    n_shared=2,
    expert_dff=1408,
)
