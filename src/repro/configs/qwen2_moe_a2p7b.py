"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (kv=16) vocab=151936,
60 routed experts top-4 (expert ff=1408) + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=1408,
    vocab=pad_vocab(151936),  # 151936 (aligned to 16; /128 ok: 1187*128)
    act="swiglu",
    n_experts=60,
    top_k=4,
    n_shared=4,
    expert_dff=1408,
)
