"""The paper's own configuration: crypto-grade RNS bases for the comparison
and Montgomery-multiplication workloads (DESIGN.md §4, examples/rns_modmul).

n=137 15-bit moduli gives a ~2048-bit dynamic range (RSA/FHE scale);
the redundant modulus m_a is drawn from the second base B' per §3.1.
"""
from repro.core import make_base, RNSBase, gen_coprime_moduli

N_CHANNELS = 137          # ~2048-bit dynamic range with 15-bit moduli
BITS = 15


def make_paper_bases():
    """(B, B') with m_a = first modulus of B' — the paper's §3.1 setup."""
    ms = gen_coprime_moduli(2 * N_CHANNELS + 1, BITS)
    B = RNSBase(moduli=tuple(ms[:N_CHANNELS]), ma=ms[2 * N_CHANNELS], bits=BITS)
    Bp = RNSBase(
        moduli=tuple(ms[N_CHANNELS : 2 * N_CHANNELS]), ma=ms[0], bits=BITS
    )
    return B, Bp
