"""gemma-7b [dense]: 28L d=3072 16H (kv=16) ff=24576 vocab=256000,
GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv=16,
    head_dim=256,
    d_ff=24576,
    vocab=pad_vocab(256000),
    act="geglu",
)
