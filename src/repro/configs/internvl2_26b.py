"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) ff=16384 vocab=92553.
InternViT frontend is a STUB: input_specs() provides 1024 precomputed patch
embeddings at d_model.  [arXiv:2404.16821; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=16384,
    vocab=pad_vocab(92553),   # 92553 -> 92672
    act="swiglu",
    n_patches=1024,
    seq_parallel=True,  # 6144-wide residuals: SP shards norm/residual
                        # activations 16x (EXPERIMENTS §Perf cell E)
)
