"""whisper-tiny [audio]: 4L enc + 4L dec, d=384 6H ff=1536 vocab=51865,
head_dim=64.  Conv frontend is a STUB: input_specs() provides precomputed
frame embeddings, padded 1500 -> 1536 frames so chunked attention tiles
evenly (DESIGN.md §7).  [arXiv:2212.04356; unverified]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    head_dim=64,
    d_ff=1536,
    vocab=pad_vocab(51865),   # 51865 -> 51968
    act="geglu",
    enc_layers=4,
    enc_frames=1536,          # 1500 mel frames padded to 3*512
)
