"""mamba2-370m [ssm]: 48L d=1024 attn-free, vocab=50280, ssm_state=128.
SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv=0,
    head_dim=0,
    d_ff=0,
    vocab=pad_vocab(50280),   # 50280 -> 50304
    ssm_state=128,
    ssm_headdim=64,           # d_inner=2048 -> 32 SSD heads
    ssm_chunk=128,
)
