"""Architecture registry + input-shape cells.

Ten assigned architectures (exact published configs; vocab padded up to a
multiple of 128 for model-axis sharding — original sizes kept in comments),
plus the paper's own RNS configuration.

Shape cells (per assignment):
    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill)
    decode_32k   seq 32768,  global_batch 128   (serve decode, 1 new token)
    long_500k    seq 524288, global_batch 1     (long-context decode)

``long_500k`` runs only for sub-quadratic archs (ssm/hybrid/sliding-window);
pure full-attention archs skip it (DESIGN.md §6).  Encoder-only archs would
skip decode cells, but none of the ten is encoder-only.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "gemma3_1b",
    "gemma_2b",
    "gemma_7b",
    "llama32_3b",
    "mamba2_370m",
    "whisper_tiny",
    "internvl2_26b",
    "zamba2_1p2b",
    "qwen2_moe_a2p7b",
    "moonshot_v1_16b_a3b",
]

# CLI ids (match the assignment spelling) -> module names
ALIASES = {
    "gemma3-1b": "gemma3_1b",
    "gemma-2b": "gemma_2b",
    "gemma-7b": "gemma_7b",
    "llama3.2-3b": "llama32_3b",
    "mamba2-370m": "mamba2_370m",
    "whisper-tiny": "whisper_tiny",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
}

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def pad_vocab(v: int) -> int:
    """Round up to a multiple of 128 so vocab shards over the model axis."""
    return -(-v // 128) * 128


def get_config(name: str) -> ModelConfig:
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG.validate()


def shape_cells(cfg: ModelConfig) -> list[str]:
    """The shape cells this arch runs (skip rules in DESIGN.md §6)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    sub_quadratic = cfg.family in ("ssm", "hybrid") or bool(cfg.window)
    if sub_quadratic and cfg.family != "encdec":
        cells.append("long_500k")
    return cells
