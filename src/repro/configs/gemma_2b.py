"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) ff=16384 vocab=256000,
GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    head_dim=256,
    d_ff=16384,
    vocab=pad_vocab(256000),  # 256000 (aligned)
    act="geglu",
)
