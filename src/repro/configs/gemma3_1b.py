"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) ff=6912 vocab=262144,
5:1 local:global sliding window, head_dim=256, GeGLU.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv=1,
    head_dim=256,
    d_ff=6912,
    vocab=pad_vocab(262144),  # 262144 (already aligned)
    act="geglu",
    rope_theta=1_000_000.0,
    window=512,
    global_every=6,           # layers 6,12,18,24 are global (5 local : 1 global)
)
