"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d=2048 (ssm_state=64) + ONE
weight-shared attention block (32H kv=32 hd=64, ff=8192) applied after every
6 SSM layers (simplified from Zamba2's 2-block rotation; DESIGN.md §7).
vocab=32000.  [arXiv:2411.15242; hf]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    d_ff=8192,
    vocab=pad_vocab(32000),   # 32000 (aligned)
    ssm_state=64,
    ssm_headdim=64,           # d_inner=4096 -> 64 SSD heads
    ssm_chunk=128,
    attn_every=6,             # 6 groups of 6 + 2-layer tail
)
