"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) ff=8192 vocab=128256,
SwiGLU, head_dim=128.  [hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.configs import pad_vocab
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=pad_vocab(128256),  # 128256 (aligned)
    act="swiglu",
    rope_theta=500_000.0,
)
