"""Serving substrate: prefill / decode with sharded caches."""
from .serve_step import make_prefill, make_decode_step, cache_abstract  # noqa: F401
