"""Serving substrate: prefill/decode steps over sharded caches plus the
continuous-batching engine (slot scheduler + persistent-jit batcher,
DESIGN.md §12)."""
from .serve_step import make_prefill, make_decode_step, cache_abstract  # noqa: F401
from .scheduler import Request, Slot, SlotScheduler  # noqa: F401
from .batcher import ContinuousBatcher  # noqa: F401
from .crypto import CryptoContext, CryptoRequest  # noqa: F401
