"""Serving substrate: prefill/decode steps over sharded caches, the
continuous-batching engine (slot scheduler + persistent-jit batcher,
DESIGN.md §12), and the saturation-grade offline harness + closed-loop
load generator on top of it (DESIGN.md §16)."""
from .serve_step import make_prefill, make_decode_step, cache_abstract  # noqa: F401
from .scheduler import Request, Slot, SlotScheduler  # noqa: F401
from .batcher import ContinuousBatcher  # noqa: F401
from .crypto import CryptoContext, CryptoRequest  # noqa: F401
from .offline import (  # noqa: F401
    CompletionPump, OfflineInference, ReplicaSet, pow2_buckets,
    replica_meshes, sample_stats,
)
from .loadgen import SLO, poisson_requests, search_max_qps  # noqa: F401
