"""Serving steps: prefill (prompt -> cache) and decode (one token, batched).

Wraps the family decode paths with a stable (params, cache, tokens, pos)
signature; `cache_abstract` derives the exact cache pytree of
ShapeDtypeStructs via eval_shape of the prefill — the dry-run lowers
decode_step against it without allocating a byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill

__all__ = ["make_prefill", "make_decode_step", "cache_abstract",
           "paged_pool_abstract", "prompt_abstract", "crypto_state_abstract"]


def make_prefill(cfg, cache_len: int):
    def fn(params, batch):
        return prefill(cfg, params, batch, cache_len)

    return fn


def make_decode_step(cfg):
    def fn(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    return fn


def prompt_abstract(cfg, batch: int, seq: int):
    """ShapeDtypeStructs of a prompt batch at (batch, seq)."""
    spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.float32
        )
    return spec


def cache_abstract(cfg, params_abs, batch: int, cache_len: int):
    """Abstract cache pytree for a decode step with capacity `cache_len`.

    Derived via eval_shape of prefill over a full-capacity prompt, so it is
    structurally identical to what serving would hold.  The prompt length
    equals capacity (minus the vlm patch prefix), i.e. the decode_32k /
    long_500k cells' "cache of seq_len" semantics.
    """
    prompt_len = cache_len - (cfg.n_patches if cfg.family == "vlm" else 0)
    prompt = prompt_abstract(cfg, batch, prompt_len)
    _, cache = jax.eval_shape(
        lambda p, b: prefill(cfg, p, b, cache_len), params_abs, prompt
    )
    return cache


def crypto_state_abstract(ctx, n_slots: int):
    """Abstract device state of the crypto lane (DESIGN.md §15): one row
    per slot holding the Montgomery-ladder registers in both bases, the
    per-request channel constants of the modulus ``N`` (per-request DATA,
    so one compiled graph serves every modulus mix), and the fixed-width
    MSB-first exponent bit row the ladder consumes ``chunk`` at a time.

    ``ctx`` is a ``serve.crypto.CryptoContext`` (duck-typed: only
    ``nch_lo`` / ``n`` / ``n_hi`` / ``exp_bits`` and the base dtype are
    read, so this module stays importable without the crypto stack).
    """
    dt = jnp.int32
    row = lambda w: jax.ShapeDtypeStruct((n_slots, w), dt)
    return {
        "r0_lo": row(ctx.nch_lo), "r0_hi": row(ctx.n_hi),
        "r1_lo": row(ctx.nch_lo), "r1_hi": row(ctx.n_hi),
        "neg": row(ctx.n), "n_lo": row(ctx.nch_lo), "n_hi": row(ctx.n_hi),
        "bits": row(ctx.exp_bits),
    }


def paged_pool_abstract(cfg, params_abs, n_pages: int, page_size: int):
    """Abstract PAGED pool pytree (DESIGN.md §13): k/v leaves of shape
    (L, n_pages, page_size, g, hd).

    Structurally this is just ``cache_abstract`` with the page pool
    standing in for the batch axis and one page for the sequence axis —
    pages are interchangeable fixed-size row fragments, so the pooled
    buffer is literally a decode cache of ``n_pages`` tiny rows that the
    page table recomposes into logical rows at gather time.
    """
    return cache_abstract(cfg, params_abs, n_pages, page_size)
