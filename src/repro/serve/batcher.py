"""Continuous-batching serve engine over the sharded KV cache (DESIGN.md §12).

One persistent ``jax.jit`` decode step serves every in-flight request at
once: the batch axis of the decode cache is a pool of ``n_slots`` fixed-
capacity rows ("slots"), each row belonging to at most one request.  New
requests are admitted into FREE rows *mid-decode* — the engine chunk-
prefills the prompt through a fixed-shape ``extend_step`` graph, splices
the resulting row into the batched cache with one jitted
dynamic-update, and the very next decode step carries the newcomer along
with every already-running stream.  Because the decode step takes per-row
positions (a ``(n_slots,)`` vector, see models/attention.py), arrival and
departure never change any traced shape: the engine compiles each of its
four graphs exactly once per process, which ``jit_cache_sizes()`` exposes
and tests/test_serve_batcher.py asserts.

Slot rows are computationally independent (attention masks per-row, MoE
dispatch is per-row, every norm/matmul is row-local), so a request's
tokens are bitwise-identical whether it runs alone or packed against
arbitrary co-resident traffic — the isolation invariant the batcher's
tier-1 tests pin down.

The cache layout is exactly ``dist/sharding.cache_specs``' decode layout:
pass ``mesh=`` and the batched cache is placed on it — slots (the batch
axis) shard over the data axes, KV heads over "model" when divisible, and
the GQA sequence-axis fallback applies unchanged because slots only ever
index the batch axis.

``rns_verify=True`` arms the RNS integrity path: at admission the engine
fingerprints the slot's immutable prompt region (per-layer K/V sums) and
encodes it through an RRNS ``GradCodec`` into a typed channel-major
``RnsArray`` wire buffer.  Decode traffic never writes below a slot's
prompt length, so at retirement the recomputed fingerprint must match
bitwise — any mismatch means cross-slot clobbering.  The wire buffers
themselves are locate-and-correct codewords: ``wire_ok`` detects a
corrupted stored buffer via ``verify_packed`` and ``repair_wire`` rebuilds
the bad channel in place with ``dist.fault.repair_packed`` — fault repair
composed with serving (DESIGN.md §12).

Doctest — admit, stream, retire (a 5-token prompt, 4 greedy tokens)::

    >>> import jax
    >>> from repro.configs import get_config
    >>> from repro.models import init_params
    >>> from repro.serve.batcher import ContinuousBatcher
    >>> from repro.serve.scheduler import Request
    >>> cfg = get_config("gemma-2b").smoke()
    >>> eng = ContinuousBatcher(cfg, init_params(cfg, jax.random.key(0)),
    ...                         n_slots=2, cache_len=32, prefill_chunk=8)
    >>> eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=4))
    >>> done = eng.run_to_completion()
    >>> [(r.rid, len(r.out)) for r in done]
    [(0, 4)]
    >>> eng.jit_cache_sizes()["decode"]         # one persistent trace
    1
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import cache_specs, named_shardings
from repro.models import decode_step, extend_step
from repro.serve.scheduler import Request, Slot, SlotScheduler
from repro.serve.serve_step import cache_abstract

__all__ = ["ContinuousBatcher"]

_SUPPORTED = ("dense", "moe")


def _zero_cache(abs_tree):
    """Concrete all-zero cache matching an abstract decode-cache pytree
    ("len" becomes the int32 scalar 0)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype), abs_tree
    )


class ContinuousBatcher:
    """Slot-based continuous batching over the sharded decode cache.

    Parameters
    ----------
    cfg, params : the model (linear-KV transformer families: dense/moe).
        Sliding-window archs are lowered to the masked full-length cache
        layout (``window_cache=False``) so every slot row is linear.
    n_slots : rows of the batched cache = max concurrent requests.
    cache_len : per-slot KV capacity; every request needs
        ``len(prompt) + max_new <= cache_len``.
    prefill_chunk : token-chunk size of the admission prefill loop — long
        prompts run as ceil(plen/chunk) calls of ONE fixed-shape graph.
    rns_verify : arm the RnsArray cache-integrity fingerprints.
    mesh : optional ``jax.sharding.Mesh``; the batched cache is placed on
        ``dist.sharding.cache_specs``' layout over it.
    """

    def __init__(self, cfg, params, *, n_slots: int, cache_len: int,
                 prefill_chunk: int = 32, rns_verify: bool = False,
                 mesh=None):
        cfg.validate()
        if cfg.family not in _SUPPORTED:
            raise NotImplementedError(
                f"continuous batching needs a linear-KV transformer family "
                f"{_SUPPORTED}, not {cfg.family!r} (SSM/hybrid state and "
                f"encoder caches are not slot-spliceable yet)"
            )
        if cfg.kv_quant:
            raise NotImplementedError(
                "int8 KV slots need per-slot scale re-estimation at "
                "admission; run the batcher on the fp cache layout"
            )
        if cfg.window and cfg.window_cache:
            # grouped ring caches can't take per-row positions; the masked
            # full-length layout is semantically identical (more HBM)
            cfg = dataclasses.replace(cfg, window_cache=False)
        if cache_len > 512 and cache_len % 512:
            raise ValueError(
                "cache_len beyond one flash chunk must be a multiple of "
                "512 (prefill eval_shape runs the chunked attention)"
            )
        if cache_len % prefill_chunk:
            # a prompt padded to the chunk grid could otherwise run past
            # the row and XLA's update-slice clamp would silently shift
            # the write window backwards over earlier positions
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must divide "
                f"cache_len={cache_len}"
            )
        self.cfg, self.params = cfg, params
        self.prefill_chunk = int(prefill_chunk)
        self.rns_verify = bool(rns_verify)
        self.sched = SlotScheduler(n_slots, cache_len)

        params_abs = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params
        )
        solo_abs = cache_abstract(cfg, params_abs, 1, cache_len)
        batch_abs = cache_abstract(cfg, params_abs, n_slots, cache_len)
        self._solo_zero = _zero_cache(solo_abs)
        self.cache = _zero_cache(batch_abs)
        self.mesh = mesh
        if mesh is not None:
            self.cache_pspecs = cache_specs(batch_abs, mesh)
            self.cache = jax.device_put(
                self.cache, named_shardings(self.cache_pspecs, mesh)
            )

        # The engine's four graphs — each traces exactly once per process
        # because every argument keeps a fixed shape across admissions,
        # retirements, and arbitrary slot occupancy.
        self._extend_fn = jax.jit(
            lambda p, c, t, pos, idx: extend_step(
                cfg, p, c, t, pos, logit_index=idx
            )
        )
        self._decode_fn = jax.jit(self._decode_impl)
        self._insert_fn = jax.jit(self._insert_impl)
        self._fp_fn = jax.jit(self._fp_impl) if rns_verify else None
        if rns_verify:
            from repro.dist.grad_codec import GradCodec

            # world=1: fingerprints are fresh encodings, wraps=0 repairs
            self.codec = GradCodec.make(world=1, correct=True)
            self._wire: dict[int, object] = {}
            self.verify_log: dict[int, bool] = {}

    # ------------------------------------------------------ jitted graphs
    def _decode_impl(self, params, cache, tokens, pos):
        """One batched decode step + greedy sampling.  tokens: (B, 1),
        pos: (B,) per-slot write positions."""
        logits, cache = decode_step(self.cfg, params, cache, tokens, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _insert_impl(self, batch_cache, solo_cache, slot):
        """Splice a freshly prefilled solo cache (batch 1) into slot row
        ``slot`` of the batched cache (one dynamic-update per leaf; the
        scalar "len" bookkeeping leaf is left alone)."""
        def one(b_leaf, s_leaf):
            if getattr(b_leaf, "ndim", 0) == 0:
                return b_leaf
            return jax.lax.dynamic_update_slice_in_dim(
                b_leaf, s_leaf.astype(b_leaf.dtype), slot, axis=1
            )

        return jax.tree_util.tree_map(one, batch_cache, solo_cache)

    def _fp_impl(self, cache, slot, plen):
        """Per-layer masked K/V sums over slot row ``slot``'s immutable
        prompt region [0, plen) -> (2L,) f32 fingerprint vector."""
        valid = (jnp.arange(cache["k"].shape[2]) < plen).astype(jnp.float32)
        sums = []
        for name in ("k", "v"):
            row = jax.lax.dynamic_index_in_dim(
                cache[name], slot, axis=1, keepdims=False
            )  # (L, S, g, hd)
            sums.append(jnp.sum(
                row.astype(jnp.float32) * valid[None, :, None, None],
                axis=(1, 2, 3),
            ))
        return jnp.concatenate(sums)

    # ------------------------------------------------------ admission path
    def submit(self, req: Request) -> None:
        if self.rns_verify and (
            req.rid in self._wire
            or any(q.rid == req.rid for q in self.sched.queue)
        ):
            # verify state is keyed on rid; refuse the collision before
            # any slot is bound or device work runs
            raise ValueError(
                f"rid {req.rid} already holds verify state (queued, in "
                f"flight, or retired-undrained); use unique rids, or "
                f"drain_completed() between reuses"
            )
        self.sched.submit(req)

    def try_admit(self, now: float = 0.0) -> list[Slot]:
        """Admit as many queued requests as there are FREE slots; each
        admission chunk-prefills the prompt and splices it into the
        batched cache.  Returns the admitted slots (normally now in
        DECODE; already FREE again if the first token retired the
        request — one-token budget or instant EOS)."""
        admitted = []
        while True:
            slot = self.sched.admit_next(now)
            if slot is None:
                return admitted
            self._prefill_into(slot, now)
            admitted.append(slot)

    def _prefill_into(self, slot: Slot, now: float) -> None:
        req = slot.req
        prompt = [int(t) for t in req.prompt]
        plen, C = len(prompt), self.prefill_chunk
        n_chunks = -(-plen // C)
        prompt = prompt + [0] * (n_chunks * C - plen)
        solo = self._solo_zero
        last = (plen - 1) - (n_chunks - 1) * C
        for ci in range(n_chunks):
            toks = jnp.asarray([prompt[ci * C:(ci + 1) * C]], jnp.int32)
            # only the final chunk's last REAL prompt position is ever
            # read (chunk padding beyond it is causally invisible below
            # it); the traced index keeps the unembed to one row per call
            idx = last if ci == n_chunks - 1 else 0
            logits, solo = self._extend_fn(
                self.params, solo, toks, jnp.int32(ci * C), jnp.int32(idx)
            )
        first = int(jnp.argmax(logits[0, 0]))
        self.cache = self._insert_fn(
            self.cache, solo, jnp.int32(slot.index)
        )
        if self.rns_verify:
            fp = self._fp_fn(
                self.cache, jnp.int32(slot.index), jnp.int32(plen)
            )
            self._wire[req.rid] = self.codec.encode_array(
                fp, channel_major=True
            )
        if self.sched.start_decode(slot, first, now) and self.rns_verify:
            # instant retirement (one-token budget / immediate EOS) never
            # reaches step()'s retirement branch — verify here instead
            self.verify_log[req.rid] = self.verify_request(req)

    # --------------------------------------------------------- decode loop
    def step(self, now: float = 0.0) -> list[Request]:
        """One persistent batched decode step over every DECODE slot;
        returns the requests that retired this step."""
        decoding = self.sched.decoding_slots()
        if not decoding:
            return []
        toks, poss = self.sched.step_rows()
        nxt, self.cache = self._decode_fn(
            self.params,
            self.cache,
            jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(poss, jnp.int32),
        )
        nxt = np.asarray(nxt)
        retired = []
        for slot in decoding:
            self.sched.advance(slot)
            req = slot.req
            if self.sched.record_token(slot, int(nxt[slot.index]), now):
                retired.append(req)
                if self.rns_verify:
                    self.verify_log[req.rid] = self.verify_request(req)
        return retired

    def run_to_completion(self, max_steps: int = 1 << 20) -> list[Request]:
        """Drain queue and slots (all arrivals already submitted)."""
        steps = 0
        while self.sched.busy:
            self.try_admit(float(steps))
            if self.sched.decoding_slots():
                self.step(float(steps))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serve loop exceeded max_steps")
        return self.sched.completed

    def drain_completed(self) -> list[Request]:
        """Hand back the retired requests and release the engine-held
        state keyed on them (wire buffers, verify entries).  A long-lived
        server calls this after reading each batch of results — without
        it, retired-request state (host Request objects and, under
        ``rns_verify``, one device RnsArray per request) accumulates for
        the engine's lifetime."""
        done, self.sched.completed = self.sched.completed, []
        if self.rns_verify:
            for r in done:
                self._wire.pop(r.rid, None)
                self.verify_log.pop(r.rid, None)
        return done

    def jit_cache_sizes(self) -> dict:
        """Compiled-graph counts per engine function — the no-retrace
        invariant says every value stays 1 for the engine's lifetime."""
        sizes = {
            "decode": self._decode_fn._cache_size(),
            "extend": self._extend_fn._cache_size(),
            "insert": self._insert_fn._cache_size(),
        }
        if self._fp_fn is not None:
            sizes["fingerprint"] = self._fp_fn._cache_size()
        return sizes

    # ------------------------------------------------- RNS integrity path
    def _require_verify(self):
        if not self.rns_verify:
            raise RuntimeError("engine built without rns_verify=True")

    def verify_request(self, req: Request) -> bool:
        """Recompute the prompt-region fingerprint of ``req``'s slot row
        and compare its RNS encoding bitwise against the stored wire
        buffer.  Valid until the slot row is reused by a later admission;
        the engine calls this automatically at retirement."""
        self._require_verify()
        fp = self._fp_fn(
            self.cache, jnp.int32(req.slot_index),
            jnp.int32(len(req.prompt)),
        )
        fresh = self.codec.encode_array(fp, channel_major=True)
        stored = self._wire[req.rid]
        return bool(jnp.array_equal(fresh.residues, stored.residues))

    def wire_ok(self, rid: int) -> bool:
        """Codeword self-consistency of the stored wire buffer (RRNS
        redundant-channel check) — detects corruption of the stored
        fingerprint itself, without touching the cache."""
        self._require_verify()
        return bool(jnp.all(self.codec.verify_packed(self._wire[rid])))

    def repair_wire(self, rid: int) -> dict:
        """Locate-and-correct the stored wire buffer in place via
        ``dist.fault.repair_packed``; returns its report dict."""
        from repro.dist.fault import repair_packed

        self._require_verify()
        fixed, report = repair_packed(self.codec, self._wire[rid], wraps=0)
        self._wire[rid] = fixed
        return report

    def corrupt_wire(self, rid: int, channel: int = 0, delta: int = 1,
                     index: int = 0) -> None:
        """Fault injection for tests/drivers: modular-bump one residue of
        the stored wire buffer (stays a syntactically valid residue so the
        corruption is only catchable by the redundant channels)."""
        self._require_verify()
        arr = self._wire[rid]
        mods = tuple(self.codec.base.moduli) + self.codec.redundant
        m = mods[channel]
        res = arr.residues
        res = res.at[channel, index].set(
            (res[channel, index] + jnp.int32(delta)) % m
        )
        self._wire[rid] = dataclasses.replace(arr, residues=res)
