"""Continuous-batching serve engine over the sharded KV cache (DESIGN.md §12).

One persistent ``jax.jit`` decode step serves every in-flight request at
once: the batch axis of the decode cache is a pool of ``n_slots`` fixed-
capacity rows ("slots"), each row belonging to at most one request.  New
requests are admitted into FREE rows *mid-decode* — the engine chunk-
prefills the prompt through a fixed-shape ``extend_step`` graph, splices
the resulting row into the batched cache with one jitted
dynamic-update, and the very next decode step carries the newcomer along
with every already-running stream.  Because the decode step takes per-row
positions (a ``(n_slots,)`` vector, see models/attention.py), arrival and
departure never change any traced shape: the engine compiles each of its
four graphs exactly once per process, which ``jit_cache_sizes()`` exposes
and tests/test_serve_batcher.py asserts.

Slot rows are computationally independent (attention masks per-row, MoE
dispatch is per-row, every norm/matmul is row-local), so a request's
tokens are bitwise-identical whether it runs alone or packed against
arbitrary co-resident traffic — the isolation invariant the batcher's
tier-1 tests pin down.

The cache layout is exactly ``dist/sharding.cache_specs``' decode layout:
pass ``mesh=`` and the batched cache is placed on it — slots (the batch
axis) shard over the data axes, KV heads over "model" when divisible, and
the GQA sequence-axis fallback applies unchanged because slots only ever
index the batch axis.

``page_size=`` switches the engine onto the PAGED pool layout
(DESIGN.md §13): the cache becomes one pooled buffer of fixed-size pages,
a host-side ``(n_slots, n_pg)`` page table (``PagedScheduler``) maps each
slot's logical pages to physical ones, and admission deduplicates shared
prompt prefixes — shared pages are refcounted read-only, the first write
into one triggers a copy-on-write through a jitted page-copy graph.  The
page table rides into the decode/extend graphs as DATA (an int32 array
argument, never a trace constant), so the one-persistent-trace invariant
carries over unchanged; prompts prefill straight into the pool through the
table (no solo cache, no splice).

``rns_verify=True`` arms the RNS integrity path: at admission the engine
fingerprints the slot's immutable prompt region (per-layer K/V sums) and
encodes it through an RRNS ``GradCodec`` into a typed channel-major
``RnsArray`` wire buffer, held in a ``dist.fault.WireStore`` keyed by
request id — or, in paged mode, by PHYSICAL PAGE, so one codeword covers
every reader of a shared page and is checked when the page is freed or
evicted.  Decode traffic never writes below a slot's prompt length, so at
retirement the recomputed fingerprint must match bitwise — any mismatch
means cross-slot clobbering.  The wire buffers themselves are
locate-and-correct codewords: ``wire_ok`` detects a corrupted stored
buffer via ``verify_packed`` and ``repair_wire`` rebuilds the bad channel
in place with ``dist.fault.repair_packed`` — fault repair composed with
serving (DESIGN.md §12).

Doctest — admit, stream, retire (a 5-token prompt, 4 greedy tokens)::

    >>> import jax
    >>> from repro.configs import get_config
    >>> from repro.models import init_params
    >>> from repro.serve.batcher import ContinuousBatcher
    >>> from repro.serve.scheduler import Request
    >>> cfg = get_config("gemma-2b").smoke()
    >>> eng = ContinuousBatcher(cfg, init_params(cfg, jax.random.key(0)),
    ...                         n_slots=2, cache_len=32, prefill_chunk=8)
    >>> eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=4))
    >>> done = eng.run_to_completion()
    >>> [(r.rid, len(r.out)) for r in done]
    [(0, 4)]
    >>> eng.jit_cache_sizes()["decode"]         # one persistent trace
    1
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import cache_specs, named_shardings
from repro.models import decode_step, extend_step
from repro.serve.scheduler import PagedScheduler, Request, Slot, SlotScheduler
from repro.serve.serve_step import cache_abstract, paged_pool_abstract

__all__ = ["ContinuousBatcher"]

_SUPPORTED = ("dense", "moe")


def _zero_cache(abs_tree):
    """Concrete all-zero cache matching an abstract decode-cache pytree
    ("len" becomes the int32 scalar 0)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype), abs_tree
    )


class ContinuousBatcher:
    """Slot-based continuous batching over the sharded decode cache.

    Parameters
    ----------
    cfg, params : the model (linear-KV transformer families: dense/moe).
        Sliding-window archs are lowered to the masked full-length cache
        layout (``window_cache=False``) so every slot row is linear.
    n_slots : rows of the batched cache = max concurrent requests.
    cache_len : per-slot KV capacity; every request needs
        ``len(prompt) + max_new <= cache_len``.
    prefill_chunk : token-chunk size of the admission prefill loop — long
        prompts run as ceil(plen/chunk) calls of ONE fixed-shape graph.
    prefill_buckets : optional ascending tuple of prompt-length buckets.
        Admission pads the prompt to the smallest bucket >= plen and
        runs ONE extend call per prompt instead of the chunk loop — one
        compiled graph per bucket width, pre-compiled by the offline
        harness's warmup.  Prompts longer than the largest bucket fall
        back to the chunked loop (counted in ``bucket_stats()``).
        Bitwise-identical to chunked prefill: pad positions beyond plen
        are causally invisible and later overwritten by decode writes.
        Composes with ``page_size``: on the paged pool the bucket is
        chosen by the tokens LEFT to compute after the shared-prefix
        skip, real tokens write through the ordinary page-table barrier,
        and pad tokens scatter into a per-call scratch page that is
        freed immediately — the padded write barrier of DESIGN.md §13.
    rns_verify : arm the RnsArray cache-integrity fingerprints.
    mesh : optional ``jax.sharding.Mesh``; the batched cache is placed on
        ``dist.sharding.cache_specs``' layout over it.
    page_size : switch to the paged pool layout with pages of this many
        tokens (must divide ``cache_len`` and align with
        ``prefill_chunk``).  None (default) keeps the monolithic slot-row
        cache.
    n_pages : physical pages in the pool (paged mode only).  Defaults to
        ``1 + n_slots * (cache_len // page_size)`` — parking page plus
        full backing for every slot, i.e. zero admission deferrals; a
        smaller pool oversubscribes slots against pages.
    prefix_share : admission-time prompt-prefix dedup via the content
        registry (paged mode only); disable to measure pure paging.
    crypto_slots : slots of the big-integer crypto lane (DESIGN.md §15);
        0 (default) disables the second request family entirely.  With
        crypto armed, ``submit`` dispatches on the request's ``family``
        tag: ``serve.crypto.CryptoRequest`` rides the crypto lane,
        ``Request`` the LLM lane, and both share the tick clock, the
        verify log, and (under ``rns_verify``) the wire store.
    crypto_ctx : optional ``serve.crypto.CryptoContext``; defaults to a
        fresh context (8 limbs per base, 32-bit exponents).
    crypto_chunk : Montgomery-ladder bits advanced per engine tick; must
        divide the context's ``exp_bits``.
    """

    def __init__(self, cfg, params, *, n_slots: int, cache_len: int,
                 prefill_chunk: int = 32,
                 prefill_buckets: tuple | None = None,
                 rns_verify: bool = False,
                 mesh=None, page_size: int | None = None,
                 n_pages: int | None = None, prefix_share: bool = True,
                 crypto_slots: int = 0, crypto_ctx=None,
                 crypto_chunk: int = 8):
        cfg.validate()
        if cfg.family not in _SUPPORTED:
            raise NotImplementedError(
                f"continuous batching needs a linear-KV transformer family "
                f"{_SUPPORTED}, not {cfg.family!r} (SSM/hybrid state and "
                f"encoder caches are not slot-spliceable yet)"
            )
        if cfg.kv_quant:
            raise NotImplementedError(
                "int8 KV slots need per-slot scale re-estimation at "
                "admission; run the batcher on the fp cache layout"
            )
        if cfg.window and cfg.window_cache:
            # grouped ring caches can't take per-row positions; the masked
            # full-length layout is semantically identical (more HBM)
            cfg = dataclasses.replace(cfg, window_cache=False)
        if cache_len > 512 and cache_len % 512:
            lo, hi = cache_len // 512 * 512, -(-cache_len // 512) * 512
            raise ValueError(
                f"cache_len={cache_len} beyond one flash chunk must be a "
                f"multiple of 512 (prefill eval_shape runs the chunked "
                f"attention); nearest legal cache_len: {lo} or {hi}"
            )
        divisors = [d for d in range(1, cache_len + 1) if cache_len % d == 0]
        if cache_len % prefill_chunk:
            # a prompt padded to the chunk grid could otherwise run past
            # the row and XLA's update-slice clamp would silently shift
            # the write window backwards over earlier positions
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must divide "
                f"cache_len={cache_len}; valid prefill_chunk values: "
                f"{divisors}"
            )
        self.cfg, self.params = cfg, params
        self.prefill_chunk = C = int(prefill_chunk)
        self.rns_verify = bool(rns_verify)
        self.paged = page_size is not None
        self.page_size = int(page_size) if self.paged else None

        self.prefill_buckets: tuple[int, ...] | None = None
        if prefill_buckets is not None:
            bks = tuple(sorted({int(b) for b in prefill_buckets}))
            if not bks:
                raise ValueError("prefill_buckets must name >= 1 bucket")
            for b in bks:
                if b < 1 or b > cache_len:
                    raise ValueError(
                        f"bucket {b} out of range 1..cache_len={cache_len}"
                    )
                if b > 512 and b % 512:
                    raise ValueError(
                        f"bucket {b} beyond one flash chunk must be a "
                        f"multiple of 512 (the padded extend runs the "
                        f"chunked attention)"
                    )
            self.prefill_buckets = bks
            # admission-time accounting the offline harness reports:
            # hits per bucket width, chunk-loop fallbacks, pad waste
            self.bucket_hits: dict[int, int] = {b: 0 for b in bks}
            self.bucket_fallbacks = 0
            self.bucket_pad_tokens = 0
            self.bucket_real_tokens = 0

        if self.paged:
            ps = self.page_size
            if cache_len % ps:
                raise ValueError(
                    f"page_size={ps} must divide cache_len={cache_len}; "
                    f"valid page sizes: {divisors}"
                )
            if ps % C and C % ps:
                # page-aligned OR chunk-aligned prefill writes; anything
                # else makes every chunk straddle page ownership checks
                legal = [d for d in divisors if d % C == 0 or C % d == 0]
                raise ValueError(
                    f"page_size={ps} must align with prefill_chunk={C} "
                    f"(one must divide the other); chunk-compatible page "
                    f"sizes for cache_len={cache_len}: {legal}"
                )
            if ps > 512 and ps % 512:
                raise ValueError(
                    f"page_size={ps} beyond one flash chunk must be a "
                    f"multiple of 512 (the pool abstract runs the chunked "
                    f"prefill per page); nearest legal page_size: "
                    f"{ps // 512 * 512} or {-(-ps // 512) * 512}"
                )
            n_pg = cache_len // ps
            if n_pages is None:
                n_pages = 1 + n_slots * n_pg
            min_pages = n_pg + 2
            if n_pages < min_pages:
                raise ValueError(
                    f"n_pages={n_pages} cannot guarantee admission of one "
                    f"max-length request: cache_len={cache_len} / "
                    f"page_size={ps} = {n_pg} logical pages, plus the "
                    f"parking page and one page of mid-page-divergence "
                    f"headroom; minimum n_pages: {min_pages}"
                )
            self.n_pages = int(n_pages)
            self.sched = PagedScheduler(
                n_slots, cache_len, page_size=ps, n_pages=self.n_pages,
                prefill_chunk=C, prefix_share=prefix_share,
                prefill_buckets=self.prefill_buckets,
            )
        else:
            self.sched = SlotScheduler(n_slots, cache_len)

        params_abs = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params
        )
        if self.paged:
            pool_abs = paged_pool_abstract(
                cfg, params_abs, self.n_pages, self.page_size
            )
            self._solo_zero = None
            self.cache = _zero_cache(pool_abs)
        else:
            solo_abs = cache_abstract(cfg, params_abs, 1, cache_len)
            pool_abs = cache_abstract(cfg, params_abs, n_slots, cache_len)
            self._solo_zero = _zero_cache(solo_abs)
            self.cache = _zero_cache(pool_abs)
        self.mesh = mesh
        if mesh is not None:
            self.cache_pspecs = cache_specs(
                pool_abs, mesh, paged_pool=self.paged
            )
            self.cache = jax.device_put(
                self.cache, named_shardings(self.cache_pspecs, mesh)
            )

        # The engine's jitted graphs — each traces exactly once per
        # process because every argument keeps a fixed shape across
        # admissions, retirements, and arbitrary slot occupancy (in paged
        # mode the page table is an int32 ARRAY argument: its contents
        # are data, never trace constants).
        if self.paged:
            psz = self.page_size
            # valid/scratch are traced int32 DATA (the padded write
            # barrier): the chunk loop passes valid = chunk width (all
            # tokens through the table — chunk-grid pads included, same
            # as ever) with the parking page as a dead scratch operand;
            # bucketed prefill passes valid = real tokens + a live
            # scratch page.  Either way one graph per token width.
            self._extend_fn = jax.jit(
                lambda p, c, t, pos, idx, pg, valid, scr: extend_step(
                    cfg, p, c, t, pos, logit_index=idx,
                    pages=pg, page_size=psz, valid_len=valid, scratch=scr,
                )
            )
            self._decode_fn = jax.jit(self._decode_paged_impl)
            self._copy_fn = jax.jit(self._copy_impl)
            self._insert_fn = None
        else:
            self._extend_fn = jax.jit(
                lambda p, c, t, pos, idx: extend_step(
                    cfg, p, c, t, pos, logit_index=idx
                )
            )
            self._decode_fn = jax.jit(self._decode_impl)
            self._insert_fn = jax.jit(self._insert_impl)
            self._copy_fn = None
        self._fp_fn = (
            jax.jit(self._fp_paged_impl if self.paged else self._fp_impl)
            if rns_verify else None
        )
        if rns_verify:
            from repro.dist.fault import WireStore
            from repro.dist.grad_codec import GradCodec

            # world=1: fingerprints are fresh encodings, wraps=0 repairs
            self.codec = GradCodec.make(world=1, correct=True)
            # keyed by rid (monolithic rows) / physical page (paged pool)
            self.wire = WireStore(self.codec)
            self._page_span: dict[int, int] = {}
            # physical page -> rid whose prefill published its codeword,
            # so corruption detected at EVICTION (no retiring request in
            # hand) still lands in verify_log under a request id
            self._page_pub: dict[int, object] = {}
            self.verify_log: dict[int, bool] = {}

        # Crypto lane (DESIGN.md §15): a second request family on the same
        # engine.  Its jitted graphs follow the exact no-retrace contract
        # of the LLM graphs above — fixed shapes, slot ids and cursors as
        # data — and its per-slot fingerprints share the LLM wire store
        # under ("crypto", rid) keys.
        self.crypto = None
        if crypto_slots:
            from repro.serve.crypto import (
                CryptoContext, CryptoLane, make_crypto_fns,
            )
            from repro.serve.serve_step import crypto_state_abstract

            self.crypto_ctx = (
                crypto_ctx if crypto_ctx is not None else CryptoContext()
            )
            self.crypto = CryptoLane(
                int(crypto_slots), self.crypto_ctx.exp_bits,
                int(crypto_chunk),
            )
            self.crypto_state = _zero_cache(
                crypto_state_abstract(self.crypto_ctx, int(crypto_slots))
            )
            self._crypto_fns = make_crypto_fns(
                self.crypto_ctx, int(crypto_chunk)
            )
        elif crypto_ctx is not None:
            raise ValueError("crypto_ctx= given but crypto_slots=0; pass "
                             "crypto_slots>=1 to enable the crypto lane")

    @property
    def _wire(self) -> dict:
        """Raw key -> RnsArray mapping of the wire store (rid-keyed on the
        monolithic path, page-keyed on the paged path)."""
        return self.wire.raw

    # ------------------------------------------------------ jitted graphs
    def _decode_impl(self, params, cache, tokens, pos):
        """One batched decode step + greedy sampling.  tokens: (B, 1),
        pos: (B,) per-slot write positions."""
        logits, cache = decode_step(self.cfg, params, cache, tokens, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _insert_impl(self, batch_cache, solo_cache, slot):
        """Splice a freshly prefilled solo cache (batch 1) into slot row
        ``slot`` of the batched cache (one dynamic-update per leaf; the
        scalar "len" bookkeeping leaf is left alone)."""
        def one(b_leaf, s_leaf):
            if getattr(b_leaf, "ndim", 0) == 0:
                return b_leaf
            return jax.lax.dynamic_update_slice_in_dim(
                b_leaf, s_leaf.astype(b_leaf.dtype), slot, axis=1
            )

        return jax.tree_util.tree_map(one, batch_cache, solo_cache)

    def _fp_impl(self, cache, slot, plen):
        """Per-layer masked K/V sums over slot row ``slot``'s immutable
        prompt region [0, plen) -> (2L,) f32 fingerprint vector."""
        valid = (jnp.arange(cache["k"].shape[2]) < plen).astype(jnp.float32)
        sums = []
        for name in ("k", "v"):
            row = jax.lax.dynamic_index_in_dim(
                cache[name], slot, axis=1, keepdims=False
            )  # (L, S, g, hd)
            sums.append(jnp.sum(
                row.astype(jnp.float32) * valid[None, :, None, None],
                axis=(1, 2, 3),
            ))
        return jnp.concatenate(sums)

    # ---------------------------------------------------- paged-pool graphs
    def _decode_paged_impl(self, params, cache, tokens, pos, pages):
        """Paged twin of ``_decode_impl``: the (n_slots, n_pg) page table
        routes each row's read gather and token write (models/attention.py
        ``attn_decode_paged``)."""
        logits, cache = decode_step(
            self.cfg, params, cache, tokens, pos,
            pages=pages, page_size=self.page_size,
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _copy_impl(self, cache, src, dst):
        """Copy physical page ``src`` over page ``dst`` on every pool leaf
        — the device half of copy-on-write (traced page ids: one graph
        serves every copy)."""
        def one(leaf):
            if getattr(leaf, "ndim", 0) < 2:
                return leaf
            page = jax.lax.dynamic_index_in_dim(
                leaf, src, axis=1, keepdims=True
            )
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, page, dst, axis=1
            )

        return jax.tree_util.tree_map(one, cache)

    def _fp_paged_impl(self, cache, pid, span):
        """Per-layer masked K/V sums over physical page ``pid``'s prompt
        span [0, span) -> (2L,) f32 fingerprint vector (paged twin of
        ``_fp_impl``; one codeword per page, shared by all its readers)."""
        valid = (jnp.arange(self.page_size) < span).astype(jnp.float32)
        sums = []
        for name in ("k", "v"):
            page = jax.lax.dynamic_index_in_dim(
                cache[name], pid, axis=1, keepdims=False
            )  # (L, page, g, hd)
            sums.append(jnp.sum(
                page.astype(jnp.float32) * valid[None, :, None, None],
                axis=(1, 2, 3),
            ))
        return jnp.concatenate(sums)

    # ---------------------------------------------------- paged host glue
    def _page_codeword(self, pid: int):
        """Freshly recomputed RRNS codeword of page ``pid``'s stored
        prompt span."""
        fp = self._fp_fn(
            self.cache, jnp.int32(pid), jnp.int32(self._page_span[pid])
        )
        return self.codec.encode_array(fp, channel_major=True)

    def _exec_actions(self, actions: list) -> None:
        """Execute a ``PagedScheduler.plan_write`` action list in order:
        evictions verify-and-drop the page's fingerprint (its content is
        still intact at this point), CoW runs the jitted page copy, fresh
        allocs need no device work.  An eviction-verify MISMATCH is cache
        corruption caught at the last possible moment — it is recorded in
        ``verify_log`` under the page's publisher rid (and in the wire
        stats), not just counted."""
        for act in actions:
            if act["op"] == "evict":
                pid = act["pid"]
                if self.rns_verify and pid in self.wire:
                    ok = self.wire.matches(pid, self._page_codeword(pid))
                    pub = self._page_pub.pop(pid, None)
                    if not ok:
                        self.verify_log[pub] = False
                    self.wire.pop(pid)
                    self._page_span.pop(pid, None)
            elif act["op"] == "cow":
                self.cache = self._copy_fn(
                    self.cache, jnp.int32(act["src"]), jnp.int32(act["dst"])
                )

    # ------------------------------------------------------ admission path
    def _rid_held(self, rid) -> bool:
        """Is ``rid``'s verify state still live in EITHER family?  The
        verify log is one rid-keyed dict shared across families, so a
        collision in either lane corrupts attribution for both."""
        held = (
            rid in self.verify_log
            or any(q.rid == rid for q in self.sched.queue)
            or any(s.req is not None and s.req.rid == rid
                   for s in self.sched.slots)
        )
        if not self.paged:
            # monolithic wires are rid-keyed, so the store itself
            # tracks in-flight and retired-undrained rids
            held = held or rid in self.wire
        if self.crypto is not None:
            held = held or (
                any(q.rid == rid for q in self.crypto.queue)
                or any(s.req is not None and s.req.rid == rid
                       for s in self.crypto.slots)
                or ("crypto", rid) in self.wire
            )
        return held

    def submit(self, req) -> None:
        """Queue one request; dispatches on ``req.family`` ("llm" default
        / "crypto" when the crypto lane is armed)."""
        family = getattr(req, "family", "llm")
        if family == "crypto":
            if self.crypto is None:
                raise ValueError(
                    "engine built without crypto_slots=; pass "
                    "crypto_slots>=1 to accept crypto-family requests"
                )
            self.crypto_ctx.validate(req)
        elif family != "llm":
            raise ValueError(f"unknown request family {family!r}; "
                             f"expected 'llm' or 'crypto'")
        if self.rns_verify and self._rid_held(req.rid):
            # verify state is keyed on rid; refuse the collision
            # before any slot is bound or device work runs
            raise ValueError(
                f"rid {req.rid} already holds verify state (queued, in "
                f"flight, or retired-undrained); use unique rids, or "
                f"drain_completed() between reuses"
            )
        if family == "crypto":
            self.crypto.queue.append(req)
        else:
            self.sched.submit(req)

    def try_admit(self, now: float = 0.0) -> list[Slot]:
        """Admit as many queued requests as there are FREE slots; each
        admission chunk-prefills the prompt and splices it into the
        batched cache.  Returns the admitted slots (normally now in
        DECODE; already FREE again if the first token retired the
        request — one-token budget or instant EOS)."""
        admitted = []
        while True:
            slot = self.sched.admit_next(now)
            if slot is None:
                break
            self._prefill_into(slot, now)
            admitted.append(slot)
        if self.crypto is not None:
            self._crypto_admit(now)
        return admitted

    def _prefill_into(self, slot: Slot, now: float) -> None:
        if self.paged:
            return self._prefill_into_paged(slot, now)
        req = slot.req
        prompt = [int(t) for t in req.prompt]
        plen, C = len(prompt), self.prefill_chunk
        solo = self._solo_zero
        bucket = self._pick_bucket(plen)
        if bucket is not None:
            # bucketed path: ONE padded extend call — the graph keys only
            # on the bucket width; pad junk beyond plen-1 is causally
            # invisible (logit_index reads the last real position) and
            # decode writes overwrite it before it can ever be attended
            toks = jnp.asarray(
                [prompt + [0] * (bucket - plen)], jnp.int32
            )
            logits, solo = self._extend_fn(
                self.params, solo, toks, jnp.int32(0), jnp.int32(plen - 1)
            )
            self.bucket_hits[bucket] += 1
            self.bucket_pad_tokens += bucket - plen
            self.bucket_real_tokens += plen
        else:
            n_chunks = -(-plen // C)
            if self.prefill_buckets is not None:
                # fallback traffic stays in the ledger: its chunk-grid
                # pads and real tokens count like a bucket's would, so
                # pad_overhead reflects ALL prefill traffic
                self.bucket_fallbacks += 1
                self.bucket_pad_tokens += n_chunks * C - plen
                self.bucket_real_tokens += plen
            prompt = prompt + [0] * (n_chunks * C - plen)
            last = (plen - 1) - (n_chunks - 1) * C
            for ci in range(n_chunks):
                toks = jnp.asarray(
                    [prompt[ci * C:(ci + 1) * C]], jnp.int32
                )
                # only the final chunk's last REAL prompt position is ever
                # read (chunk padding beyond it is causally invisible
                # below it); the traced index keeps the unembed to one
                # row per call
                idx = last if ci == n_chunks - 1 else 0
                logits, solo = self._extend_fn(
                    self.params, solo, toks, jnp.int32(ci * C),
                    jnp.int32(idx)
                )
        first = int(jnp.argmax(logits[0, 0]))
        self.cache = self._insert_fn(
            self.cache, solo, jnp.int32(slot.index)
        )
        if self.rns_verify:
            fp = self._fp_fn(
                self.cache, jnp.int32(slot.index), jnp.int32(plen)
            )
            self.wire.put(req.rid, self.codec.encode_array(
                fp, channel_major=True
            ))
        if self.sched.start_decode(slot, first, now) and self.rns_verify:
            # instant retirement (one-token budget / immediate EOS) never
            # reaches step()'s retirement branch — verify here instead
            self.verify_log[req.rid] = self.verify_request(req)

    def _prefill_into_paged(self, slot: Slot, now: float) -> None:
        """Paged admission prefill: chunks write straight into the pool
        through the slot's page-table row.  Positions below
        ``slot.prefill_start`` are NOT recomputed — the scheduler mapped
        registry pages holding that shared prefix at admission; each
        chunk's write barrier (``plan_write``) allocates/CoWs the pages
        the chunk lands on before its extend runs.

        With a bucket ladder, a prompt whose remaining extend fits a
        bucket prefills in ONE padded call through the padded write
        barrier (DESIGN.md §13): the real span goes through the normal
        page-table barrier, while every pad token scatters into a
        one-call scratch page taken from the slot's reservation — pad
        K/V never lands in a shared, registered, or retained page, so
        dedup/CoW/fingerprints see exactly the rows the chunk loop
        would have written."""
        req = slot.req
        prompt = [int(t) for t in req.prompt]
        plen, C = len(prompt), self.prefill_chunk
        start = slot.prefill_start
        need = plen - start  # tokens the extend actually computes
        bucket = self.sched.bucket_for(need)
        if bucket is not None:
            self._exec_actions(self.sched.plan_write(slot, start, need))
            scratch, acts = self.sched.alloc_scratch(slot)
            self._exec_actions(acts)
            pages_row = jnp.asarray(
                [self.sched.table[slot.index]], jnp.int32
            )
            toks = jnp.asarray(
                [prompt[start:] + [0] * (bucket - need)], jnp.int32
            )
            logits, self.cache = self._extend_fn(
                self.params, self.cache, toks, jnp.int32(start),
                jnp.int32(need - 1), pages_row, jnp.int32(need),
                jnp.int32(scratch),
            )
            self.sched.free_scratch(scratch)
            self.bucket_hits[bucket] += 1
            self.bucket_pad_tokens += bucket - need
            self.bucket_real_tokens += need
        else:
            n_chunks = -(-need // C)
            if self.prefill_buckets is not None:
                self.bucket_fallbacks += 1
                self.bucket_pad_tokens += n_chunks * C - need
                self.bucket_real_tokens += need
            padded = prompt + [0] * (start + n_chunks * C - plen)
            last = (plen - 1) - (start + (n_chunks - 1) * C)
            for ci in range(n_chunks):
                s0 = start + ci * C
                self._exec_actions(self.sched.plan_write(slot, s0, C))
                pages_row = jnp.asarray(
                    [self.sched.table[slot.index]], jnp.int32
                )
                toks = jnp.asarray([padded[s0:s0 + C]], jnp.int32)
                idx = last if ci == n_chunks - 1 else 0
                # chunk-grid pads keep writing THROUGH the table (their
                # pages are reserved for this slot's decode span anyway):
                # valid = full width, parking page as dead scratch operand
                logits, self.cache = self._extend_fn(
                    self.params, self.cache, toks, jnp.int32(s0),
                    jnp.int32(idx), pages_row, jnp.int32(C), jnp.int32(0),
                )
        first = int(jnp.argmax(logits[0, 0]))
        # publish fully-covered prompt pages for later admissions to share
        self.sched.register_prompt(slot, prompt)
        if self.rns_verify:
            self._fingerprint_prompt_pages(slot, plen)
        if self.sched.start_decode(slot, first, now):
            self._retire_paged(req)

    def _fingerprint_prompt_pages(self, slot: Slot, plen: int) -> None:
        """Encode one RRNS codeword per prompt page of ``slot`` that does
        not already carry one — shared registry pages keep their original
        publisher's codeword (that sharing is the point: one wire entry
        covers every reader)."""
        ps = self.page_size
        for lp, pid in self.sched.slot_pages(slot.index):
            off = lp * ps
            if off >= plen:
                break  # decode-region pages are mutable: never fingerprinted
            if pid in self.wire:
                continue
            self._page_span[pid] = min(ps, plen - off)
            self._page_pub[pid] = slot.req.rid
            self.wire.put(pid, self._page_codeword(pid))

    def _retire_paged(self, req: Request) -> None:
        """Paged retirement: verify the request's prompt-page fingerprints
        while its table row is still mapped, then release the row —
        ``'freed'`` pages drop their codewords (already verified),
        ``'retained'``/``'shared'`` pages keep them for future/current
        readers."""
        if self.rns_verify:
            self.verify_log[req.rid] = self.verify_request(req)
        for pid, disp in self.sched.release_pages(req.slot_index):
            if disp == "freed" and self.rns_verify:
                self.wire.pop(pid)
                self._page_span.pop(pid, None)
                self._page_pub.pop(pid, None)

    # --------------------------------------------------------- crypto lane
    def _crypto_row(self, v):
        return jnp.asarray(np.asarray(v))[None, :]

    def _crypto_admit(self, now: float) -> None:
        """Drain the crypto queue: one-shots (modmul/divmod) execute and
        retire inside this call; modexp binds a FREE lane slot and writes
        its ladder state (publishing the slot fingerprint when
        ``rns_verify`` is armed).  Stops when a modexp finds no free slot
        — FIFO order is preserved within the family."""
        lane, ctx = self.crypto, self.crypto_ctx
        while lane.queue:
            req = lane.queue[0]
            if req.op == "modexp":
                slot = lane.free_slot()
                if slot is None:
                    return
                lane.queue.popleft()
                self._crypto_bind(slot, req, now)
            else:
                lane.queue.popleft()
                req.t_admit = now
                req.result = (self._crypto_divmod(req)
                              if req.op == "divmod"
                              else self._crypto_modmul(req))
                req.t_done = now
                lane.completed.append(req)
                if self.rns_verify:
                    # one-shots hold no resident device state to corrupt;
                    # log them verified so rid accounting stays uniform
                    self.verify_log[req.rid] = True

    def _crypto_bind(self, slot, req, now: float) -> None:
        ctx, row = self.crypto_ctx, self._crypto_row
        from repro.serve.crypto import encode_exponent

        c = ctx.consts_for(req.n)
        a = req.a % req.n
        self.crypto_state = self._crypto_fns["admit"](
            self.crypto_state, jnp.int32(slot.index),
            row(ctx.encode_lo(a)), row(ctx.encode_hi(a)),
            row(c["m2_lo"]), row(c["m2_hi"]),
            row(c["one_lo"]), row(c["one_hi"]),
            row(c["neg"]), row(c["n_lo"]), row(c["n_hi"]),
            row(encode_exponent(ctx, req.b)),
        )
        self.crypto.bind(slot, req, now)
        if self.rns_verify:
            fp = self._crypto_fns["fp"](
                self.crypto_state, jnp.int32(slot.index)
            )
            self.wire.put(("crypto", req.rid), self.codec.encode_array(
                fp, channel_major=True
            ))

    def _crypto_modmul(self, req) -> int:
        ctx, row = self.crypto_ctx, self._crypto_row
        c = ctx.consts_for(req.n)
        a, b = req.a % req.n, req.b % req.n
        out = self._crypto_fns["modmul"](
            row(ctx.encode_lo(a)), row(ctx.encode_hi(a)),
            row(ctx.encode_lo(b)), row(ctx.encode_hi(b)),
            row(c["m2_lo"]), row(c["m2_hi"]),
            row(c["neg"]), row(c["n_hi"]), row(c["n_lo"]),
        )
        return ctx.decode_lo(np.asarray(out)[0])

    def _crypto_divmod(self, req) -> tuple:
        ctx, row = self.crypto_ctx, self._crypto_row
        # Alg.-1 packed layout: base channels + m_a (RRNS contexts just
        # drop their extra m_b channel here — divmod runs on (n+1) rows)
        xp = row(ctx.encode_lo(req.a)[: ctx.n + 1])
        dp = row(ctx.encode_lo(req.b)[: ctx.n + 1])
        q, r = self._crypto_fns["divmod"](xp, dp)
        return (ctx.decode_lo(np.asarray(q)[0]),
                ctx.decode_lo(np.asarray(r)[0]))

    def _crypto_step(self, now: float) -> list:
        """Advance every RUN lane slot ``crypto_chunk`` ladder bits and
        retire the slots whose cursor reaches ``exp_bits``."""
        lane = self.crypto
        running = lane.running_slots()
        if not running:
            return []
        cursors = jnp.asarray([s.cursor for s in lane.slots], jnp.int32)
        active = jnp.asarray(
            [1 if s.state == "RUN" else 0 for s in lane.slots], jnp.int32
        )
        self.crypto_state = self._crypto_fns["step"](
            self.crypto_state, cursors, active
        )
        retired = []
        for slot in running:
            slot.cursor += lane.chunk
            if slot.cursor >= lane.exp_bits:
                retired.append(self._crypto_retire(slot, now))
        return retired

    def _crypto_retire(self, slot, now: float):
        """Exit the Montgomery domain, decode the canonical result to a
        Python int, and verify the slot fingerprint against the wire
        codeword published at admission."""
        req = slot.req
        out = self._crypto_fns["final"](
            self.crypto_state, jnp.int32(slot.index)
        )
        req.result = self.crypto_ctx.decode_lo(np.asarray(out)[0])
        if self.rns_verify:
            self.verify_log[req.rid] = self.verify_request(req)
        return self.crypto.retire(slot, now)

    # --------------------------------------------------------- decode loop
    def step(self, now: float = 0.0) -> list[Request]:
        """One persistent batched decode step over every DECODE slot,
        plus one ``crypto_chunk``-bit ladder advance of the crypto lane
        when it is armed; returns the requests (both families) that
        retired this step."""
        crypto_retired = (
            self._crypto_step(now) if self.crypto is not None else []
        )
        decoding = self.sched.decoding_slots()
        if not decoding:
            return crypto_retired
        if self.paged:
            # write barrier for this step's one-token writes: page-boundary
            # crossings allocate, divergence into a shared page CoWs —
            # all BEFORE the table snapshot rides into the decode graph
            for slot in decoding:
                self._exec_actions(
                    self.sched.plan_write(slot, slot.next_pos, 1)
                )
        toks, poss = self.sched.step_rows()
        step_args = [
            self.params,
            self.cache,
            jnp.asarray(toks, jnp.int32)[:, None],
            jnp.asarray(poss, jnp.int32),
        ]
        if self.paged:
            step_args.append(jnp.asarray(self.sched.table, jnp.int32))
        nxt, self.cache = self._decode_fn(*step_args)
        nxt = np.asarray(nxt)
        retired = []
        for slot in decoding:
            self.sched.advance(slot)
            req = slot.req
            if self.sched.record_token(slot, int(nxt[slot.index]), now):
                retired.append(req)
                if self.paged:
                    self._retire_paged(req)
                elif self.rns_verify:
                    self.verify_log[req.rid] = self.verify_request(req)
        return retired + crypto_retired

    @property
    def busy(self) -> bool:
        """Work anywhere in the engine: LLM queue/slots or crypto lane."""
        return self.sched.busy or (
            self.crypto is not None and self.crypto.busy
        )

    def run_to_completion(self, max_steps: int = 1 << 20) -> list[Request]:
        """Drain queue and slots (all arrivals already submitted)."""
        steps = 0
        while self.busy:
            self.try_admit(float(steps))
            if self.sched.decoding_slots() or (
                self.crypto is not None and self.crypto.running_slots()
            ):
                self.step(float(steps))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serve loop exceeded max_steps")
        if self.crypto is None:
            return self.sched.completed
        return list(self.sched.completed) + list(self.crypto.completed)

    def drain_completed(self) -> list[Request]:
        """Hand back the retired requests and release the engine-held
        state keyed on them (wire buffers, verify entries).  A long-lived
        server calls this after reading each batch of results — without
        it, retired-request state (host Request objects and, under
        ``rns_verify``, one device RnsArray per request) accumulates for
        the engine's lifetime."""
        done, self.sched.completed = self.sched.completed, []
        if self.crypto is not None:
            done = done + self.crypto.completed
            self.crypto.completed = []
        if self.rns_verify:
            for r in done:
                if getattr(r, "family", "llm") == "crypto":
                    self.wire.pop(("crypto", r.rid), None)
                elif not self.paged:
                    # paged wires are page-keyed and already released with
                    # their pages at retirement
                    self.wire.pop(r.rid, None)
                self.verify_log.pop(r.rid, None)
        return done

    def jit_cache_sizes(self) -> dict:
        """Compiled-graph counts per engine function — the no-retrace
        invariant says every value stays 1 for the engine's lifetime
        (with ``prefill_buckets`` armed, ``extend`` instead stays at the
        number of distinct padded widths the warmup compiled: the graph
        keys on token shape, and every width is pre-compiled before
        timed traffic)."""
        sizes = {
            "decode": self._decode_fn._cache_size(),
            "extend": self._extend_fn._cache_size(),
        }
        if self.paged:
            sizes["copy"] = self._copy_fn._cache_size()
        else:
            sizes["insert"] = self._insert_fn._cache_size()
        if self._fp_fn is not None:
            sizes["fingerprint"] = self._fp_fn._cache_size()
        if self.crypto is not None:
            for name in ("admit", "step", "final", "modmul", "divmod"):
                sizes[f"crypto_{name}"] = (
                    self._crypto_fns[name]._cache_size()
                )
            if self.rns_verify:
                sizes["crypto_fingerprint"] = (
                    self._crypto_fns["fp"]._cache_size()
                )
        return sizes

    def _pick_bucket(self, plen: int) -> int | None:
        """Smallest armed bucket >= plen, or None (buckets off / prompt
        longer than every bucket -> chunk-loop fallback)."""
        if self.prefill_buckets is None:
            return None
        for b in self.prefill_buckets:
            if b >= plen:
                return b
        return None

    def bucket_stats(self) -> dict:
        """Bucketed-prefill accounting: hits per width, chunk-loop
        fallbacks, and pad overhead (pad tokens / real tokens) — the
        ``buckets`` block of the offline harness report.  Fallback
        prompts count too (their chunk-grid pads and real tokens), so
        ``pad_overhead`` covers ALL prefill traffic, not only the
        bucketed slice.  On the paged engine "real" means the tokens the
        extend computed — a shared prefix mapped from the registry is
        neither padded nor recomputed, so it appears in neither term."""
        if self.prefill_buckets is None:
            raise RuntimeError("engine built without prefill_buckets=")
        real = self.bucket_real_tokens
        return {
            "widths": list(self.prefill_buckets),
            "hits": {str(b): n for b, n in self.bucket_hits.items()},
            "fallbacks": self.bucket_fallbacks,
            "pad_tokens": self.bucket_pad_tokens,
            "real_tokens": real,
            "pad_overhead": (self.bucket_pad_tokens / real) if real else 0.0,
        }

    def page_stats(self) -> dict:
        """Pool / dedup / CoW counters (paged mode), plus the per-page
        fingerprint verify/repair counters when ``rns_verify`` is armed —
        the ``paging`` block of ``launch/serve.py --report``."""
        if not self.paged:
            raise RuntimeError("engine built without page_size=")
        stats = self.sched.page_stats()
        if self.rns_verify:
            stats["fingerprints"] = dict(self.wire.stats)
        return stats

    # ---------------------------------------------------- warm restart
    def _params_sha(self) -> str:
        import hashlib

        from repro.dist.fault import tree_fingerprints

        fps = tree_fingerprints(self.params)
        joined = "".join(f"{k}={v};" for k, v in sorted(fps.items()))
        return hashlib.sha256(joined.encode()).hexdigest()[:16]

    def _require_warm(self):
        if not (self.paged and self.rns_verify
                and self.sched.registry is not None):
            raise RuntimeError(
                "warm restart needs the paged engine with rns_verify=True "
                "and prefix sharing (the persisted state IS the retained "
                "prefix pages plus their RRNS fingerprints)")

    def _retained_chain(self) -> list[int]:
        """Registered retained pages with live codewords, parents before
        children (restore must adopt in this order)."""
        reg, al = self.sched.registry, self.sched.alloc
        out, queue = [], list(reg.children.get(None, ()))
        while queue:
            pid = queue.pop(0)
            if al.is_retained(pid) and pid in self.wire:
                out.append(pid)
                queue.extend(reg.children.get(pid, ()))
        return out

    def save_warm_state(self, state_dir: str) -> dict:
        """Persist the paged pool for a warm restart (DESIGN.md §14): the
        pooled cache leaves, every retained page's RRNS codeword, and the
        registry chain metadata, written through the RRNS checkpoint
        format (train/checkpointer.write_step_dir) so the saved state is
        itself single-channel self-healing.  Engine must be idle."""
        self._require_warm()
        if self.sched.busy:
            raise RuntimeError("cannot snapshot warm state mid-flight: "
                               "drain the engine first")
        from repro.train import checkpointer as ckpt

        reg = self.sched.registry
        chain = self._retained_chain()
        pages = []
        for pid in chain:
            parent_key, toks = reg.by_pid[pid]
            pages.append({
                "pid": pid,
                "parent": parent_key,
                "toks": [int(t) for t in toks],
                "span": int(self._page_span[pid]),
                "pub": self._page_pub.get(pid),
            })
        tree = {"cache": self.cache}
        if chain:
            tree["wire"] = {str(pid): np.asarray(self.wire.get(pid).residues)
                            for pid in chain}
        extra = {
            "geometry": {"page_size": self.page_size,
                         "n_pages": self.n_pages},
            "params_sha": self._params_sha(),
            "pages": pages,
        }
        ckpt.write_step_dir(state_dir, 0, tree, extra=extra)
        return {"pages_saved": len(pages)}

    def load_warm_state(self, state_dir: str) -> dict:
        """Rehydrate a ``save_warm_state`` snapshot into a FRESH engine:
        restore the pool cache, then revalidate every persisted page —
        codeword self-check (``ok``), RRNS repair on failure, and a
        recomputed-fingerprint match against the restored cache content —
        adopting survivors as retained registry chains and DROPPING
        failures (with their descendants, since children chain through
        the parent's pid).  A restarted server thus re-verifies shared
        prefix pages instead of discarding them.

        Returns the revalidation report; raises FileNotFoundError when
        nothing restorable exists under ``state_dir``."""
        self._require_warm()
        if (self.sched.busy or self.sched.alloc.in_use
                or self.sched.alloc.retained or self.sched.registry.by_pid):
            raise RuntimeError("warm state must load into a fresh engine")
        from repro.train import checkpointer as ckpt

        tree, _, extra, ck_rep = ckpt.restore(state_dir)
        geo = extra["geometry"]
        if (geo["page_size"] != self.page_size
                or geo["n_pages"] != self.n_pages):
            raise ValueError(
                f"warm state geometry {geo} does not match engine "
                f"(page_size={self.page_size}, n_pages={self.n_pages})")
        if extra["params_sha"] != self._params_sha():
            raise ValueError(
                "warm state was saved under different params — its KV "
                "content would be wrong for this model")
        from repro.train.checkpoint import _flatten

        names, leaves, treedef = _flatten(self.cache)
        got, got_leaves, _ = _flatten(tree["cache"])
        if names != got:
            raise ValueError(f"cache tree mismatch: {set(names) ^ set(got)}")
        for n, mine, theirs in zip(names, leaves, got_leaves):
            if mine.shape != theirs.shape or mine.dtype != theirs.dtype:
                raise ValueError(
                    f"cache leaf {n!r}: saved {theirs.shape}/{theirs.dtype}"
                    f" vs engine {mine.shape}/{mine.dtype}")
        cache = jax.tree_util.tree_unflatten(treedef, got_leaves)
        if self.mesh is not None:
            cache = jax.device_put(
                cache, named_shardings(self.cache_pspecs, self.mesh))
        else:
            cache = jax.tree_util.tree_map(jnp.asarray, cache)
        self.cache = cache

        wire_raw = tree.get("wire", {})
        report = {"pages_saved": len(extra["pages"]), "adopted": 0,
                  "repaired_pages": 0, "dropped": 0,
                  "ckpt_repaired_leaves": ck_rep["repaired_leaves"]}
        for entry in extra["pages"]:
            pid, parent = int(entry["pid"]), entry["parent"]
            if parent is not None:
                parent = int(parent)
                if parent not in self.sched.registry.by_pid:
                    report["dropped"] += 1  # parent fell: subtree dies
                    continue
            raw = wire_raw.get(str(pid))
            if raw is None:
                report["dropped"] += 1
                continue
            self.wire.put(pid, self.codec.as_array(
                jnp.asarray(raw, jnp.int32), channel_major=True))
            self._page_span[pid] = int(entry["span"])
            repaired_here = False
            if not self.wire.ok(pid):
                rep = self.wire.repair(pid)
                repaired_here = rep["repaired"] > 0
                if rep["unrecoverable"] or not self.wire.ok(pid):
                    self.wire.pop(pid)
                    self._page_span.pop(pid, None)
                    report["dropped"] += 1
                    continue
            if not self.wire.matches(pid, self._page_codeword(pid)):
                # content/fingerprint disagree: the page is not trustworthy
                self.wire.pop(pid)
                self._page_span.pop(pid, None)
                report["dropped"] += 1
                continue
            self.sched.adopt_page(pid, parent, tuple(entry["toks"]))
            if entry.get("pub") is not None:
                self._page_pub[pid] = entry["pub"]
            report["adopted"] += 1
            report["repaired_pages"] += int(repaired_here)
        return report

    # ------------------------------------------------- RNS integrity path
    def _require_verify(self):
        if not self.rns_verify:
            raise RuntimeError("engine built without rns_verify=True")

    def verify_request(self, req: Request) -> bool:
        """Recompute ``req``'s prompt-region fingerprints and compare
        their RNS encodings bitwise against the stored wire buffers.

        Monolithic: one codeword over the slot row's [0, plen) region,
        keyed by rid.  Paged: one codeword per mapped prompt PAGE of the
        slot's table row (shared pages check against the original
        publisher's codeword — the dedup dataflow of DESIGN.md §13).
        Valid until the row/pages are reused by a later admission; the
        engine calls this automatically at retirement.

        Crypto-family requests verify their lane slot's immutable device
        rows (exponent bits + modulus channel constants) against the
        ``("crypto", rid)`` codeword published at admission."""
        self._require_verify()
        if getattr(req, "family", "llm") == "crypto":
            fp = self._crypto_fns["fp"](
                self.crypto_state, jnp.int32(req.slot_index)
            )
            fresh = self.codec.encode_array(fp, channel_major=True)
            return self.wire.matches(("crypto", req.rid), fresh)
        if self.paged:
            ok = True
            for lp, pid in self.sched.slot_pages(req.slot_index):
                if lp * self.page_size >= len(req.prompt):
                    break  # decode-region pages carry no fingerprints
                if pid in self.wire:
                    ok &= self.wire.matches(pid, self._page_codeword(pid))
            return ok
        fp = self._fp_fn(
            self.cache, jnp.int32(req.slot_index),
            jnp.int32(len(req.prompt)),
        )
        fresh = self.codec.encode_array(fp, channel_major=True)
        return self.wire.matches(req.rid, fresh)

    def wire_ok(self, key) -> bool:
        """Codeword self-consistency of one stored wire buffer (RRNS
        redundant-channel check) — detects corruption of the stored
        fingerprint itself, without touching the cache.  ``key`` is a rid
        on the monolithic path, a physical page id on the paged path."""
        self._require_verify()
        return self.wire.ok(key)

    def repair_wire(self, key) -> dict:
        """Locate-and-correct one stored wire buffer in place via
        ``dist.fault.repair_packed``; returns its report dict.  On the
        paged path a shared page's buffer is repaired ONCE and every
        reader re-verifies against the fixed codeword."""
        self._require_verify()
        return self.wire.repair(key)

    def corrupt_wire(self, key, channel: int = 0, delta: int = 1,
                     index: int = 0) -> None:
        """Fault injection for tests/drivers: modular-bump one residue of
        a stored wire buffer (stays a syntactically valid residue so the
        corruption is only catchable by the redundant channels)."""
        self._require_verify()
        self.wire.corrupt(key, channel=channel, delta=delta, index=index)
