"""Slot scheduler for the continuous-batching serve engine (DESIGN.md §12).

Pure host-side bookkeeping — no JAX here.  The engine (serve/batcher.py)
owns the device arrays; this module owns the request queue and the per-slot
state machine that decides which row of the batched KV cache belongs to
which request at every decode step:

    FREE ──admit_next()──> PREFILL ──start_decode()──> DECODE
      ^                                                   │
      └────────── retirement (EOS / max_new) ─────────────┘

A ``Slot`` is one row of the batched cache (a fixed-capacity sequence of
``cache_len`` KV positions).  Admission binds a queued ``Request`` to a
FREE slot; the engine then chunk-prefills the prompt into that row and
calls ``start_decode`` with the first sampled token.  Every decode step
consumes ``step_rows()`` — the (token, position) vectors the persistent
jitted decode step reads — and feeds each sampled token back through
``record_token``, which retires the slot (back to FREE, ready for reuse)
when the request hits its EOS token or its ``max_new`` budget.

Doctest — a 2-slot admission/retirement trace (the worked example of
DESIGN.md §12)::

    >>> from repro.serve.scheduler import Request, SlotScheduler
    >>> sch = SlotScheduler(n_slots=2, cache_len=16)
    >>> sch.submit(Request(rid=0, prompt=[5, 6, 7], max_new=3))
    >>> sch.submit(Request(rid=1, prompt=[8, 9], max_new=2))
    >>> slot = sch.admit_next()
    >>> slot.index, slot.state
    (0, 'PREFILL')
    >>> sch.admit_next().index                  # second request -> slot 1
    1
    >>> sch.admit_next() is None                # no slots left
    True
    >>> sch.start_decode(slot, first_token=9)   # not yet retired
    False
    >>> slot.state, slot.next_pos, slot.last_token
    ('DECODE', 3, 9)
    >>> sch.start_decode(sch.slots[1], first_token=4)
    False
    >>> sch.step_rows()                         # (tokens, write positions)
    ([9, 4], [3, 2])
    >>> sch.record_token(slot, 11)              # token 2 of 3
    False
    >>> sch.record_token(sch.slots[1], 7)       # rid 1 hits max_new=2
    True
    >>> sch.slots[1].state                      # retired -> reusable
    'FREE'
    >>> sch.step_rows()                         # freed row parks at S-1
    ([11, 0], [3, 15])
    >>> sch.record_token(slot, 12)              # rid 0 hits max_new=3
    True
    >>> sorted((r.rid, r.out) for r in sch.completed)
    [(0, [9, 11, 12]), (1, [4, 7])]
"""
from __future__ import annotations

import dataclasses
from collections import deque

__all__ = ["FREE", "PREFILL", "DECODE", "Request", "Slot", "SlotScheduler"]

FREE = "FREE"
PREFILL = "PREFILL"
DECODE = "DECODE"


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-filled result/latency fields.

    ``arrival`` and the ``t_*`` stamps are in the caller's clock (the serve
    driver uses decode-step ticks so reports are deterministic; wall time
    is recorded separately).
    """

    rid: int
    prompt: list
    max_new: int
    eos: int | None = None
    arrival: float = 0.0
    # engine-filled:
    out: list = dataclasses.field(default_factory=list)
    slot_index: int | None = None
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


@dataclasses.dataclass
class Slot:
    """One row of the batched KV cache: state + decode cursor.

    ``next_pos`` is the cache position the NEXT decode step writes (the
    position of ``last_token``, which has been sampled but not yet run
    through the model).  The fields of an idle slot reset to (0, 0), but
    the device view (``step_rows``) parks idle rows at position
    ``cache_len - 1`` — the one position real traffic never writes — so
    their junk KV writes stay outside every read or fingerprinted span.
    """

    index: int
    state: str = FREE
    req: Request | None = None
    next_pos: int = 0
    last_token: int = 0


class SlotScheduler:
    """Admission/retirement over a fixed pool of ``n_slots`` cache rows."""

    def __init__(self, n_slots: int, cache_len: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.slots = [Slot(index=i) for i in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []

    # ------------------------------------------------------------ queries
    @property
    def pending(self) -> int:
        """Queued requests not yet admitted."""
        return len(self.queue)

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state == FREE]

    def decoding_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state == DECODE]

    @property
    def busy(self) -> bool:
        """True while any request is queued or in flight."""
        return bool(self.queue) or any(s.state != FREE for s in self.slots)

    # -------------------------------------------------------- transitions
    def submit(self, req: Request) -> None:
        """Queue a request (FIFO).  Capacity is checked here so a prompt
        that can never fit fails at submit time, not mid-stream."""
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        need = len(req.prompt) + req.max_new
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {need} exceeds the "
                f"slot capacity cache_len = {self.cache_len}"
            )
        self.queue.append(req)

    def admit_next(self, now: float = 0.0) -> Slot | None:
        """Bind the oldest queued request to a FREE slot (FREE -> PREFILL);
        None when the queue is empty or every slot is occupied."""
        free = self.free_slots()
        if not free or not self.queue:
            return None
        slot, req = free[0], self.queue.popleft()
        slot.state, slot.req = PREFILL, req
        slot.next_pos, slot.last_token = 0, 0
        req.slot_index, req.t_admit = slot.index, now
        return slot

    def start_decode(self, slot: Slot, first_token: int,
                     now: float = 0.0) -> bool:
        """PREFILL -> DECODE once the prompt is in the cache row and the
        first token has been sampled from the last prompt position's
        logits.  Returns True if the request retired immediately (one-token
        budget or instant EOS)."""
        assert slot.state == PREFILL, slot.state
        slot.state = DECODE
        slot.next_pos = len(slot.req.prompt)
        slot.last_token = int(first_token)
        return self.record_token(slot, first_token, now)

    def record_token(self, slot: Slot, token: int, now: float = 0.0) -> bool:
        """Append a sampled token to the slot's request; retire the slot
        (DECODE -> FREE) and return True on EOS or exhausted ``max_new``."""
        assert slot.state == DECODE, slot.state
        req = slot.req
        req.out.append(int(token))
        if req.t_first is None:
            req.t_first = now
        slot.last_token = int(token)
        if len(req.out) >= req.max_new or (
            req.eos is not None and int(token) == req.eos
        ):
            req.t_done = now
            self.completed.append(req)
            slot.state, slot.req = FREE, None
            slot.next_pos, slot.last_token = 0, 0
            return True
        return False

    # ------------------------------------------------------- device views
    def step_rows(self) -> tuple[list, list]:
        """The (tokens, positions) rows one persistent decode step reads:
        DECODE slots contribute (last_token, next_pos); FREE/PREFILL rows
        park at (0, cache_len - 1).  The parking position is the one row
        position NO request ever writes — real traffic stops at position
        len(prompt) + max_new - 2 <= cache_len - 2 (the final sampled
        token is never written back) — so idle junk never lands inside a
        region anyone reads or fingerprints (DESIGN.md §12)."""
        park = self.cache_len - 1
        toks = [s.last_token if s.state == DECODE else 0 for s in self.slots]
        poss = [s.next_pos if s.state == DECODE else park
                for s in self.slots]
        return toks, poss

    def advance(self, slot: Slot) -> None:
        """Move a DECODE slot's write cursor past the token the decode step
        just committed to the cache."""
        assert slot.state == DECODE, slot.state
        slot.next_pos += 1
