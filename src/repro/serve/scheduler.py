"""Slot scheduler for the continuous-batching serve engine (DESIGN.md §12)
and the paged-pool extension on top of it (DESIGN.md §13).

Pure host-side bookkeeping — no JAX here.  The engine (serve/batcher.py)
owns the device arrays; this module owns the request queue and the per-slot
state machine that decides which row of the batched KV cache belongs to
which request at every decode step:

    FREE ──admit_next()──> PREFILL ──start_decode()──> DECODE
      ^                                                   │
      └────────── retirement (EOS / max_new) ─────────────┘

A ``Slot`` is one row of the batched cache (a fixed-capacity sequence of
``cache_len`` KV positions).  Admission binds a queued ``Request`` to a
FREE slot; the engine then chunk-prefills the prompt into that row and
calls ``start_decode`` with the first sampled token.  Every decode step
consumes ``step_rows()`` — the (token, position) vectors the persistent
jitted decode step reads — and feeds each sampled token back through
``record_token``, which retires the slot (back to FREE, ready for reuse)
when the request hits its EOS token or its ``max_new`` budget.

Doctest — a 2-slot admission/retirement trace (the worked example of
DESIGN.md §12)::

    >>> from repro.serve.scheduler import Request, SlotScheduler
    >>> sch = SlotScheduler(n_slots=2, cache_len=16)
    >>> sch.submit(Request(rid=0, prompt=[5, 6, 7], max_new=3))
    >>> sch.submit(Request(rid=1, prompt=[8, 9], max_new=2))
    >>> slot = sch.admit_next()
    >>> slot.index, slot.state
    (0, 'PREFILL')
    >>> sch.admit_next().index                  # second request -> slot 1
    1
    >>> sch.admit_next() is None                # no slots left
    True
    >>> sch.start_decode(slot, first_token=9)   # not yet retired
    False
    >>> slot.state, slot.next_pos, slot.last_token
    ('DECODE', 3, 9)
    >>> sch.start_decode(sch.slots[1], first_token=4)
    False
    >>> sch.step_rows()                         # (tokens, write positions)
    ([9, 4], [3, 2])
    >>> sch.record_token(slot, 11)              # token 2 of 3
    False
    >>> sch.record_token(sch.slots[1], 7)       # rid 1 hits max_new=2
    True
    >>> sch.slots[1].state                      # retired -> reusable
    'FREE'
    >>> sch.step_rows()                         # freed row parks at S-1
    ([11, 0], [3, 15])
    >>> sch.record_token(slot, 12)              # rid 0 hits max_new=3
    True
    >>> sorted((r.rid, r.out) for r in sch.completed)
    [(0, [9, 11, 12]), (1, [4, 7])]
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

__all__ = [
    "FREE", "PREFILL", "DECODE", "Request", "Slot", "SlotScheduler",
    "PageAllocator", "PrefixRegistry", "PagedScheduler",
]

FREE = "FREE"
PREFILL = "PREFILL"
DECODE = "DECODE"


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-filled result/latency fields.

    ``arrival`` and the ``t_*`` stamps are in the caller's clock (the serve
    driver uses decode-step ticks so reports are deterministic; wall time
    is recorded separately).
    """

    rid: int
    prompt: list
    max_new: int
    eos: int | None = None
    arrival: float = 0.0
    family: str = "llm"      # engine dispatch tag; crypto requests carry
    #                          "crypto" (serve/crypto.py CryptoRequest)
    # engine-filled:
    out: list = dataclasses.field(default_factory=list)
    slot_index: int | None = None
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


@dataclasses.dataclass
class Slot:
    """One row of the batched KV cache: state + decode cursor.

    ``next_pos`` is the cache position the NEXT decode step writes (the
    position of ``last_token``, which has been sampled but not yet run
    through the model).  The fields of an idle slot reset to (0, 0), but
    the device view (``step_rows``) parks idle rows at position
    ``cache_len - 1`` — the one position real traffic never writes — so
    their junk KV writes stay outside every read or fingerprinted span.
    """

    index: int
    state: str = FREE
    req: Request | None = None
    next_pos: int = 0
    last_token: int = 0
    # paged-pool extension (PagedScheduler; always 0 on the monolithic
    # path): first position the admission prefill actually computes (below
    # it the row reads shared prefix pages) and the not-yet-consumed page
    # reservation backing this request's future writes.
    prefill_start: int = 0
    reserved_left: int = 0


class SlotScheduler:
    """Admission/retirement over a fixed pool of ``n_slots`` cache rows."""

    def __init__(self, n_slots: int, cache_len: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.slots = [Slot(index=i) for i in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.completed: list[Request] = []

    # ------------------------------------------------------------ queries
    @property
    def pending(self) -> int:
        """Queued requests not yet admitted."""
        return len(self.queue)

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state == FREE]

    def decoding_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.state == DECODE]

    @property
    def busy(self) -> bool:
        """True while any request is queued or in flight."""
        return bool(self.queue) or any(s.state != FREE for s in self.slots)

    # -------------------------------------------------------- transitions
    def submit(self, req: Request) -> None:
        """Queue a request (FIFO).  Capacity is checked here so a prompt
        that can never fit fails at submit time, not mid-stream."""
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        need = len(req.prompt) + req.max_new
        if need > self.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {need} exceeds the "
                f"slot capacity cache_len = {self.cache_len}"
            )
        self.queue.append(req)

    def admit_next(self, now: float = 0.0) -> Slot | None:
        """Bind the oldest queued request to a FREE slot (FREE -> PREFILL);
        None when the queue is empty or every slot is occupied."""
        free = self.free_slots()
        if not free or not self.queue:
            return None
        slot, req = free[0], self.queue.popleft()
        slot.state, slot.req = PREFILL, req
        slot.next_pos, slot.last_token = 0, 0
        req.slot_index, req.t_admit = slot.index, now
        return slot

    def start_decode(self, slot: Slot, first_token: int,
                     now: float = 0.0) -> bool:
        """PREFILL -> DECODE once the prompt is in the cache row and the
        first token has been sampled from the last prompt position's
        logits.  Returns True if the request retired immediately (one-token
        budget or instant EOS)."""
        assert slot.state == PREFILL, slot.state
        slot.state = DECODE
        slot.next_pos = len(slot.req.prompt)
        slot.last_token = int(first_token)
        return self.record_token(slot, first_token, now)

    def record_token(self, slot: Slot, token: int, now: float = 0.0) -> bool:
        """Append a sampled token to the slot's request; retire the slot
        (DECODE -> FREE) and return True on EOS or exhausted ``max_new``."""
        assert slot.state == DECODE, slot.state
        req = slot.req
        req.out.append(int(token))
        if req.t_first is None:
            req.t_first = now
        slot.last_token = int(token)
        if len(req.out) >= req.max_new or (
            req.eos is not None and int(token) == req.eos
        ):
            req.t_done = now
            self.completed.append(req)
            slot.state, slot.req = FREE, None
            slot.next_pos, slot.last_token = 0, 0
            return True
        return False

    # ------------------------------------------------------- device views
    def step_rows(self) -> tuple[list, list]:
        """The (tokens, positions) rows one persistent decode step reads:
        DECODE slots contribute (last_token, next_pos); FREE/PREFILL rows
        park at (0, cache_len - 1).  The parking position is the one row
        position NO request ever writes — real traffic stops at position
        len(prompt) + max_new - 2 <= cache_len - 2 (the final sampled
        token is never written back) — so idle junk never lands inside a
        region anyone reads or fingerprints (DESIGN.md §12)."""
        park = self.cache_len - 1
        toks = [s.last_token if s.state == DECODE else 0 for s in self.slots]
        poss = [s.next_pos if s.state == DECODE else park
                for s in self.slots]
        return toks, poss

    def advance(self, slot: Slot) -> None:
        """Move a DECODE slot's write cursor past the token the decode step
        just committed to the cache."""
        assert slot.state == DECODE, slot.state
        slot.next_pos += 1


# ======================================================================
# Paged pool (DESIGN.md §13): allocator, prefix registry, paged scheduler
# ======================================================================
class PageAllocator:
    """Physical-page pool bookkeeping: free list, refcounts, reservations,
    and an LRU set of RETAINED pages (refcount 0 but still holding a
    registered, shareable prefix — evicted only under pressure).

    Page 0 is the PARKING page: every unmapped page-table entry points at
    it, idle decode rows scatter their junk into it, and it is never
    allocated — so pool traffic can never corrupt a mapped page.

    Page lifecycle::

        FREE ──alloc()──> ACTIVE (refcount >= 1) ──deref() to 0──┐
          ^                      ^                               │
          │                      └──── ref() revival ──── RETAINED (LRU)
          └───── deref(retain=False) ────┘      alloc() eviction ──> ACTIVE

    >>> al = PageAllocator(5)
    >>> al.alloc(), al.alloc()          # lowest free pids first, no evict
    ((1, False), (2, False))
    >>> al.ref(1); al.deref(1, retain=True)   # still shared
    'shared'
    >>> al.deref(1, retain=True)        # refcount 0 + registered -> LRU
    'retained'
    >>> al.deref(2, retain=False)
    'freed'
    >>> [al.alloc() for _ in range(2)]  # free pids 2,3 before evicting 1
    [(2, False), (3, False)]
    >>> al.alloc()
    (4, False)
    >>> al.alloc()                      # pool dry: evict LRU-retained 1
    (1, True)
    >>> al.in_use                       # all 4 non-parking pages live
    4
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("page pool needs >= 2 pages (one is parking)")
        self.n_pages = n_pages
        self.free: list[int] = list(range(n_pages - 1, 0, -1))  # pop -> 1
        self.refcount = [0] * n_pages
        self.retained: OrderedDict[int, None] = OrderedDict()  # LRU order
        self.reserved = 0
        self.stats = {"allocated": 0, "freed": 0, "evicted": 0,
                      "peak_in_use": 0}

    # ------------------------------------------------------------ queries
    @property
    def in_use(self) -> int:
        """Pages holding live (refcounted) data."""
        return self.n_pages - 1 - len(self.free) - len(self.retained)

    @property
    def available(self) -> int:
        """Pages an alloc() could hand out: free + evictable-retained."""
        return len(self.free) + len(self.retained)

    def is_retained(self, pid: int) -> bool:
        return pid in self.retained

    def can_reserve(self, n: int) -> bool:
        return n <= self.available - self.reserved

    def reserve(self, n: int) -> None:
        self.reserved += n

    def unreserve(self, n: int) -> None:
        self.reserved -= n
        assert self.reserved >= 0, "reservation underflow"

    # -------------------------------------------------------- transitions
    def alloc(self) -> tuple[int, bool]:
        """One exclusively-owned page: ``(pid, evicted)``.  Prefers the
        free list; under pressure evicts the LRU retained page (the caller
        must then drop that page's registry/fingerprint state — its
        CONTENT stays intact until the next device write to it)."""
        if self.free:
            pid, evicted = self.free.pop(), False
        elif self.retained:
            pid, _ = self.retained.popitem(last=False)
            evicted = True
            self.stats["evicted"] += 1
        else:
            raise RuntimeError(
                "page pool exhausted despite reservation gating (bug)"
            )
        self.refcount[pid] = 1
        self.stats["allocated"] += 1
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                        self.in_use)
        return pid, evicted

    def ref(self, pid: int) -> None:
        """Add a reader.  Reviving a retained page pulls it back out of
        the evictable set (its registry entry never went away)."""
        if pid in self.retained:
            del self.retained[pid]
            self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                            self.in_use + 1)
        self.refcount[pid] += 1

    def adopt_retained(self, pid: int) -> None:
        """Warm-restart seeding (DESIGN.md §14): move a FREE page straight
        into the retained LRU set, as if a previous process had published
        and released it.  Only legal on a pristine pool — the caller
        (batcher.load_warm_state) restores page CONTENT separately.

        >>> al = PageAllocator(4)
        >>> al.adopt_retained(2); al.is_retained(2), al.available
        (True, 3)
        >>> al.alloc(), al.alloc(), al.alloc()   # 2 evicts last, LRU order
        ((1, False), (3, False), (2, True))
        """
        if self.refcount[pid] != 0 or pid not in self.free:
            raise ValueError(f"page {pid} is not free — cannot adopt")
        self.free.remove(pid)
        self.retained[pid] = None

    def deref(self, pid: int, *, retain: bool) -> str:
        """Drop a reader; returns the page's disposition — ``'shared'``
        (readers remain), ``'retained'`` (refcount 0 but registered: parked
        in the LRU evictable set, content + fingerprint still live), or
        ``'freed'`` (returned to the free list; content is dead)."""
        assert self.refcount[pid] > 0, f"deref of unreferenced page {pid}"
        self.refcount[pid] -= 1
        if self.refcount[pid] > 0:
            return "shared"
        if retain:
            self.retained[pid] = None
            return "retained"
        self.free.append(pid)
        self.stats["freed"] += 1
        return "freed"


class PrefixRegistry:
    """Content-addressed chains of immutable, fully-prompt-covered pages.

    A node maps ``(parent_pid | None, page_tokens)`` to the physical page
    holding that page of KV — so a chain walk from the root deduplicates
    any shared prompt PREFIX, not just exact prompt matches.  Only pages
    fully covered by a prompt are ever registered (partial tail pages keep
    getting decode writes and stay private), which is what makes
    registered pages immutable and safe to share.

    Dropping an evicted page takes its ENTIRE descendant subtree with it:
    child keys name the parent's physical pid, so if an orphaned chain
    survived and that pid were later re-allocated and re-registered for
    different content, ``match`` would walk straight through the reused
    pid into the stale chain and hand out pages whose KV was computed
    under a different prefix.  Subtree-dropped descendants keep their
    pool/fingerprint state (they stay in the allocator's retained set
    until evicted through the normal verify path) — only their
    reachability dies here.

    >>> reg = PrefixRegistry(page_size=2)
    >>> reg.add(None, (5, 6), pid=3); reg.add(3, (7, 8), pid=4)
    >>> reg.match([5, 6, 7, 8, 9])       # walks the chain, full pages only
    [3, 4]
    >>> reg.match([5, 6, 1, 2])          # diverges after one page
    [3]
    >>> reg.drop(3); reg.match([5, 6, 7, 8])   # parent evicted: no match
    []
    >>> 4 in reg.by_pid                  # descendant chain died with it
    False
    >>> reg.add(None, (9, 9), pid=3)     # pid 3 reused for NEW content
    >>> reg.match([9, 9, 7, 8])          # cannot resurrect the old chain
    [3]
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.nodes: dict[tuple, int] = {}
        self.by_pid: dict[int, tuple] = {}
        self.children: dict[int | None, set[int]] = {}

    def match(self, prompt: list) -> list[int]:
        """Physical pages of the longest registered chain covering the
        leading FULL pages of ``prompt`` (order = logical page order)."""
        out: list[int] = []
        key = None
        ps = self.page_size
        for j in range(len(prompt) // ps):
            toks = tuple(prompt[j * ps:(j + 1) * ps])
            pid = self.nodes.get((key, toks))
            if pid is None:
                break
            out.append(pid)
            key = pid
        return out

    def add(self, parent_key, toks: tuple, pid: int) -> None:
        self.nodes[(parent_key, toks)] = pid
        self.by_pid[pid] = (parent_key, toks)
        self.children.setdefault(parent_key, set()).add(pid)

    def drop(self, pid: int) -> None:
        """Unregister ``pid`` AND its whole descendant subtree (children
        are keyed by the raw parent pid, which the pool may reuse)."""
        stack = [pid]
        while stack:
            p = stack.pop()
            node_key = self.by_pid.pop(p, None)
            if node_key is None:
                continue
            self.nodes.pop(node_key, None)
            siblings = self.children.get(node_key[0])
            if siblings is not None:
                siblings.discard(p)
                if not siblings:
                    del self.children[node_key[0]]
            stack.extend(self.children.get(p, ()))


class PagedScheduler(SlotScheduler):
    """Slot scheduler over a PAGED physical pool (DESIGN.md §13).

    Extends the FREE/PREFILL/DECODE machine with the page-table layer: a
    host-side ``(n_slots, n_pg)`` int32 table maps each slot's logical
    pages to physical pages of the pooled cache buffer, and admission
    deduplicates shared prompt prefixes through ``PrefixRegistry`` —
    shared pages are refcounted read-only; the first write into one
    (divergence mid-page) triggers a copy-on-write.

    Division of labor with the engine: THIS class owns every host decision
    (which pages back which positions, when to copy, evict, or free) and
    reports device work as action dicts; serve/batcher.py executes them
    (page copies, fingerprint verification) and owns all device arrays.

    Admission gating is a capacity check in PAGES, not slots: a request
    reserves its worst-case exclusive page count up front and stays queued
    while the pool can't cover it, so max in-flight requests is bounded by
    the page pool even with free slot rows available.
    """

    def __init__(self, n_slots: int, cache_len: int, *, page_size: int,
                 n_pages: int, prefill_chunk: int,
                 prefix_share: bool = True, prefill_buckets=None):
        super().__init__(n_slots, cache_len)
        assert cache_len % page_size == 0
        self.page_size = page_size
        self.n_pg = cache_len // page_size
        self.prefill_chunk = prefill_chunk
        # the engine's (validated, sorted) bucket ladder, or None: the
        # scheduler must reserve by the SAME bucketed-vs-chunk rule the
        # engine dispatches by, or admission gating and the write barrier
        # disagree about the scratch page
        self.prefill_buckets = (tuple(prefill_buckets)
                                if prefill_buckets else None)
        # numpy-free on purpose: plain host ints; the engine snapshots the
        # table into a device array each step (data, never a trace const)
        self.table = [[0] * self.n_pg for _ in range(n_slots)]
        self.alloc = PageAllocator(n_pages)
        self.registry = PrefixRegistry(page_size) if prefix_share else None
        self.stats = {"dedup_hits": 0, "cow_copies": 0, "deferrals": 0}

    # ------------------------------------------------------------ queries
    def slot_pages(self, slot_index: int) -> list[tuple[int, int]]:
        """Mapped (logical_page, physical_page) pairs of one slot row."""
        return [(lp, pid) for lp, pid in enumerate(self.table[slot_index])
                if pid != 0]

    # ---------------------------------------------------------- admission
    def bucket_for(self, n: int):
        """Smallest configured bucket covering an ``n``-token extend, or
        None (no ladder / over-bucket fallback to the chunk loop)."""
        if self.prefill_buckets is None:
            return None
        for b in self.prefill_buckets:
            if b >= n:
                return b
        return None

    def _plan_admission(self, prompt: list, max_new: int):
        """Pure planning for the queue head: (pages to map from the
        registry, first position prefill must compute, worst-case pages to
        reserve).  ``prefill_start`` is chunk-aligned and always leaves at
        least the last prompt position to recompute, so first-token logits
        exist even on a full-prefix hit.

        Reservation sizing is path-dependent (DESIGN.md §13): the chunk
        loop writes its chunk-grid pads THROUGH the table, so it reserves
        up to ``pad_end``; the bucketed path routes every pad into a
        scratch page instead, so it reserves only the real span PLUS one
        page for the scratch itself."""
        ps, C = self.page_size, self.prefill_chunk
        plen = len(prompt)
        matched = self.registry.match(prompt) if self.registry else []
        shared_cap = min(len(matched) * ps, plen - 1)
        prefill_start = (shared_cap // C) * C
        # pages that provide content below prefill_start are worth mapping;
        # anything fully recomputed is cheaper to fill fresh than to copy
        m_map = min(len(matched), -(-prefill_start // ps))
        if self.bucket_for(plen - prefill_start) is not None:
            span_end = plen + max_new - 1
            n_reserve = -(-span_end // ps) - prefill_start // ps + 1
        else:
            pad_end = prefill_start + -(-(plen - prefill_start) // C) * C
            span_end = max(plen + max_new - 1, pad_end)
            n_reserve = -(-span_end // ps) - prefill_start // ps
        return matched[:m_map], prefill_start, n_reserve

    def admit_next(self, now: float = 0.0) -> Slot | None:
        """Like ``SlotScheduler.admit_next`` plus page planning: map the
        registered shared prefix into the slot's table row (refcounted)
        and reserve the worst-case exclusive pages.  A request whose
        reservation the pool can't cover DEFERS (stays at the queue head)
        even when slot rows are free — capacity is pages, not slots."""
        free = self.free_slots()
        if not free or not self.queue:
            return None
        req = self.queue[0]
        prompt = [int(t) for t in req.prompt]
        mapped, prefill_start, n_reserve = self._plan_admission(
            prompt, req.max_new
        )
        # revived retained pages leave the evictable set, so they need
        # headroom on top of the reservation itself
        n_revive = sum(1 for pid in mapped if self.alloc.is_retained(pid))
        if not self.alloc.can_reserve(n_reserve + n_revive):
            self.stats["deferrals"] += 1
            return None
        self.queue.popleft()
        slot = free[0]
        slot.state, slot.req = PREFILL, req
        slot.next_pos, slot.last_token = 0, 0
        slot.prefill_start, slot.reserved_left = prefill_start, n_reserve
        req.slot_index, req.t_admit = slot.index, now
        for j, pid in enumerate(mapped):
            self.alloc.ref(pid)
            self.table[slot.index][j] = pid
            self.stats["dedup_hits"] += 1
        self.alloc.reserve(n_reserve)
        return slot

    # ------------------------------------------------------ write barrier
    def _alloc_for(self, slot: Slot, actions: list) -> int:
        if slot.reserved_left <= 0:
            raise RuntimeError(
                f"slot {slot.index}: write past its page reservation "
                f"(engine bug)"
            )
        pid, evicted = self.alloc.alloc()
        if evicted:
            # a retained shareable page got recycled: its registry entry
            # AND its descendant chain die now (the reused pid must never
            # resurrect them); the engine verifies + drops its fingerprint
            # when it executes this action (content is still intact)
            if self.registry is not None:
                self.registry.drop(pid)
            actions.append({"op": "evict", "pid": pid})
        slot.reserved_left -= 1
        self.alloc.unreserve(1)
        return pid

    def plan_write(self, slot: Slot, start: int, n: int) -> list[dict]:
        """Host write barrier: make logical positions [start, start+n) of
        ``slot`` writable — every touched page mapped, exclusively owned,
        and unregistered.  Returns the device actions the engine must
        execute IN ORDER before the write lands:

          {"op": "evict", "pid": p}              verify+drop p's fingerprint
          {"op": "cow", "lp": l, "src": s, "dst": d}   copy page s -> d
          {"op": "alloc", "lp": l, "pid": p}     informational (fresh page)

        Copy-on-write fires when a to-be-written page is shared (refcount
        > 1) OR registered (immutable while shareable, even at refcount 1
        — a later admission may still match it)."""
        actions: list[dict] = []
        ps = self.page_size
        row = self.table[slot.index]
        for lp in range(start // ps, (start + n - 1) // ps + 1):
            pid = row[lp]
            if pid == 0:
                new = self._alloc_for(slot, actions)
                row[lp] = new
                actions.append({"op": "alloc", "lp": lp, "pid": new})
                continue
            registered = (self.registry is not None
                          and pid in self.registry.by_pid)
            if self.alloc.refcount[pid] > 1 or registered:
                new = self._alloc_for(slot, actions)  # src is refd: safe
                row[lp] = new
                self.alloc.deref(pid, retain=registered)
                actions.append({"op": "cow", "lp": lp, "src": pid,
                                "dst": new})
                self.stats["cow_copies"] += 1
        return actions

    def alloc_scratch(self, slot: Slot) -> tuple[int, list[dict]]:
        """Take one page from ``slot``'s reservation as the pad sink for a
        bucketed prefill call.  The scratch page is NEVER entered in the
        table, never registered, and never fingerprinted — it exists only
        so the padded write barrier has a physical page to absorb pad
        scatters (DESIGN.md §13).  May evict a retained page (the returned
        actions must be executed before the extend call).  The caller MUST
        ``free_scratch`` it right after the extend lands."""
        actions: list[dict] = []
        pid = self._alloc_for(slot, actions)
        return pid, actions

    def free_scratch(self, pid: int) -> None:
        """Return a scratch page to the free list.  Its pad content is
        garbage by construction; it must not be retained (a retained page
        is shareable, and scratch content must never become shareable)."""
        self.alloc.deref(pid, retain=False)

    # ------------------------------------------------------- registration
    def register_prompt(self, slot: Slot, prompt: list) -> None:
        """After prefill: publish the slot's fully-prompt-covered pages as
        registry chain nodes so later admissions can share them.  Pages
        whose content already has a registered twin (this slot recomputed
        a known prefix) are skipped — first publisher wins."""
        if self.registry is None:
            return
        ps = self.page_size
        row = self.table[slot.index]
        key = None
        for j in range(len(prompt) // ps):
            toks = tuple(int(t) for t in prompt[j * ps:(j + 1) * ps])
            hit = self.registry.nodes.get((key, toks))
            if hit is not None:
                key = hit
                continue
            pid = row[j]
            if self.alloc.refcount[pid] != 1 or pid in self.registry.by_pid:
                break  # not exclusively ours to publish — stop the chain
            self.registry.add(key, toks, pid)
            key = pid

    # -------------------------------------------------------- retirement
    def release_pages(self, slot_index: int) -> list[tuple[int, str]]:
        """Page-granular free at retirement: deref every mapped page of
        the slot row and zero the row (back to parking).  Returns the
        (pid, disposition) transitions — ``'freed'`` pages are dead (the
        engine verifies + drops their fingerprints), ``'retained'`` pages
        stay shareable/evictable with live fingerprints, ``'shared'``
        pages still have readers.  The unused tail of the request's page
        reservation returns to the pool here too (early EOS)."""
        slot = self.slots[slot_index]
        self.alloc.unreserve(slot.reserved_left)
        slot.reserved_left, slot.prefill_start = 0, 0
        out = []
        row = self.table[slot_index]
        for lp in range(self.n_pg):
            pid = row[lp]
            if pid == 0:
                continue
            row[lp] = 0
            retain = (self.registry is not None
                      and pid in self.registry.by_pid)
            out.append((pid, self.alloc.deref(pid, retain=retain)))
        return out

    # ------------------------------------------------------ warm restart
    def adopt_page(self, pid: int, parent_key, toks: tuple) -> None:
        """Seed one revalidated page from a previous process's warm state:
        pool side becomes retained (evictable LRU), registry side becomes
        a chain node under ``parent_key`` — exactly the state the page
        held when the old process released it.  Parents must be adopted
        before children (chain keys name the parent's physical pid)."""
        if self.registry is None:
            raise RuntimeError("prefix sharing disabled: nothing to adopt")
        if parent_key is not None and parent_key not in self.registry.by_pid:
            raise ValueError(
                f"page {pid}: parent {parent_key} not adopted — restore "
                f"chains parents-first")
        self.alloc.adopt_retained(pid)
        self.registry.add(parent_key, tuple(toks), pid)

    # ------------------------------------------------------------- stats
    def page_stats(self) -> dict:
        """Pool/dedup counters for reports (launch/serve.py --report)."""
        return {
            "page_size": self.page_size,
            "n_pages": self.alloc.n_pages,
            "pages_in_use": self.alloc.in_use,
            "pages_retained": len(self.alloc.retained),
            "pages_in_use_peak": self.alloc.stats["peak_in_use"],
            "pages_allocated": self.alloc.stats["allocated"],
            "pages_freed": self.alloc.stats["freed"],
            "pages_evicted": self.alloc.stats["evicted"],
            "dedup_hits": self.stats["dedup_hits"],
            "cow_copies": self.stats["cow_copies"],
            "deferrals": self.stats["deferrals"],
        }
