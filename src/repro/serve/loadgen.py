# Copyright 2026 the repro authors
#
# Closed-loop load generation for the offline harness (DESIGN.md §16).
#
# ``OfflineInference.run`` measures one workload; this module drives it
# to SATURATION: offered load is open-loop (Poisson arrivals at a target
# QPS — requests arrive whether or not the system keeps up), admission
# is closed-loop (the engine's slots apply backpressure through the
# shared admission queue), and ``search_max_qps`` binary-searches the
# highest offered rate whose measured phase still meets the SLO.
#
# The SLO combines tail latency (TTFT p99 + end-to-end p99, both in
# wall seconds off the request stamps) with a saturation wall check:
# a phase that keeps up finishes within its arrival span plus one
# latency budget of drain tail; a saturated phase's backlog pushes the
# wall far past that.  The check is tail-COMPENSATED (the allowance
# includes the latency budget) so small phases are not biased toward
# failure by their fixed drain tail.

from __future__ import annotations

import dataclasses

from repro.serve.scheduler import Request

__all__ = ["SLO", "phase_stats", "poisson_requests", "search_max_qps"]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Pass/fail contract one measured phase is held to."""

    ttft_p99_s: float = 2.0
    latency_p99_s: float = 10.0
    # fraction of the ideal completion rate the phase must sustain: the
    # measured wall may not exceed (arrival span + one latency budget) /
    # this ratio — backlog growth, not per-request latency, is the
    # first symptom of overload and it lands squarely on the wall
    min_sustained_ratio: float = 0.95

    def check(self, phase: dict) -> list[str]:
        """Empty list = pass; otherwise the failed clauses."""
        fails = []
        if phase["ttft_s"]["p99"] > self.ttft_p99_s:
            fails.append(
                f"ttft_p99 {phase['ttft_s']['p99']:.4f}s > "
                f"{self.ttft_p99_s}s"
            )
        if phase["latency_s"]["p99"] > self.latency_p99_s:
            fails.append(
                f"latency_p99 {phase['latency_s']['p99']:.4f}s > "
                f"{self.latency_p99_s}s"
            )
        allowed = (phase["arrival_span_s"] + self.latency_p99_s) \
            / self.min_sustained_ratio
        if phase["wall_s"] > allowed:
            fails.append(
                f"saturated: wall {phase['wall_s']:.3f}s > allowed "
                f"{allowed:.3f}s (arrival span "
                f"{phase['arrival_span_s']:.3f}s + latency budget, "
                f"/{self.min_sustained_ratio})"
            )
        return fails


def poisson_requests(n: int, qps: float, rng, *, vocab: int,
                     prompt_mean: float, max_new: int, cache_len: int,
                     rid0: int = 0) -> list[Request]:
    """Open-loop LLM phase workload: ``n`` requests with exponential
    inter-arrival gaps at ``qps`` (arrival offsets in SECONDS — the
    harness replays them on the wall clock) and geometric prompt
    lengths clipped to fit ``cache_len``."""
    if qps <= 0:
        raise ValueError("qps must be > 0")
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / qps))
        plen = 1 + min(int(rng.geometric(1.0 / max(prompt_mean, 1.0))),
                       cache_len - max_new - 1)
        prompt = [int(x) for x in rng.integers(1, vocab, size=plen)]
        reqs.append(Request(rid=rid0 + i, prompt=prompt, max_new=max_new,
                            eos=-1, arrival=t))
    return reqs


def phase_stats(report: dict, offered_qps: float) -> dict:
    """One phase's record for the search transcript: the harness report
    reduced to the SLO-relevant figures plus the offered/sustained
    pair.  Sustained QPS counts COMPLETED requests over the full wall
    (arrival span + drain tail) — a backlogged phase keeps paying its
    tail, which is exactly what blows the SLO's wall allowance."""
    wall = report["wall_s"]
    return {
        "offered_qps": offered_qps,
        "sustained_qps": report["requests"] / wall if wall > 0 else 0.0,
        "requests": report["requests"],
        "wall_s": wall,
        "arrival_span_s": report["arrival_span_s"],
        "tok_per_s": report["tok_per_s"],
        "ttft_s": report["ttft_s"],
        "latency_s": report["latency_s"],
        "retrace_free": report["retrace_free"],
    }


def search_max_qps(harness, make_requests, slo: SLO, *, qps_lo: float,
                   qps_hi: float, iters: int = 5,
                   phase_requests: int = 32) -> dict:
    """Binary-search the max sustainable offered QPS under ``slo``.

    ``make_requests(n, qps)`` must synthesize a FRESH phase workload
    (new rids) with Poisson arrivals at ``qps``; ``harness`` is a
    warmed ``OfflineInference``.  Protocol: measure ``qps_lo`` (fail ->
    report unsustainable floor), measure ``qps_hi`` (pass -> the
    bracket never saturated; report the ceiling), then ``iters``
    geometric bisections of the (pass, fail) bracket.  Returns the full
    phase transcript plus an attestation of the best PASSING phase —
    the sustained-QPS figure is a measurement, never an interpolation.
    """
    if not 0 < qps_lo < qps_hi:
        raise ValueError("need 0 < qps_lo < qps_hi")
    if iters < 0:
        raise ValueError("iters must be >= 0")
    phases: list[dict] = []

    def trial(qps: float) -> dict:
        reqs = make_requests(phase_requests, qps)
        ph = phase_stats(harness.run(reqs), qps)
        fails = slo.check(ph)
        ph["slo_pass"], ph["slo_fails"] = not fails, fails
        phases.append(ph)
        return ph

    def attest(ph: dict | None, note: str) -> dict:
        out = {
            "slo": dataclasses.asdict(slo),
            "phases": phases,
            "bracket": [qps_lo, qps_hi],
            "note": note,
        }
        if ph is None:
            out["slo_pass"] = False
            out["max_qps"] = 0.0
            out["sustained_qps"] = 0.0
            return out
        out["slo_pass"] = True
        out["max_qps"] = ph["offered_qps"]
        out["sustained_qps"] = ph["sustained_qps"]
        out["attestation"] = {
            "slo_pass": True,
            "offered_qps": ph["offered_qps"],
            "sustained_qps": ph["sustained_qps"],
            "ttft_p99_s": ph["ttft_s"]["p99"],
            "latency_p99_s": ph["latency_s"]["p99"],
            "retrace_free": ph["retrace_free"],
        }
        return out

    lo_ph = trial(qps_lo)
    if not lo_ph["slo_pass"]:
        return attest(None, f"floor qps_lo={qps_lo} already violates the "
                            f"SLO: {lo_ph['slo_fails']}")
    hi_ph = trial(qps_hi)
    if hi_ph["slo_pass"]:
        return attest(hi_ph, f"ceiling qps_hi={qps_hi} still meets the "
                             f"SLO; raise the bracket to find the knee")
    lo, hi, best = qps_lo, qps_hi, lo_ph
    for _ in range(iters):
        mid = (lo * hi) ** 0.5  # geometric: brackets often span decades
        ph = trial(mid)
        if ph["slo_pass"]:
            lo, best = mid, ph
        else:
            hi = mid
    return attest(best, f"converged bracket [{lo:.3f}, {hi:.3f}] qps "
                        f"after {iters} bisections")
