"""Batched RNS big-integer crypto service — the serve engine's second
request family (DESIGN.md §15).

The paper's closing claim is that full-range comparison "opens perspectives
for … division, scaling, and cryptographic applications".  This module is
that claim as a workload: ``modexp`` / ``modmul`` / ``divmod`` requests are
admitted into slots of the SAME continuous-batching engine that serves LLM
decode, advance in fixed-size ladder chunks under the same tick clock, and
carry per-slot RRNS fingerprints verified at retirement.

Execution model:

* ``modexp`` is SLOT-RESIDENT: admission runs one jitted graph that enters
  the Montgomery domain (ā = MM(a, M² mod N)) and writes the slot's ladder
  state — r0/r1 in both bases, the per-``N`` channel constants, and the
  full fixed-width exponent bit row, all DEVICE state.  Each engine tick
  advances every running slot by ``chunk`` ladder bits through one jitted
  step graph (the bits are gathered per-slot with a vmapped dynamic slice
  at the slot's cursor, so the fingerprinted device rows are the actual
  computation inputs).  The ladder always runs its full ``exp_bits`` width
  — leading-zero bits are no-ops (r0 stays 1̄) — so latency is constant and
  exponent-independent (the classic SPA/timing countermeasure), and slot
  residency is the same for every request: ``exp_bits / chunk`` ticks.
* ``modmul`` and ``divmod`` are ONE-SHOT: a single jitted graph at
  admission computes and retires them in the same call — they never occupy
  a slot, so they cannot starve ladder traffic.  Their operands live only
  inside that one functional device call, hence there is no resident state
  to fingerprint (the wire-integrity story below applies to slot-resident
  ops).

Integrity: a running modexp slot's IMMUTABLE device rows — exponent bits
and the ``N``-derived channel constants — are fingerprinted at admission
(plain + index-weighted f32 sums, exact for these magnitudes), RRNS-encoded
through the engine's ``GradCodec``, and stored in the engine's shared
``WireStore`` under the key ``("crypto", rid)``.  At retirement the engine
recomputes the fingerprint from the device rows that actually fed the
ladder and verifies bitwise — the same detect/locate-and-repair machinery
(``wire_ok`` / ``repair_wire``) the LLM KV path uses, unchanged.

Every result is differentially checkable against Python's big ints
(``pow(a, e, n)`` / ``divmod(a, b)``); tests/test_crypto_service.py and the
``launch/serve.py`` report's ``oracle_ok`` field do exactly that.

>>> from repro.serve.crypto import CryptoContext, CryptoRequest
>>> ctx = CryptoContext(n_limbs=3, exp_bits=8)
>>> N = 1000003
>>> ctx.validate(CryptoRequest(rid=0, op="modexp", a=7, b=200, n=N))
>>> int(ctx.decode_lo(ctx.encode_lo(12345))) == 12345
True
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.array import Layout, RnsArray
from repro.core.base import RNSBase, gen_coprime_moduli
from repro.core.convert import rns_to_int
from repro.core.division import _divmod_impl
from repro.core.montgomery import (
    DualRep,
    _channel_targets,
    exp_bits_msb,
    ladder_step,
    mont_consts,
    mont_mul,
)

__all__ = ["CryptoRequest", "CryptoContext", "CryptoLane", "CryptoSlot",
           "make_crypto_fns", "CRYPTO_OPS"]

CRYPTO_OPS = ("modexp", "modmul", "divmod")


@dataclasses.dataclass
class CryptoRequest:
    """One big-integer operation.  ``modexp``: a^b mod n; ``modmul``:
    a·b mod n; ``divmod``: (a // b, a % b) over the base's full dynamic
    range [0, M).  ``result`` is engine-filled at retirement: an int, or
    an (q, r) int pair for divmod."""

    rid: int
    op: str
    a: int
    b: int
    n: int | None = None
    arrival: float = 0.0
    family: str = "crypto"
    result: object = None
    slot_index: int | None = None
    t_admit: float | None = None
    t_done: float | None = None


@dataclasses.dataclass
class CryptoSlot:
    index: int
    state: str = "FREE"          # FREE | RUN
    req: CryptoRequest | None = None
    cursor: int = 0              # exponent bits already consumed


class CryptoContext:
    """The crypto lane's algebraic configuration: one dual Montgomery base
    pair shared by every request (the modulus ``N`` is per-request DATA).

    ``n_limbs`` 15-bit channels per base give a ``15·n_limbs``-bit dynamic
    range: requests need ``4·n < M`` and ``2·n < M'``.  Bases are built
    with four extra coprime moduli so both redundant channels (m_a each
    side of the draw, plus m_b for RRNS layouts) are distinct from every
    base channel — full-range comparison with no special-form moduli.
    """

    def __init__(self, *, n_limbs: int = 8, bits: int = 15,
                 exp_bits: int = 32, layout: Layout = Layout.BASE_MA,
                 mb: int | None = None,
                 bases: tuple[RNSBase, RNSBase] | None = None):
        if layout is Layout.BASE:
            raise ValueError("the crypto lane needs the redundant m_a "
                             "channel (Alg.-1 canonicalization): use "
                             "BASE_MA or RRNS")
        if bases is None:
            k = int(n_limbs)
            ms = gen_coprime_moduli(2 * k + 3, bits)
            # interleave so M and M' are within one modulus of each other
            B = RNSBase(moduli=tuple(ms[0:2 * k:2]), ma=ms[2 * k], bits=bits)
            Bp = RNSBase(moduli=tuple(ms[1:2 * k:2]), ma=ms[2 * k + 1],
                         bits=bits)
            if layout is Layout.RRNS and mb is None:
                mb = ms[2 * k + 2]
        else:
            B, Bp = bases
        self.baseB, self.baseBp = B, Bp
        self.layout, self.mb = layout, mb
        self.exp_bits = int(exp_bits)
        self.lo_targets = _channel_targets(B, layout, mb)
        self.nch_lo, self.n, self.n_hi = len(self.lo_targets), B.n, Bp.n
        # largest modulus with bounded Montgomery outputs (exclusive)
        self.n_max = min(B.M // 4, Bp.M // 2)
        self._consts: dict[int, dict] = {}

    def consts_for(self, N: int) -> dict[str, np.ndarray]:
        """Per-``N`` channel-constant rows (cached — traffic reuses moduli)."""
        if N not in self._consts:
            self._consts[N] = mont_consts(self.baseB, self.baseBp, N,
                                          layout=self.layout, mb=self.mb)
        return self._consts[N]

    def encode_lo(self, v: int) -> np.ndarray:
        """(nch_lo,) exact host residues of a big int over all B channels."""
        return np.asarray([v % t for t in self.lo_targets],
                          dtype=self.baseB.dtype)

    def encode_hi(self, v: int) -> np.ndarray:
        return np.asarray(self.baseBp.residues_of(v), dtype=self.baseBp.dtype)

    def decode_lo(self, row) -> int:
        """Exact big int from a (nch_lo,)-or-(n,)-leading row (CRT oracle)."""
        return rns_to_int(self.baseB, np.asarray(row)[..., : self.n])

    def validate(self, req: CryptoRequest) -> None:
        """Host-side admission contract; raises ValueError on bad requests."""
        if req.op not in CRYPTO_OPS:
            raise ValueError(f"unknown crypto op {req.op!r}; one of "
                             f"{CRYPTO_OPS}")
        if req.op == "divmod":
            M = self.baseB.M
            if not 0 <= req.a < M:
                raise ValueError(f"divmod dividend must lie in the base's "
                                 f"dynamic range [0, M={M})")
            if not 1 <= req.b < M:
                raise ValueError("divmod divisor must lie in [1, M)")
            return
        if req.n is None:
            raise ValueError(f"{req.op} needs a modulus n=")
        if not 1 < req.n < self.n_max:
            raise ValueError(
                f"modulus n must lie in (1, {self.n_max}) — the bases give "
                f"a {self.baseB.M.bit_length()}-bit range and Montgomery "
                f"needs M > 4n, M' > 2n")
        import math

        if math.gcd(req.n, self.baseB.M * self.baseBp.M) != 1:
            raise ValueError("modulus n must be coprime to both base "
                             "products M and M'")
        if req.op == "modexp":
            if req.b < 0 or int(req.b).bit_length() > self.exp_bits:
                raise ValueError(
                    f"exponent needs {int(req.b).bit_length()} bits > the "
                    f"lane's exp_bits={self.exp_bits}")


class CryptoLane:
    """Host-side slot scheduler for the crypto family — the crypto twin of
    ``SlotScheduler``, minus positions/tokens: a modexp binds a slot for
    exactly ``exp_bits / chunk`` ticks; one-shots never bind."""

    def __init__(self, n_slots: int, exp_bits: int, chunk: int):
        if n_slots < 1:
            raise ValueError("crypto_slots must be >= 1")
        divisors = [d for d in range(1, exp_bits + 1) if exp_bits % d == 0]
        if chunk < 1 or exp_bits % chunk:
            raise ValueError(
                f"crypto_chunk={chunk} must divide exp_bits={exp_bits} "
                f"(the ladder is advanced whole chunks); valid chunks: "
                f"{divisors}")
        self.n_slots, self.exp_bits, self.chunk = n_slots, exp_bits, chunk
        self.slots = [CryptoSlot(i) for i in range(n_slots)]
        self.queue: deque[CryptoRequest] = deque()
        self.completed: list[CryptoRequest] = []

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.state == "RUN" for s in self.slots)

    def free_slot(self) -> CryptoSlot | None:
        return next((s for s in self.slots if s.state == "FREE"), None)

    def running_slots(self) -> list[CryptoSlot]:
        return [s for s in self.slots if s.state == "RUN"]

    def bind(self, slot: CryptoSlot, req: CryptoRequest, now: float) -> None:
        slot.state, slot.req, slot.cursor = "RUN", req, 0
        req.slot_index, req.t_admit = slot.index, now

    def retire(self, slot: CryptoSlot, now: float) -> CryptoRequest:
        req = slot.req
        req.t_done = now
        self.completed.append(req)
        slot.state, slot.req, slot.cursor = "FREE", None, 0
        return req


def make_crypto_fns(ctx: CryptoContext, chunk: int) -> dict:
    """The crypto lane's jitted device graphs.  Like the engine's LLM
    graphs, each traces exactly once: every argument keeps a fixed shape
    (slot ids / cursors / active masks are DATA), and the backend route
    (jnp vs Pallas kernels) is captured at trace time by
    ``core.dispatch.resolve_backend`` inside ``mont_mul``/``ladder_step``.
    """
    B, Bp = ctx.baseB, ctx.baseBp
    lo = lambda p: RnsArray.from_packed(B, p, mb=ctx.mb)
    dual = lambda l, h: DualRep(lo(l), RnsArray.from_packed(Bp, h))
    m_lo = np.asarray(ctx.lo_targets, dtype=B.dtype)

    def canonical(ex_lo: RnsArray, n_lo_rows):
        """< 2N -> < N: full-range Alg.-1 compare vs N + conditional
        channel-wise subtract (exact in the redundant channels too)."""
        ge = ex_lo.compare_ge(lo(n_lo_rows))
        d = ex_lo._cl() - n_lo_rows.astype(ex_lo.dtype)
        d = jnp.where(d < 0, d + jnp.asarray(m_lo, ex_lo.dtype), d)
        return jnp.where(jnp.asarray(ge)[..., None], d, ex_lo._cl())

    def admit(state, slot, a_lo, a_hi, m2_lo, m2_hi, one_lo, one_hi,
              neg, n_lo, n_hi, bits):
        """Enter the Montgomery domain and write slot ``slot``'s ladder
        state; every row argument is (1, width)."""
        abar = mont_mul(dual(a_lo, a_hi), dual(m2_lo, m2_hi), neg, n_hi)
        upd = {"r0_lo": one_lo, "r0_hi": one_hi,
               "r1_lo": abar.lo.to_packed(), "r1_hi": abar.hi.to_packed(),
               "neg": neg, "n_lo": n_lo, "n_hi": n_hi, "bits": bits}
        out = dict(state)
        for k, v in upd.items():
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                state[k], v.astype(state[k].dtype), slot, axis=0)
        return out

    def step(state, cursors, active):
        """Advance EVERY slot row ``chunk`` ladder bits; rows with
        ``active == 0`` are restored bitwise untouched at the end (one
        masked select per output, so co-residency never perturbs a
        neighbour — the crypto twin of the LLM isolation invariant)."""
        bits = jax.vmap(
            lambda row, c: jax.lax.dynamic_slice_in_dim(row, c, chunk)
        )(state["bits"], cursors)                         # (S, chunk)
        r0 = dual(state["r0_lo"], state["r0_hi"])
        r1 = dual(state["r1_lo"], state["r1_hi"])
        for i in range(chunk):
            r0, r1 = ladder_step(r0, r1, bits[:, i],
                                 state["neg"], state["n_hi"])
        keep = active[:, None].astype(bool)
        sel = lambda new, old: jnp.where(keep, new.astype(old.dtype), old)
        return {**state,
                "r0_lo": sel(r0.lo.to_packed(), state["r0_lo"]),
                "r0_hi": sel(r0.hi.to_packed(), state["r0_hi"]),
                "r1_lo": sel(r1.lo.to_packed(), state["r1_lo"]),
                "r1_hi": sel(r1.hi.to_packed(), state["r1_hi"])}

    def final(state, slot):
        """Leave the domain (MM(r0, 1)) and canonicalize to < N; returns
        the (1, nch_lo) result row."""
        row = lambda k: jax.lax.dynamic_slice_in_dim(state[k], slot, 1,
                                                     axis=0)
        r0 = dual(row("r0_lo"), row("r0_hi"))
        ones = dual(jnp.ones((1, ctx.nch_lo), r0.lo.dtype),
                    jnp.ones((1, ctx.n_hi), r0.hi.dtype))
        ex = mont_mul(r0, ones, row("neg"), row("n_hi"))
        return canonical(ex.lo, row("n_lo"))

    def modmul(a_lo, a_hi, b_lo, b_hi, m2_lo, m2_hi, neg, n_hi, n_lo):
        """One-shot a·b mod N: enter the domain, one product, leave."""
        abar = mont_mul(dual(a_lo, a_hi), dual(m2_lo, m2_hi), neg, n_hi)
        r = mont_mul(abar, dual(b_lo, b_hi), neg, n_hi)
        return canonical(r.lo, n_lo)

    def divmod_fn(xp, dp):
        """One-shot full-range (a // b, a % b) via the comparison-driven
        division (core/division.py) on (1, n+1) Alg.-1 packed rows."""
        return _divmod_impl(B, xp, dp)

    def fp(state, slot):
        """(8,) f32 fingerprint of slot ``slot``'s IMMUTABLE rows (bits +
        the three N-derived constant rows): plain and index-weighted sums,
        exact in f32 for 15-bit residues over <= 2**8 channels."""
        parts = []
        for k in ("bits", "neg", "n_lo", "n_hi"):
            row = jax.lax.dynamic_index_in_dim(
                state[k], slot, axis=0, keepdims=False).astype(jnp.float32)
            w = jnp.arange(1, row.shape[0] + 1, dtype=jnp.float32)
            parts.append(jnp.stack([jnp.sum(row), jnp.sum(row * w)]))
        return jnp.concatenate(parts)

    return {"admit": jax.jit(admit), "step": jax.jit(step),
            "final": jax.jit(final), "modmul": jax.jit(modmul),
            "divmod": jax.jit(divmod_fn), "fp": jax.jit(fp)}


def encode_exponent(ctx: CryptoContext, e: int) -> np.ndarray:
    """(exp_bits,) MSB-first fixed-width bit row for the device state."""
    return exp_bits_msb(int(e), ctx.exp_bits)
