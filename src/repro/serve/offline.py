# Copyright 2026 the repro authors
#
# Saturation-grade offline inference harness (DESIGN.md §16).
#
# ``launch/serve.py --mode sim`` replays traces on a single-threaded tick
# clock: it measures the ENGINE, never the system.  This module is the
# MLPerf-offline-style measurement layer on top of the PR 5-8 engine,
# modeled on maxtext's ``OfflineInference``:
#
#   * ``OfflineInference`` — wall-clock driver over one or more
#     ``ContinuousBatcher`` replicas.  ``warmup()`` pre-compiles every
#     (bucket, family) graph BEFORE timing starts; ``run()`` then replays
#     a workload under the real clock and asserts steady state added zero
#     retraces.
#   * ``CompletionPump`` — ONE background detokenize/callback thread fed
#     by a bounded queue, so host-side completion work overlaps the
#     persistent jitted decode step.  First-error-wins propagation
#     exactly like ``train/checkpointer.py``: a failed callback surfaces
#     on the next ``put()`` / ``flush()`` / ``close()``, never silently.
#   * ``ReplicaSet`` — data-parallel engine replicas behind ONE shared
#     admission deque; a request is dispatched to the least-loaded
#     replica with free capacity for its family.  ``replica_meshes``
#     carves the device fleet into per-replica meshes when it divides
#     evenly (on a single-device host every replica shares the device —
#     still useful as a scheduling test vehicle, reported as 1 chip).
#
# The closed-loop QPS search that drives this harness to saturation
# lives in ``serve/loadgen.py``.

from __future__ import annotations

import math
import queue
import threading
import time

import numpy as np

import jax

__all__ = [
    "CompletionPump",
    "OfflineInference",
    "ReplicaSet",
    "default_callback",
    "pow2_buckets",
    "replica_meshes",
    "sample_stats",
]


def sample_stats(xs) -> dict:
    """n/mean/p50/p95/p99 summary of a sample list.

    An empty sample returns the explicit ``n: 0`` record (all stats 0.0)
    instead of crashing ``np.percentile`` on ``[]`` — a family filter
    that leaves zero completed requests must not kill report generation.
    """
    if not xs:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(xs, np.float64)
    return {
        "n": int(a.size),
        "mean": float(a.mean()),
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
    }


def pow2_buckets(cache_len: int, lo: int = 8) -> tuple[int, ...]:
    """Power-of-two prefill buckets ``lo, 2*lo, ... , cache_len`` (the
    default bucket ladder of ``--mode offline``).  ``cache_len`` itself
    is appended when it is not a power of two so every admissible prompt
    hits a bucket (widths > 512 are multiples of 512 whenever cache_len
    is, per the engine's flash-chunk rule)."""
    if cache_len < 1:
        raise ValueError("cache_len must be >= 1")
    lo = max(1, min(lo, cache_len))
    out = []
    b = 1 << (lo - 1).bit_length()
    while b < cache_len:
        out.append(b)
        b <<= 1
    out.append(cache_len)
    return tuple(out)


def default_callback(req) -> str:
    """Minimal "detokenize": completed crypto requests render their
    big-int result, LLM requests their output token ids.  Real servers
    swap in a tokenizer's ``decode`` — anything swapped in runs on the
    pump thread, overlapped with device decode."""
    if getattr(req, "family", "llm") == "crypto":
        return f"{req.op}:{req.result}"
    return " ".join(str(t) for t in req.out)


class CompletionPump:
    """Background completion/detokenize thread behind a bounded queue.

    ``put(req)`` enqueues a retired request for the worker to run
    ``callback(req)`` on; the driver thread returns to stepping the
    engine immediately unless the queue is full (bounded = backpressure:
    a slow callback eventually throttles the producer instead of growing
    an unbounded buffer).  Results land in ``completed`` in submission
    order (single worker = FIFO).

    Error contract (the ``train/checkpointer.py`` pattern): the FIRST
    callback exception is held and re-raised from the next ``put()`` /
    ``flush()`` / ``close()`` — never dropped, no silent hang.  After an
    error the worker keeps draining the queue (dropping items) so a
    producer blocked on a full queue always unblocks.
    """

    _SENTINEL = object()

    def __init__(self, callback, *, queue_size: int = 64):
        if queue_size < 1:
            raise ValueError("queue_size must be >= 1")
        self._callback = callback
        self._q: queue.Queue = queue.Queue(maxsize=queue_size)
        self.completed: list = []  # (request, callback result), FIFO
        self._error: BaseException | None = None
        self._error_lock = threading.Lock()
        self._closed = False
        self.processed = 0
        self.dropped = 0  # items drained after the first error
        self.max_depth = 0
        self.blocked_puts = 0  # puts that found the queue full
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name="completion-pump"
        )
        self._thread.start()

    # -- lifecycle ---------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # don't mask an in-flight exception with the held one: it already
        # surfaced (or will, from the caller's own flush/close)
        self.close(raise_error=exc[0] is None)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is self._SENTINEL:
                self._q.task_done()
                return
            try:
                if self._error is not None:
                    self.dropped += 1  # drain-after-error: never deadlock
                    continue
                self.completed.append((item, self._callback(item)))
                self.processed += 1
            except BaseException as e:
                with self._error_lock:
                    if self._error is None:  # first failure wins
                        self._error = e
            finally:
                self._q.task_done()

    def _check_error(self) -> None:
        with self._error_lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # -- producing ---------------------------------------------------------

    def put(self, req) -> None:
        """Enqueue one retired request; blocks when the queue is full
        (backpressure); re-raises the first worker error if any."""
        self._check_error()
        if self._closed:
            raise RuntimeError("CompletionPump is closed")
        if self._q.full():
            self.blocked_puts += 1
        self._q.put(req)  # blocks when full
        self.max_depth = max(self.max_depth, self._q.qsize())

    def flush(self) -> None:
        """Block until every enqueued completion has run; re-raise the
        first worker error if any callback failed."""
        self._q.join()
        self._check_error()

    def close(self, *, raise_error: bool = True) -> None:
        """Idempotent: stop the worker and join it.  With ``raise_error``
        (default) the held error surfaces here; pass False on exception
        paths where another error is already propagating."""
        if not self._closed:
            self._closed = True
            self._q.put(self._SENTINEL)
            self._thread.join()
        if raise_error:
            self._check_error()

    def stats(self) -> dict:
        return {
            "queue_size": self._q.maxsize,
            "processed": self.processed,
            "dropped": self.dropped,
            "max_depth": self.max_depth,
            "blocked_puts": self.blocked_puts,
        }


def replica_meshes(n: int, devices=None) -> list:
    """Carve the device fleet into ``n`` per-replica 1-axis meshes.

    Returns ``n`` ``Mesh(("data",))`` objects when the fleet divides
    evenly with >= 1 device each; otherwise ``n`` Nones (every replica's
    arrays land on the default device — the single-host CPU case, where
    replicas still exercise the shared-admission scheduling protocol)."""
    if n < 1:
        raise ValueError("need >= 1 replica")
    devs = list(jax.devices()) if devices is None else list(devices)
    per = len(devs) // n
    if n == 1 and per == len(devs) == 1:
        return [None]  # one replica, one device: no mesh indirection
    if per < 1 or len(devs) % n:
        return [None] * n
    return [
        jax.sharding.Mesh(
            np.asarray(devs[i * per:(i + 1) * per]), ("data",)
        )
        for i in range(n)
    ]


class ReplicaSet:
    """Data-parallel engine replicas behind ONE shared admission deque.

    ``submit`` parks requests in arrival order; ``pump(now)`` dispatches
    each to the least-loaded replica that has free capacity for its
    family (LLM: FREE slots beyond the engine's own backlog; crypto
    modexp: FREE lane slots beyond queued ladders; crypto one-shots:
    round-robin — they execute inside admission and never bind a slot).
    A request whose family has no capacity anywhere stays parked; FIFO
    is preserved WITHIN each family (capacity is family-wide, so a
    later same-family request can never jump an earlier one).
    """

    def __init__(self, engines: list):
        if not engines:
            raise ValueError("need >= 1 engine replica")
        self.engines = list(engines)
        self.queue: list = []  # shared admission queue (arrival order)
        self.steps = 0  # total engine decode/ladder steps across replicas
        self.dispatched = [0] * len(engines)
        self._rr = 0  # one-shot round-robin cursor
        # fingerprint verdicts harvested at retirement (the engines pop
        # their verify logs when drained, so the set keeps the tally)
        self.verify_ok = 0
        self.verify_failed = 0

    # -- capacity probes ---------------------------------------------------

    @staticmethod
    def _free_llm(eng) -> int:
        free = sum(1 for s in eng.sched.slots if s.state == "FREE")
        return free - len(eng.sched.queue)

    @staticmethod
    def _free_modexp(eng) -> int:
        if eng.crypto is None:
            return 0
        free = sum(1 for s in eng.crypto.slots if s.state == "FREE")
        queued = sum(1 for r in eng.crypto.queue if r.op == "modexp")
        return free - queued

    # -- shared-queue protocol ---------------------------------------------

    def submit(self, req) -> None:
        self.queue.append(req)

    def pump(self, now: float) -> int:
        """One dispatch pass over the shared queue; returns how many
        requests were handed to a replica.  ``now`` is threaded through
        for symmetry with the engine API (dispatch itself stamps
        nothing — admission stamps ``t_admit``)."""
        del now
        placed, rest = 0, []
        for req in self.queue:
            family = getattr(req, "family", "llm")
            ei = self._pick(family, req)
            if ei is None:
                rest.append(req)
                continue
            self.engines[ei].submit(req)
            self.dispatched[ei] += 1
            placed += 1
        self.queue = rest
        return placed

    def _pick(self, family: str, req) -> int | None:
        if family == "crypto":
            armed = [i for i, e in enumerate(self.engines)
                     if e.crypto is not None]
            if not armed:
                raise ValueError(
                    "crypto-family request but no replica has a crypto "
                    "lane; build engines with crypto_slots >= 1"
                )
            if req.op != "modexp":
                # one-shots execute inside admission: spread round-robin
                self._rr += 1
                return armed[self._rr % len(armed)]
            best = max(armed, key=lambda i: self._free_modexp(
                self.engines[i]))
            return best if self._free_modexp(self.engines[best]) > 0 \
                else None
        best = max(range(len(self.engines)),
                   key=lambda i: self._free_llm(self.engines[i]))
        return best if self._free_llm(self.engines[best]) > 0 else None

    # -- stepping ----------------------------------------------------------

    @property
    def stepping(self) -> bool:
        """Any replica has device work this instant (decoding rows or
        running ladders) — False means the set is idle waiting on
        arrivals or free capacity."""
        return any(
            e.sched.decoding_slots()
            or (e.crypto is not None and e.crypto.running_slots())
            for e in self.engines
        )

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(e.busy for e in self.engines)

    def step_all(self, now: float) -> list:
        """Admit + one decode/ladder step on every replica with work;
        returns the requests (all families, all replicas) that retired."""
        retired = []
        for eng in self.engines:
            eng.try_admit(now)
            if eng.sched.decoding_slots() or (
                eng.crypto is not None and eng.crypto.running_slots()
            ):
                eng.step(now)
                self.steps += 1
            if eng.rns_verify:
                # harvest before drain_completed pops the log entries
                for ok in eng.verify_log.values():
                    self.verify_ok += bool(ok)
                    self.verify_failed += not ok
            retired.extend(eng.drain_completed())
        return retired


class OfflineInference:
    """Wall-clock saturation harness over data-parallel engine replicas.

    Lifecycle: construct -> ``warmup()`` (pre-compiles every (bucket,
    family) graph and snapshots the jit-cache census) -> ``run(reqs)``
    one or more times (timed; asserts zero steady-state retraces via
    ``require_steady_state``).  Engine kwargs mirror
    ``ContinuousBatcher``; ``buckets`` arms length-bucketed single-call
    prefill, ``overlap`` routes completions through a
    ``CompletionPump`` instead of running the callback inline on the
    driver thread.  ``page_size`` puts every replica on the paged,
    prefix-sharing pool — buckets compose with it through the padded
    write barrier (DESIGN.md §13), and warmup additionally pre-compiles
    the copy-on-write graph so steady state stays retrace-free.
    """

    def __init__(self, cfg, params, *, n_slots: int, cache_len: int,
                 prefill_chunk: int = 32,
                 buckets: tuple | None = None,
                 replicas: int = 1,
                 overlap: bool = True,
                 queue_size: int = 64,
                 callback=None,
                 rns_verify: bool = False,
                 page_size: int | None = None, n_pages: int | None = None,
                 prefix_share: bool = True,
                 crypto_slots: int = 0, crypto_ctx=None,
                 crypto_chunk: int = 8):
        from repro.serve.batcher import ContinuousBatcher

        self.meshes = replica_meshes(replicas)
        self.engines = [
            ContinuousBatcher(
                cfg, params, n_slots=n_slots, cache_len=cache_len,
                prefill_chunk=prefill_chunk, prefill_buckets=buckets,
                rns_verify=rns_verify, mesh=mesh,
                page_size=page_size, n_pages=n_pages,
                prefix_share=prefix_share,
                crypto_slots=crypto_slots, crypto_ctx=crypto_ctx,
                crypto_chunk=crypto_chunk,
            )
            for mesh in self.meshes
        ]
        self.replica_set = ReplicaSet(self.engines)
        self.cache_len = int(cache_len)
        self.buckets = self.engines[0].prefill_buckets
        self.overlap = bool(overlap)
        self.queue_size = int(queue_size)
        self.callback = callback if callback is not None else \
            default_callback
        devs = set()
        for mesh in self.meshes:
            devs.update(mesh.devices.flat if mesh is not None
                        else [jax.devices()[0]])
        self.n_chips = len(devs)
        self._warm_sizes: list[dict] | None = None
        self.completions: list = []  # (request, callback result) last run
        self.on_step = None  # default per-loop hook (profiler window)

    # -- warmup ------------------------------------------------------------

    def _warm_llm_plens(self) -> list[int]:
        """One prompt length per compiled prefill width: each armed
        bucket gets the longest admissible prompt that selects it (a
        bucket no admissible prompt can select is skipped — it can never
        compile under traffic either); without buckets, one multi-chunk
        prompt compiles the chunk-loop graph."""
        top = self.cache_len - 2  # warmup decodes 2: plen+2 <= cache_len
        if self.buckets is None:
            C = self.engines[0].prefill_chunk
            return [min(2 * C, top)]
        plens, prev = [], 0
        for b in self.buckets:
            hi = min(b, top)
            if hi > prev:  # a prompt of length hi selects bucket b
                plens.append(hi)
            prev = b
        return plens

    def warmup(self) -> dict:
        """Pre-compile every (bucket, family) graph on every replica
        BEFORE timing starts, then snapshot the jit-cache census that
        ``require_steady_state`` holds ``run()`` to.  Warmup requests
        use negative rids (real traffic uses non-negative) and are
        drained, not reported."""
        from repro.serve.scheduler import Request

        for ei, eng in enumerate(self.engines):
            rid = -(1 + 1000 * ei)  # unique negative ids per replica
            for wi, plen in enumerate(self._warm_llm_plens()):
                # max_new=2 reaches the decode graph (1 would retire at
                # start_decode, before any batched step compiles).  One
                # DISTINCT token per warmup prompt: on the paged pool an
                # earlier warmup registers its prompt pages, and a
                # repeated token would prefix-hit — shrinking the next
                # prompt's real extend and silently skipping the bucket
                # width it was meant to compile.
                tok = 3 + wi % (eng.cfg.vocab - 3)
                eng.submit(Request(rid=rid, prompt=[tok] * plen, max_new=2,
                                   eos=-1))
                rid -= 1
            if (eng.paged and eng.sched.registry is not None
                    and eng.prefill_chunk < eng.page_size
                    and eng.page_size + 2 <= self.cache_len):
                # pre-compile the copy-on-write graph: a full-prefix
                # re-admission of a one-page prompt re-writes the shared
                # tail inside the registered page (chunk-grained restart
                # below the page boundary), which is exactly the CoW the
                # first timed prefix hit would otherwise compile
                dup = [2] * eng.page_size
                for _ in range(2):
                    eng.submit(Request(rid=rid, prompt=dup, max_new=2,
                                       eos=-1))
                    rid -= 1
            if eng.crypto is not None:
                from repro.serve.crypto import CryptoRequest

                ctx = eng.crypto_ctx
                MMp = ctx.baseB.M * ctx.baseBp.M
                n = 5
                while n < ctx.n_max and math.gcd(n, MMp) != 1:
                    n += 2
                eng.submit(CryptoRequest(rid=rid, op="modexp", a=3, b=5,
                                         n=n))
                eng.submit(CryptoRequest(rid=rid - 1, op="modmul", a=2,
                                         b=3, n=n))
                eng.submit(CryptoRequest(rid=rid - 2, op="divmod", a=7,
                                         b=3))
            eng.run_to_completion()
            eng.drain_completed()
            # warmup hits count compile coverage, not traffic: reset
            if eng.prefill_buckets is not None:
                eng.bucket_hits = {b: 0 for b in eng.prefill_buckets}
                eng.bucket_fallbacks = 0
                eng.bucket_pad_tokens = eng.bucket_real_tokens = 0
        self._warm_sizes = [e.jit_cache_sizes() for e in self.engines]
        return {
            "replicas": len(self.engines),
            "warmed_plens": self._warm_llm_plens(),
            "jit_traces": [dict(s) for s in self._warm_sizes],
        }

    # -- steady-state assertion --------------------------------------------

    def require_steady_state(self) -> None:
        """Raise unless the jit-cache census is EXACTLY the warmup
        snapshot — a timed run that compiled anything was mis-warmed and
        its numbers are garbage."""
        if self._warm_sizes is None:
            raise RuntimeError("warmup() has not run")
        live = [e.jit_cache_sizes() for e in self.engines]
        if live != self._warm_sizes:
            raise RuntimeError(
                f"steady state retraced: warmup compiled "
                f"{self._warm_sizes}, after run: {live}"
            )

    def steady_state_ok(self) -> bool:
        try:
            self.require_steady_state()
        except RuntimeError:
            return False
        return True

    # -- timed run ---------------------------------------------------------

    def run(self, reqs: list, *, clock=time.perf_counter,
            on_step=None) -> dict:
        """Replay ``reqs`` under the real clock and report saturation
        metrics.  Arrivals are offsets in seconds from the run's t0
        (offline mode zeroes them: everything available at once);
        ``t_admit/t_first/t_done`` land in the same timebase, so TTFT
        and latency come straight off the request stamps.  ``on_step``
        fires once per driver loop (profiler hook)."""
        if self._warm_sizes is None:
            raise RuntimeError(
                "warmup() must complete before timed traffic — otherwise "
                "the run pays compile time and retraces mid-measurement"
            )
        rs = self.replica_set
        if on_step is None:
            on_step = self.on_step
        reqs = sorted(reqs, key=lambda r: getattr(r, "arrival", 0.0))
        pump = (CompletionPump(self.callback, queue_size=self.queue_size)
                if self.overlap else None)
        inline: list = []
        i, n = 0, len(reqs)
        steps0 = rs.steps
        t0 = clock()
        try:
            while i < n or rs.busy:
                now = clock() - t0
                while i < n and reqs[i].arrival <= now:
                    rs.submit(reqs[i])
                    i += 1
                rs.pump(now)
                if on_step is not None:
                    on_step()
                retired = rs.step_all(clock() - t0)
                for r in retired:
                    if pump is not None:
                        pump.put(r)
                    else:
                        inline.append((r, self.callback(r)))
                if not retired and not rs.stepping and i < n:
                    # idle until the next open-loop arrival (short naps:
                    # an admission may free up before the next arrival)
                    gap = reqs[i].arrival - (clock() - t0)
                    if gap > 0:
                        time.sleep(min(gap, 5e-4))
            if pump is not None:
                pump.flush()  # completion work counts inside the wall
            wall = clock() - t0
        finally:
            if pump is not None:
                pump.close(raise_error=False)
        self.completions = list(pump.completed) if pump is not None \
            else inline
        return self._report(wall, steps0, pump)

    def _report(self, wall: float, steps0: int, pump) -> dict:
        done = [r for r, _ in self.completions]
        llm = [r for r in done if getattr(r, "family", "llm") == "llm"]
        crypto = [r for r in done if getattr(r, "family", "llm")
                  == "crypto"]
        toks = sum(len(r.out) for r in llm)
        report = {
            "requests": len(done),
            "llm_requests": len(llm),
            "crypto_requests": len(crypto),
            "tokens_out": toks,
            "wall_s": wall,
            "arrival_span_s": max(
                (getattr(r, "arrival", 0.0) for r in done), default=0.0
            ),
            "tok_per_s": toks / wall if wall > 0 else 0.0,
            "tok_per_s_per_chip": (toks / wall / self.n_chips)
            if wall > 0 else 0.0,
            "n_chips": self.n_chips,
            "replicas": len(self.engines),
            "engine_steps": self.replica_set.steps - steps0,
            "dispatched": list(self.replica_set.dispatched),
            "ttft_s": sample_stats(
                [r.t_first - r.arrival for r in llm
                 if r.t_first is not None]
            ),
            "latency_s": sample_stats(
                [r.t_done - r.arrival for r in done
                 if r.t_done is not None]
            ),
            "overlap": {
                "enabled": self.overlap,
                **(pump.stats() if pump is not None else {}),
            },
            "retrace_free": self.steady_state_ok(),
            "jit_traces": [dict(e.jit_cache_sizes())
                           for e in self.engines],
        }
        if self.buckets is not None:
            agg = {
                "widths": list(self.buckets),
                "hits": {str(b): 0 for b in self.buckets},
                "fallbacks": 0, "pad_tokens": 0, "real_tokens": 0,
            }
            for e in self.engines:
                st = e.bucket_stats()
                for k, v in st["hits"].items():
                    agg["hits"][k] += v
                for k in ("fallbacks", "pad_tokens", "real_tokens"):
                    agg[k] += st[k]
            agg["pad_overhead"] = (
                agg["pad_tokens"] / agg["real_tokens"]
                if agg["real_tokens"] else 0.0
            )
            report["buckets"] = agg
        if self.engines[0].paged:
            report["paging"] = [e.page_stats() for e in self.engines]
        return report
