"""Pallas TPU kernel: FUSED gradient-codec encode (the transport hot path).

Per (nch, BLOCK_B) tile — nch = n base channels plus one (detect) or two
(locate-and-correct) redundant channels — this kernel fuses what the jnp
path does in four HBM round-trips (f64 upcast, round/clip, per-channel mod,
redundant-channel fixup) into one pass:

    quantize  r = round(g * 2^frac_bits)        (f32, exact — see below)
    split     |r| -> hi*2^15 + lo               (exact power-of-two scales)
    clip      (hi, lo) vs qmax's limbs          (int32 compare/select)
    reduce    |q| mod m_c per channel           (Barrett, 15-bit moduli)
    embed     negate residues where r < 0; shift each redundant channel by
              its M mod m_r offset (the signed embedding of core/signed.py;
              base channels get offset 0 since m_i | M)

Exactness (all f32/int32, no 64-bit anywhere, bitwise equal to the f64
jnp path for M < 2^45):

  * g * 2^frac_bits is a power-of-two scale — exact in f32.
  * jnp.round of an f32 is exact: results < 2^24 are representable, and
    anything >= 2^24 was already an integer.  Round-half-even on the same
    real value gives the same integer as the f64 path.
  * |r| is pre-clamped to 2^44 (any such value still clips to qmax < 2^44,
    since qmax < M/2), so hi = floor(|r| * 2^-15) < 2^30 fits int32 and
    both halves of the split are exact f32 subtractions.
  * The clip compares (hi, lo) against (qmax >> 15, qmax & 0x7FFF) in
    int32 — exact at the boundary, unlike an f32 clamp at float(qmax).
  * hi < 2^30 and r_hi * (2^15 mod m) + lo < 2^30 keep every Barrett
    input in the proven range (common.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import barrett_mod

__all__ = ["codec_encode_kernel_call"]

_MASK = 0x7FFF


def _kernel(g_ref, m_ref, pow15_ref, off_ref, out_ref, *, scale, qh, ql):
    m = m_ref[...]                             # (nch, 1) base + redundant
    recip = 1.0 / m.astype(jnp.float32)

    r = jnp.round(g_ref[...] * jnp.float32(scale))  # (1, B) exact integer
    neg = r < 0.0                                   # -0.0 stays non-negative
    a = jnp.minimum(jnp.abs(r), jnp.float32(float(1 << 44)))

    hi_f = jnp.floor(a * jnp.float32(2.0 ** -15))
    lo_f = a - hi_f * jnp.float32(float(1 << 15))   # exact: |r| mod 2^15
    hi = hi_f.astype(jnp.int32)                     # < 2^30
    lo = lo_f.astype(jnp.int32)                     # < 2^15

    over = (hi > qh) | ((hi == qh) & (lo > ql))     # |q| > qmax: clip exact
    hi = jnp.where(over, jnp.int32(qh), hi)
    lo = jnp.where(over, jnp.int32(ql), lo)

    # |q| mod m_c = ((hi mod m_c) * (2^15 mod m_c) + lo) mod m_c, broadcast
    # over the channel axis; every Barrett operand stays below 2^30.
    r_hi = barrett_mod(hi, m, recip)                # (nch, B)
    r_abs = barrett_mod(r_hi * pow15_ref[...] + lo, m, recip)

    # signed embedding: (-|q|) mod m = m - (|q| mod m), except when 0
    res = jnp.where(neg & (r_abs > 0), m - r_abs, jnp.where(neg, 0, r_abs))

    # redundant rows additionally shift by M mod m_r when negative: the
    # channels store q + M, so each m_r must track (q + M) mod m_r.  Base
    # rows carry off = 0 (m_i divides M), so the shift is the identity there.
    shifted = res + off_ref[...]
    shifted = jnp.where(shifted >= m, shifted - m, shifted)
    out_ref[...] = jnp.where(neg, shifted, res)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "qh", "ql", "block_b", "interpret"),
)
def codec_encode_kernel_call(
    g_row, m_all, pow15, off, *, scale: float, qh: int, ql: int,
    block_b: int = 1024, interpret: bool = True,
):
    """g_row: (1, B) f32 gradients -> (nch, B) int32 packed residues, where
    nch = n base + 1 or 2 redundant channels (detect vs locate-and-correct
    codecs share the kernel).

    qh/ql are qmax's 15-bit limbs (qmax = qh*2^15 + ql < 2^44); ``off`` is
    the per-channel negative-embedding shift column (0 for base rows,
    M mod m_r for redundant rows).  B must be a multiple of block_b
    (ops.py pads).
    """
    nch = m_all.shape[0]
    _, B = g_row.shape
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, qh=qh, ql=ql),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b), lambda b: (0, b)),
            pl.BlockSpec((nch, 1), lambda b: (0, 0)),
            pl.BlockSpec((nch, 1), lambda b: (0, 0)),
            pl.BlockSpec((nch, 1), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nch, block_b), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((nch, B), jnp.int32),
        interpret=interpret,
    )(g_row, m_all, pow15, off)
