"""Pallas TPU kernel: channel-wise modular multiply (RNS ring product).

The throughput workhorse of every RNS pipeline (the paper's op-count unit
``M``).  Elementwise over an (n, B) tile; Barrett-via-f32 reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import barrett_mod

__all__ = ["modmul_kernel_call"]


def _kernel(x_ref, y_ref, m_ref, out_ref):
    m = m_ref[...]
    recip = 1.0 / m.astype(jnp.float32)
    out_ref[...] = barrett_mod(x_ref[...] * y_ref[...], m, recip)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def modmul_kernel_call(x_t, y_t, m_col, *, block_b: int = 1024, interpret: bool = True):
    """x_t, y_t: (n, B) int32 reduced residues -> (n, B) product residues."""
    n, B = x_t.shape
    grid = (B // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_b), lambda b: (0, b)),
            pl.BlockSpec((n, block_b), lambda b: (0, b)),
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_b), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((n, B), jnp.int32),
        interpret=interpret,
    )(x_t, y_t, m_col)
