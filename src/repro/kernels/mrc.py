"""Pallas TPU kernel: batched Mixed-Radix Conversion (paper Alg. 2).

Grid: 1-D over batch blocks.  Each program instance holds an
(n, BLOCK_B) residue tile plus the (n, n) inverse table in VMEM and runs the
triangular recurrence entirely in registers — n(n-1)/2 modular mults per
element with zero HBM round-trips between steps.

VMEM budget (int32): n*BLOCK_B + n*n + O(n) words.  With the default
BLOCK_B=512 and n<=128: 128*512*4 = 256 KiB tile + 64 KiB table — far under
the ~16 MiB v5e VMEM, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import mrc_rows

__all__ = ["mrc_kernel_call"]


def _kernel(x_ref, invt_ref, m_ref, out_ref, *, n: int):
    w = x_ref[...]
    m = m_ref[...]                       # (n, 1)
    recip = 1.0 / m.astype(jnp.float32)
    out_ref[...] = mrc_rows(w, invt_ref[...], m, recip, n=n)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mrc_kernel_call(x_t, inv_t, m_col, *, block_b: int = 512, interpret: bool = True):
    """x_t: (n, B) int32 residues (channel-major).  Returns (n, B) digits.

    B must be a multiple of block_b (ops.py pads).
    """
    n, B = x_t.shape
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_b), lambda b: (0, b)),
            pl.BlockSpec((n, n), lambda b: (0, 0)),
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_b), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((n, B), jnp.int32),
        interpret=interpret,
    )(x_t, inv_t, m_col)
