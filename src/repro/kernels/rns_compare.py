"""Pallas TPU kernel: FUSED Algorithm 1 (the paper's comparison).

One pass per (n, BLOCK_B) tile:

    z      = (x1 - x2) mod m_i          channel-wise subtract
    digits = MRC(z)                     Alg. 2, in-register triangle
    Delta  = to_ma(digits)              Alg. 3 dot against betas
    Delta' = (xa1 - xa2) mod m_a        redundant channel
    out    = (Delta == Delta')          verdict (int32 0/1)

Fusing all four stages keeps the digit tensor entirely in VMEM/registers —
the unfused path writes/reads the (B, n) digit tensor through HBM twice.
This kernel is the framework's hot path for element-wise magnitude tests on
RNS-coded tensors (gradient codec sign/clip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import mrc_rows, to_ma_rows

__all__ = ["compare_kernel_call"]


def _kernel(
    x1_ref, xa1_ref, x2_ref, xa2_ref, invt_ref, m_ref, betas_ref, out_ref, *, n, ma
):
    m = m_ref[...]                       # (n, 1)
    recip = 1.0 / m.astype(jnp.float32)
    z = x1_ref[...] - x2_ref[...]
    z = jnp.where(z < 0, z + m, z)                         # line 2 of Alg. 1
    digits = mrc_rows(z, invt_ref[...], m, recip, n=n)     # line 3
    delta = to_ma_rows(digits, betas_ref[...], ma)         # line 4, (1, B)
    dp = xa1_ref[...] - xa2_ref[...]
    dp = jnp.where(dp < 0, dp + ma, dp)                    # line 1
    out_ref[...] = (delta == dp).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("ma", "block_b", "interpret"))
def compare_kernel_call(
    x1_t, xa1, x2_t, xa2, inv_t, m_col, betas_col, *, ma: int,
    block_b: int = 512, interpret: bool = True,
):
    """x*_t: (n, B) residues; xa*: (1, B) redundant residues.

    Returns (1, B) int32 verdicts (1 where N1 >= N2).
    """
    n, B = x1_t.shape
    grid = (B // block_b,)
    blk = lambda r: pl.BlockSpec((r, block_b), lambda b: (0, b))
    tbl = lambda s: pl.BlockSpec(s, lambda b: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, n=n, ma=ma),
        grid=grid,
        in_specs=[blk(n), blk(1), blk(n), blk(1), tbl((n, n)), tbl((n, 1)), tbl((n, 1))],
        out_specs=blk(1),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.int32),
        interpret=interpret,
    )(x1_t, xa1, x2_t, xa2, inv_t, m_col, betas_col)
