"""Pallas TPU kernel: FUSED gradient-codec decode (the optimizer hot path).

After the per-channel psum, every gradient element holds n+1 summed int32
channels.  Per (n+1, BLOCK_B) tile this kernel fuses:

    fold    summed -> residues            (Barrett per channel)
    MRC     residues -> digits            (Alg. 2 triangle, in-register)
    Horner  digits -> value v in [0, M)   (3x15-bit limbs, int32-exact)
    sign    v >= ceil(M/2) ? v - M : v    (limb-wise compare & subtract)
    cast    correctly-rounded f32 of the exact integer v via a Fast2Sum
            compensated limb sum — bitwise identical to the f64 jnp path

The unfused jnp path round-trips the tensor through HBM four times; fused
it is once.  Limb arithmetic bounds (all int32):

    limbs l0,l1,l2 < 2^15 represent v = l2*2^30 + l1*2^15 + l0  (M < 2^45)
    v' = v*m + d:  t0 = l0*m + d        <= (2^15-1)(2^15-1)+2^15 < 2^30
                   t1 = l1*m + (t0>>15) < 2^30
                   t2 = l2*m + (t1>>15) < 2^30, requires l2 < 2^15 i.e.
                   every partial value < 2^45 — guaranteed since M < 2^45.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import barrett_mod, mrc_rows

__all__ = ["codec_decode_kernel_call"]

_MASK = 0x7FFF


def _kernel(x_ref, invt_ref, m_ref, half_ref, out_ref, *, n, inv_scale):
    m = m_ref[...]                         # (n, 1)
    recip = 1.0 / m.astype(jnp.float32)
    res = barrett_mod(x_ref[...][:n, :], m, recip)         # fold
    digits = mrc_rows(res, invt_ref[...], m, recip, n=n)   # Alg. 2

    # Horner over the mixed radix, most-significant digit first.
    l0 = digits[n - 1 : n, :]
    l1 = jnp.zeros_like(l0)
    l2 = jnp.zeros_like(l0)
    for i in range(n - 2, -1, -1):
        mi = m[i : i + 1, :]
        t0 = l0 * mi + digits[i : i + 1, :]
        t1 = l1 * mi + (t0 >> 15)
        t2 = l2 * mi + (t1 >> 15)
        l0, l1, l2 = t0 & _MASK, t1 & _MASK, t2 & _MASK

    # signed fold: v >= T (= ceil(M/2), limbs in half_ref) ? v - M : v.
    # M's limbs are (T*2 - (M odd ? ... )) — we pass BOTH T and M limbs:
    # half_ref is (6, 1): rows 0..2 = T limbs, rows 3..5 = M limbs.
    h = half_ref[...]
    t0c, t1c, t2c = h[0:1], h[1:2], h[2:3]
    m0c, m1c, m2c = h[3:4], h[4:5], h[5:6]
    ge = (
        (l2 > t2c)
        | ((l2 == t2c) & (l1 > t1c))
        | ((l2 == t2c) & (l1 == t1c) & (l0 >= t0c))
    )
    # v - M with borrows (only where ge)
    b0 = l0 - m0c
    bor0 = (b0 < 0).astype(jnp.int32)
    b1 = l1 - m1c - bor0
    bor1 = (b1 < 0).astype(jnp.int32)
    b2 = l2 - m2c - bor1
    s0 = jnp.where(ge, b0 + (bor0 << 15), l0)
    s1 = jnp.where(ge, b1 + (bor1 << 15), l1)
    s2 = jnp.where(ge, b2, l2)
    # Correctly-rounded f32 of v = s2*2^30 + s1*2^15 + s0 (s2 may be
    # negative after the signed fold).  Each term is exact in f32; naive
    # summation double-rounds, so compensate: Fast2Sum(a2, a1) is valid
    # because |a2| >= 2^30 > |a1| whenever s2 != 0 (and exact trivially at
    # s2 == 0), and the residual e1 + a0 is an integer < 2^24, hence exact.
    # The final add then rounds the EXACT v once — matching the jnp path's
    # f64->f32 cast bit for bit (inv_scale is a power of two: exact).
    a2 = s2.astype(jnp.float32) * jnp.float32(float(1 << 30))
    a1 = s1.astype(jnp.float32) * jnp.float32(float(1 << 15))
    a0 = s0.astype(jnp.float32)
    t1 = a2 + a1
    e1 = a1 - (t1 - a2)
    val = t1 + (e1 + a0)
    out_ref[...] = val * jnp.float32(inv_scale)


@functools.partial(
    jax.jit, static_argnames=("n", "inv_scale", "block_b", "interpret")
)
def codec_decode_kernel_call(
    x_t, inv_t, m_col, half_col, *, n: int, inv_scale: float,
    block_b: int = 1024, interpret: bool = True,
):
    """x_t: (n+1, B) int32 summed channels -> (1, B) f32 gradients."""
    nch, B = x_t.shape
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, n=n, inv_scale=inv_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nch, block_b), lambda b: (0, b)),
            pl.BlockSpec((n, n), lambda b: (0, 0)),
            pl.BlockSpec((n, 1), lambda b: (0, 0)),
            pl.BlockSpec((6, 1), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.float32),
        interpret=interpret,
    )(x_t, inv_t, m_col, half_col)
