"""Shared in-kernel primitives for the RNS Pallas kernels.

TPU adaptation notes (DESIGN.md §3):

* Layout is **(n, B)** — channels on sublanes, batch on the 128-wide lane
  axis.  The paper parallelizes one conversion across channels; on TPU the
  VPU's width is better spent across batch elements, with the short channel
  axis resident in registers/sublanes.
* Modular reduction is **Barrett-via-f32**: ``q = floor(t * (1/m))`` with a
  single ±m correction pass.  With 15-bit moduli every intermediate product
  t < 2**30, the f32 quotient error is < 1/2, so one conditional add and one
  conditional subtract make the result exact.  This replaces integer
  division/remainder, which the VPU lowers slowly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["barrett_mod", "mrc_rows", "to_ma_rows"]


def barrett_mod(t, m, recip):
    """Exact t mod m for 0 <= t < 2**30, m < 2**15 (all int32, f32 recip)."""
    q = jnp.floor(t.astype(jnp.float32) * recip).astype(jnp.int32)
    r = t - q * m
    r = jnp.where(r < 0, r + m, r)
    r = jnp.where(r >= m, r - m, r)
    return r


def mrc_rows(w, inv_t, m, recip, *, n: int):
    """Alg. 2 on an (n, B) register tile.

    w:      (n, B) residues
    inv_t:  (n, n) transposed inverse table: inv_t[i, j] = m_j^{-1} mod m_i
    m:      (n, 1) moduli;  recip: (n, 1) f32 reciprocals
    Returns (n, B) mixed-radix digits.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def body(j, w):
        a_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=0)        # (1, B)
        inv_j = jax.lax.dynamic_slice_in_dim(inv_t, j, 1, axis=1)  # (n, 1)
        d = w - a_j
        d = jnp.where(d < 0, d + m, d)
        r = barrett_mod(d * inv_j, m, recip)
        return jnp.where(idx > j, r, w)

    return jax.lax.fori_loop(0, n - 1, body, w) if n > 1 else w


def to_ma_rows(digits, betas, ma: int):
    """Alg. 3 on an (n, B) digit tile -> (1, B) residues mod m_a.

    betas: (n, 1) partial products mod m_a.  Per-term reduction keeps the
    row-sum < n * m_a < 2**31.
    """
    recip = jnp.float32(1.0 / ma)
    terms = barrett_mod(digits * betas, jnp.int32(ma), recip)
    s = jnp.sum(terms, axis=0, keepdims=True)  # (1, B)
    return barrett_mod(s, jnp.int32(ma), recip)
