"""Pallas TPU kernels: dual-base Montgomery product and fused ladder step.

One Montgomery product MM(X, Y) on an (n, BLOCK_B) tile chains every RNS
primitive this framework has (core/montgomery.py documents the algebra):

    q      = x·y·(-N^{-1})    channel-wise in B       Barrett products
    digits = MRC(q)           Alg. 2 triangle          (mrc_rows)
    q'     = digits · betas   Alg. 3 dot -> B'         (_dot_rows)
    r'     = (x'y' + q'N)·M^{-1}  channel-wise in B'
    r      = extend(r')       MRC + dot back to B (+ redundant channels)

The ladder kernel fuses ONE exponent bit — two Montgomery products plus the
branchless square-and-multiply select — so the (n, B) operand tiles for
both bases stay in VMEM/registers across the whole bit instead of making
six HBM round-trips per extension.  Per-request moduli ``N`` arrive as DATA
rows (``neg``/``n_hi`` per batch column), not baked constants, so one
compiled kernel serves every modulus in a batch — that is what lets the
serve engine mix crypto requests with different ``N`` in the same slots.

Invariants (DESIGN.md §15): inputs < 2N per column ⟹ every intermediate
product < 2^30 (15-bit moduli, int32 lanes, exact Barrett-via-f32), both
MRC extensions are exact, and outputs are < 2N — so the fixed-width ladder
never wraps and matches the pure-jnp reference bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import barrett_mod, mrc_rows

__all__ = ["mont_mul_kernel_call", "mont_ladder_kernel_call"]


def _dot_rows(digits, betas, m, recip, *, n: int):
    """Alg. 3 dot against T arbitrary targets on (n, B) digit tiles.

    digits: (n, B); betas: (T, n) with betas[t, j] = (prod_{k<j} m_k) mod
    m_t; m/recip: (T, 1).  Returns (T, B) residues, each term Barrett-
    reduced so the running sum stays < 2m < 2**16.
    """
    zero = jnp.zeros((betas.shape[0], digits.shape[1]), jnp.int32)

    def body(j, acc):
        d_j = jax.lax.dynamic_slice_in_dim(digits, j, 1, axis=0)   # (1, B)
        b_j = jax.lax.dynamic_slice_in_dim(betas, j, 1, axis=1)    # (T, 1)
        s = acc + barrett_mod(d_j * b_j, m, recip)
        return jnp.where(s >= m, s - m, s)

    return jax.lax.fori_loop(0, n, body, zero)


def _mm_tile(xlo, xhi, ylo, yhi, neg, nhi, invt_lo, m_lo, betas_l2h,
             invt_hi, m_hi, betas_h2l, minv, *, n_lo: int, n_hi: int):
    """One Montgomery product on loaded tiles; returns (rlo, rhi).

    xlo/ylo: (nch_lo, B) — base channels first, then redundant; only the
    first n_lo rows feed q.  xhi/yhi: (n_hi, B).  neg: (n_lo, B) and
    nhi: (n_hi, B) are per-column data (the modulus N of each request).
    """
    r_lo = 1.0 / m_lo.astype(jnp.float32)
    r_hi = 1.0 / m_hi.astype(jnp.float32)
    mb, rb = m_lo[:n_lo], r_lo[:n_lo]
    q = barrett_mod(barrett_mod(xlo[:n_lo] * ylo[:n_lo], mb, rb) * neg,
                    mb, rb)
    qd = mrc_rows(q, invt_lo, mb, rb, n=n_lo)
    qp = _dot_rows(qd, betas_l2h, m_hi, r_hi, n=n_lo)          # (n_hi, B)
    t = barrett_mod(xhi * yhi, m_hi, r_hi) + barrett_mod(qp * nhi, m_hi, r_hi)
    t = jnp.where(t >= m_hi, t - m_hi, t)
    rhi = barrett_mod(t * minv, m_hi, r_hi)
    rd = mrc_rows(rhi, invt_hi, m_hi, r_hi, n=n_hi)
    rlo = _dot_rows(rd, betas_h2l, m_lo, r_lo, n=n_hi)         # (nch_lo, B)
    return rlo, rhi


def _mont_mul_kernel(xlo_ref, xhi_ref, ylo_ref, yhi_ref, neg_ref, nhi_ref,
                     invtlo_ref, mlo_ref, bl2h_ref, invthi_ref, mhi_ref,
                     bh2l_ref, minv_ref, olo_ref, ohi_ref, *,
                     n_lo: int, n_hi: int):
    rlo, rhi = _mm_tile(
        xlo_ref[...], xhi_ref[...], ylo_ref[...], yhi_ref[...],
        neg_ref[...], nhi_ref[...], invtlo_ref[...], mlo_ref[...],
        bl2h_ref[...], invthi_ref[...], mhi_ref[...], bh2l_ref[...],
        minv_ref[...], n_lo=n_lo, n_hi=n_hi)
    olo_ref[...] = rlo
    ohi_ref[...] = rhi


def _ladder_kernel(r0lo_ref, r0hi_ref, r1lo_ref, r1hi_ref, bit_ref,
                   neg_ref, nhi_ref, invtlo_ref, mlo_ref, bl2h_ref,
                   invthi_ref, mhi_ref, bh2l_ref, minv_ref,
                   o0lo_ref, o0hi_ref, o1lo_ref, o1hi_ref, *,
                   n_lo: int, n_hi: int):
    tables = (invtlo_ref[...], mlo_ref[...], bl2h_ref[...], invthi_ref[...],
              mhi_ref[...], bh2l_ref[...], minv_ref[...])
    neg, nhi = neg_ref[...], nhi_ref[...]
    r0lo, r0hi = r0lo_ref[...], r0hi_ref[...]
    r1lo, r1hi = r1lo_ref[...], r1hi_ref[...]
    k = bit_ref[...] == 0                                      # (1, B)
    t_lo, t_hi = _mm_tile(r0lo, r0hi, r1lo, r1hi, neg, nhi, *tables,
                          n_lo=n_lo, n_hi=n_hi)
    sqlo = jnp.where(k, r0lo, r1lo)
    sqhi = jnp.where(k, r0hi, r1hi)
    s_lo, s_hi = _mm_tile(sqlo, sqhi, sqlo, sqhi, neg, nhi, *tables,
                          n_lo=n_lo, n_hi=n_hi)
    o0lo_ref[...] = jnp.where(k, s_lo, t_lo)
    o0hi_ref[...] = jnp.where(k, s_hi, t_hi)
    o1lo_ref[...] = jnp.where(k, t_lo, s_lo)
    o1hi_ref[...] = jnp.where(k, t_hi, s_hi)


def _specs(nch_lo, n_lo, n_hi, block_b):
    blk = lambda r: pl.BlockSpec((r, block_b), lambda b: (0, b))
    tbl = lambda s: pl.BlockSpec(s, lambda b: (0, 0))
    tables = [tbl((n_lo, n_lo)), tbl((nch_lo, 1)), tbl((n_hi, n_lo)),
              tbl((n_hi, n_hi)), tbl((n_hi, 1)), tbl((nch_lo, n_hi)),
              tbl((n_hi, 1))]
    return blk, tables


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mont_mul_kernel_call(xlo_t, xhi_t, ylo_t, yhi_t, neg_t, nhi_t,
                         invt_lo, m_lo, betas_l2h, invt_hi, m_hi, betas_h2l,
                         minv, *, block_b: int = 256, interpret: bool = True):
    """One batched Montgomery product; operands channel-major (rows, B).

    Returns ``(olo (nch_lo, B), ohi (n_hi, B))``.
    """
    nch_lo, B = xlo_t.shape
    n_lo, n_hi = invt_lo.shape[0], xhi_t.shape[0]
    blk, tables = _specs(nch_lo, n_lo, n_hi, block_b)
    return pl.pallas_call(
        functools.partial(_mont_mul_kernel, n_lo=n_lo, n_hi=n_hi),
        grid=(B // block_b,),
        in_specs=[blk(nch_lo), blk(n_hi), blk(nch_lo), blk(n_hi),
                  blk(n_lo), blk(n_hi)] + tables,
        out_specs=[blk(nch_lo), blk(n_hi)],
        out_shape=[jax.ShapeDtypeStruct((nch_lo, B), jnp.int32),
                   jax.ShapeDtypeStruct((n_hi, B), jnp.int32)],
        interpret=interpret,
    )(xlo_t, xhi_t, ylo_t, yhi_t, neg_t, nhi_t,
      invt_lo, m_lo, betas_l2h, invt_hi, m_hi, betas_h2l, minv)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mont_ladder_kernel_call(r0lo_t, r0hi_t, r1lo_t, r1hi_t, bit_t,
                            neg_t, nhi_t, invt_lo, m_lo, betas_l2h,
                            invt_hi, m_hi, betas_h2l, minv, *,
                            block_b: int = 256, interpret: bool = True):
    """One fused ladder bit (two Montgomery products + select) per column.

    ``bit_t: (1, B)`` int32 exponent bits.  Returns the four updated tiles
    ``(o0lo, o0hi, o1lo, o1hi)``.
    """
    nch_lo, B = r0lo_t.shape
    n_lo, n_hi = invt_lo.shape[0], r0hi_t.shape[0]
    blk, tables = _specs(nch_lo, n_lo, n_hi, block_b)
    return pl.pallas_call(
        functools.partial(_ladder_kernel, n_lo=n_lo, n_hi=n_hi),
        grid=(B // block_b,),
        in_specs=[blk(nch_lo), blk(n_hi), blk(nch_lo), blk(n_hi), blk(1),
                  blk(n_lo), blk(n_hi)] + tables,
        out_specs=[blk(nch_lo), blk(n_hi), blk(nch_lo), blk(n_hi)],
        out_shape=[jax.ShapeDtypeStruct((nch_lo, B), jnp.int32),
                   jax.ShapeDtypeStruct((n_hi, B), jnp.int32),
                   jax.ShapeDtypeStruct((nch_lo, B), jnp.int32),
                   jax.ShapeDtypeStruct((n_hi, B), jnp.int32)],
        interpret=interpret,
    )(r0lo_t, r0hi_t, r1lo_t, r1hi_t, bit_t, neg_t, nhi_t,
      invt_lo, m_lo, betas_l2h, invt_hi, m_hi, betas_h2l, minv)
