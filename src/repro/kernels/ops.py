"""Public wrappers for the RNS Pallas kernels.

These present the same (..., n) channel-minor API as repro.core, and handle:
  * layout: transpose to the kernel-native (n, B) channel-major tiles,
  * padding: batch padded to the block size (pad values are benign — every
    kernel is elementwise/per-column in batch),
  * dispatch: ``interpret=True`` automatically off-TPU so the same call site
    runs the Mosaic kernel on TPU and the Python interpreter on CPU,
  * constraints: kernels require 15-bit (int32-lane) bases; wider bases fall
    back to the pure-jnp core implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.base import RNSBase

from .modmul import modmul_kernel_call
from .mrc import mrc_kernel_call
from .rns_compare import compare_kernel_call

__all__ = ["mrc_op", "modmul_op", "compare_op"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _flatten_batch(x):
    """(..., n) -> (B, n), plus a reconstructor."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _tables(base: RNSBase):
    if base.bits > 15:
        raise ValueError("Pallas kernels require bits<=15 (int32 lanes); "
                         "use repro.core for wider bases")
    inv_t = jnp.asarray(base.inv_tri_np.T, dtype=jnp.int32)        # (i, j)
    m_col = jnp.asarray(base.moduli_np[:, None], dtype=jnp.int32)  # (n, 1)
    return inv_t, m_col


def mrc_op(base: RNSBase, x, *, block_b: int = 512, interpret: bool | None = None):
    """Mixed-radix digits of ``x: (..., n)`` via the Pallas kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    flat, lead = _flatten_batch(x.astype(jnp.int32))
    xt, B = _pad_to(flat.T, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = mrc_kernel_call(xt, inv_t, m_col, block_b=block_b, interpret=interpret)
    return out[:, :B].T.reshape(*lead, base.n).astype(x.dtype)


def modmul_op(base: RNSBase, x, y, *, block_b: int = 1024, interpret: bool | None = None):
    """Channel-wise (x * y) mod m_i via the Pallas kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    _, m_col = _tables(base)
    fx, lead = _flatten_batch(x.astype(jnp.int32))
    fy, _ = _flatten_batch(y.astype(jnp.int32))
    xt, B = _pad_to(fx.T, block_b, axis=1)
    yt, _ = _pad_to(fy.T, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = modmul_kernel_call(xt, yt, m_col, block_b=block_b, interpret=interpret)
    return out[:, :B].T.reshape(*lead, base.n).astype(x.dtype)


def compare_op(
    base: RNSBase, x1, xa1, x2, xa2, *, block_b: int = 512, interpret: bool | None = None
):
    """Fused Algorithm 1: boolean (N1 >= N2) for batched operands.

    x1, x2: (..., n); xa1, xa2: (...,).
    """
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    betas_col = jnp.asarray(base.betas_ma_np[:, None], dtype=jnp.int32)
    f1, lead = _flatten_batch(x1.astype(jnp.int32))
    f2, _ = _flatten_batch(x2.astype(jnp.int32))
    a1 = xa1.astype(jnp.int32).reshape(1, -1)
    a2 = xa2.astype(jnp.int32).reshape(1, -1)
    x1t, B = _pad_to(f1.T, block_b, axis=1)
    x2t, _ = _pad_to(f2.T, block_b, axis=1)
    a1p, _ = _pad_to(a1, block_b, axis=1)
    a2p, _ = _pad_to(a2, block_b, axis=1)
    block_b = min(block_b, x1t.shape[1])
    out = compare_kernel_call(
        x1t, a1p, x2t, a2p, inv_t, m_col, betas_col,
        ma=base.ma, block_b=block_b, interpret=interpret,
    )
    return out[0, :B].reshape(lead).astype(bool)


def codec_decode_op(codec, summed, *, block_b: int = 1024,
                    interpret: bool | None = None):
    """Fused gradient-codec decode: summed channels (..., n+1) -> f32 mean
    gradient contribution (caller divides by world).  See codec_decode.py."""
    from .codec_decode import codec_decode_kernel_call

    base = codec.base
    if base.M >= 1 << 45:
        raise ValueError("codec decode kernel requires M < 2**45 (3 limbs)")
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    T = (base.M + 1) // 2
    M = base.M
    half_col = jnp.asarray(
        [[T & 0x7FFF], [(T >> 15) & 0x7FFF], [T >> 30],
         [M & 0x7FFF], [(M >> 15) & 0x7FFF], [M >> 30]], dtype=jnp.int32,
    )
    flat, lead = _flatten_batch(summed.astype(jnp.int32))
    xt, B = _pad_to(flat.T, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = codec_decode_kernel_call(
        xt, inv_t, m_col, half_col, n=base.n,
        inv_scale=1.0 / (1 << codec.frac_bits),
        block_b=block_b, interpret=interpret,
    )
    return out[0, :B].reshape(lead)
