"""Public wrappers for the RNS Pallas kernels.

These present the same (..., n) channel-minor API as repro.core, and handle:
  * layout: transpose to the kernel-native (n, B) channel-major tiles,
  * padding: batch padded to the block size (pad values are benign — every
    kernel is elementwise/per-column in batch),
  * dispatch: ``interpret=True`` automatically off-TPU so the same call site
    runs the Mosaic kernel on TPU and the Python interpreter on CPU — the
    default comes from the ONE resolver in core/dispatch.py
    (``interpret_default``), shared by every op here,
  * constraints: kernels require 15-bit (int32-lane) bases; wider bases fall
    back to the pure-jnp core implementations.

Every op also accepts ``RnsArray`` operands directly (core/array.py): pass
the typed array in place of the ``base, x[, xa]`` argument group and the
wrapper pulls the buffers/layout out itself.  ``modmul_op`` on packed
layouts then runs the kernel over ALL channels (each row reduces in its own
modulus — redundant channels included) and returns an ``RnsArray``.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.array import RnsArray
from repro.core.base import RNSBase
from repro.core.dispatch import interpret_default as _interpret_default

from .modmul import modmul_kernel_call
from .mont_ladder import mont_ladder_kernel_call, mont_mul_kernel_call
from .mrc import mrc_kernel_call
from .rns_compare import compare_kernel_call

__all__ = ["mrc_op", "modmul_op", "compare_op", "codec_encode_op",
           "codec_decode_op", "mont_mul_op", "mont_ladder_op"]


def _flatten_batch(x):
    """(..., n) -> (B, n), plus a reconstructor."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def _tables(base: RNSBase):
    if base.bits > 15:
        raise ValueError("Pallas kernels require bits<=15 (int32 lanes); "
                         "use repro.core for wider bases")
    inv_t = jnp.asarray(base.inv_tri_np.T, dtype=jnp.int32)        # (i, j)
    m_col = jnp.asarray(base.moduli_np[:, None], dtype=jnp.int32)  # (n, 1)
    return inv_t, m_col


def mrc_op(base, x=None, *, block_b: int = 512, interpret: bool | None = None):
    """Mixed-radix digits of ``x: (..., n)`` via the Pallas kernel.

    Also callable as ``mrc_op(arr)`` with an ``RnsArray`` — digits of the
    base channels, channels-last.
    """
    if isinstance(base, RnsArray):
        base, x = base.base, base.x
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    flat, lead = _flatten_batch(x.astype(jnp.int32))
    xt, B = _pad_to(flat.T, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = mrc_kernel_call(xt, inv_t, m_col, block_b=block_b, interpret=interpret)
    return out[:, :B].T.reshape(*lead, base.n).astype(x.dtype)


def modmul_op(base, x=None, y=None, *, block_b: int = 1024,
              interpret: bool | None = None):
    """Channel-wise (x * y) mod m_i via the Pallas kernel.

    Also callable as ``modmul_op(a, b)`` with two ``RnsArray`` operands of
    matching base/layout: the kernel then reduces EVERY channel in its own
    modulus (redundant rows included) and the result comes back typed.
    """
    arr = None
    if isinstance(base, RnsArray):
        arr, other = base, x
        if not isinstance(other, RnsArray):
            raise TypeError("modmul_op(a, b) needs both operands as RnsArray")
        other = arr._lift(other)  # validates matching base/layout/mb
        if arr.base.bits > 15:
            raise ValueError("Pallas kernels require bits<=15 (int32 lanes)")
        m_col = jnp.asarray(arr.channel_moduli[:, None], dtype=jnp.int32)
        x, y = arr.to_packed(), other.to_packed()
        nch = arr.n_channels
        base = arr.base
    else:
        _, m_col = _tables(base)
        nch = base.n
    interpret = _interpret_default() if interpret is None else interpret
    fx, lead = _flatten_batch(x.astype(jnp.int32))
    fy, _ = _flatten_batch(y.astype(jnp.int32))
    xt, B = _pad_to(fx.T, block_b, axis=1)
    yt, _ = _pad_to(fy.T, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = modmul_kernel_call(xt, yt, m_col, block_b=block_b, interpret=interpret)
    out = out[:, :B].T.reshape(*lead, nch).astype(x.dtype)
    if arr is not None:
        return RnsArray(
            out, base, layout=arr.layout,
            signed=arr.signed or other.signed, channel_axis=-1, mb=arr.mb,
        ).with_channel_axis(arr.channel_axis)
    return out


def compare_op(
    base, x1=None, xa1=None, x2=None, xa2=None, *, block_b: int = 512,
    interpret: bool | None = None
):
    """Fused Algorithm 1: boolean (N1 >= N2) for batched operands.

    x1, x2: (..., n); xa1, xa2: (...,).

    Also callable as ``compare_op(a, b)`` with two ``RnsArray`` operands
    (BASE_MA or RRNS layout — the m_a channel drives Theorem 1).
    """
    if isinstance(base, RnsArray):
        a, b = base, x1
        if not isinstance(b, RnsArray):
            raise TypeError("compare_op(a, b) needs both operands as "
                            "RnsArray")
        b = a._lift(b)  # validates matching base/layout/mb
        base, x1, xa1, x2, xa2 = a.base, a.x, a.xa, b.x, b.xa
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    betas_col = jnp.asarray(base.betas_ma_np[:, None], dtype=jnp.int32)
    f1, lead = _flatten_batch(x1.astype(jnp.int32))
    f2, _ = _flatten_batch(x2.astype(jnp.int32))
    a1 = xa1.astype(jnp.int32).reshape(1, -1)
    a2 = xa2.astype(jnp.int32).reshape(1, -1)
    x1t, B = _pad_to(f1.T, block_b, axis=1)
    x2t, _ = _pad_to(f2.T, block_b, axis=1)
    a1p, _ = _pad_to(a1, block_b, axis=1)
    a2p, _ = _pad_to(a2, block_b, axis=1)
    block_b = min(block_b, x1t.shape[1])
    out = compare_kernel_call(
        x1t, a1p, x2t, a2p, inv_t, m_col, betas_col,
        ma=base.ma, block_b=block_b, interpret=interpret,
    )
    return out[0, :B].reshape(lead).astype(bool)


def _auto_block(nelems: int, interpret: bool) -> int:
    """Default tile width: 1024 keeps compiled tiles VMEM-friendly on TPU;
    the interpreter has no VMEM and pays per grid step, so it takes the
    whole (padded) buffer as one tile."""
    return max(1, nelems) if interpret else 1024


def codec_decode_op(codec, summed, *, block_b: int | None = None,
                    interpret: bool | None = None,
                    channel_major: bool = False):
    """Fused gradient-codec decode: summed channels (..., nch) -> f32 mean
    gradient contribution (caller divides by world).  See codec_decode.py.
    Redundant channels beyond the base (m_a, and m_b on locate-and-correct
    codecs) ride along unread — the decode consumes base residues only.

    channel_major=True takes the kernel-native (nch, B) layout directly and
    returns (B,) — the zero-transpose path used by the bucketed pipeline.
    """
    from .codec_decode import codec_decode_kernel_call

    base = codec.base
    if base.M >= 1 << 45:
        raise ValueError("codec decode kernel requires M < 2**45 (3 limbs)")
    interpret = _interpret_default() if interpret is None else interpret
    inv_t, m_col = _tables(base)
    T = (base.M + 1) // 2
    M = base.M
    half_col = jnp.asarray(
        [[T & 0x7FFF], [(T >> 15) & 0x7FFF], [T >> 30],
         [M & 0x7FFF], [(M >> 15) & 0x7FFF], [M >> 30]], dtype=jnp.int32,
    )
    if channel_major:
        flat_t, lead = summed.astype(jnp.int32), None
    else:
        flat, lead = _flatten_batch(summed.astype(jnp.int32))
        flat_t = flat.T
    if block_b is None:
        block_b = _auto_block(flat_t.shape[1], interpret)
    xt, B = _pad_to(flat_t, block_b, axis=1)
    block_b = min(block_b, xt.shape[1])
    out = codec_decode_kernel_call(
        xt, inv_t, m_col, half_col, n=base.n,
        inv_scale=1.0 / (1 << codec.frac_bits),
        block_b=block_b, interpret=interpret,
    )
    return out[0, :B] if channel_major else out[0, :B].reshape(lead)


def codec_encode_op(codec, g, *, block_b: int | None = None,
                    interpret: bool | None = None,
                    channel_major: bool = False):
    """Fused gradient-codec encode: f32 tensor (...,) -> packed int32
    residues (..., nch), bitwise identical to ``GradCodec.encode`` (which
    needs global x64; this kernel does not).  nch = n base channels plus the
    codec's redundant moduli (m_a alone, or m_a + m_b when the codec was
    built with ``correct=True``).  See codec_encode.py.

    channel_major=True returns the kernel-native (nch, B) layout for a
    flat (B,) input — the zero-transpose path used by the bucketed
    pipeline (the decode kernel consumes it directly).
    """
    from .codec_encode import codec_encode_kernel_call

    base = codec.base
    if base.M >= 1 << 45:
        raise ValueError("codec encode kernel requires M < 2**45 "
                         "(qmax limbs must fit 2x15-bit + int32 high part)")
    if base.bits > 15:
        raise ValueError("Pallas kernels require bits<=15 (int32 lanes); "
                         "use GradCodec.encode for wider bases")
    interpret = _interpret_default() if interpret is None else interpret
    reds = codec.redundant  # (m_a,) or (m_a, m_b)
    m_all = jnp.asarray(
        np.concatenate([base.moduli_np, reds])[:, None], dtype=jnp.int32
    )
    pow15 = jnp.asarray(
        [[(1 << 15) % int(m)] for m in tuple(base.moduli) + reds],
        dtype=jnp.int32,
    )
    # negative-embedding shift per row: base rows 0 (m_i | M), redundant
    # rows M mod m_r
    off = jnp.asarray(
        [[0]] * base.n + [[base.M % r] for r in reds], dtype=jnp.int32
    )
    lead = g.shape if not channel_major else None
    row = g.astype(jnp.float32).reshape(1, -1)
    if block_b is None:
        block_b = _auto_block(row.shape[1], interpret)
    gt, B = _pad_to(row, block_b, axis=1)
    block_b = min(block_b, gt.shape[1])
    out = codec_encode_kernel_call(
        gt, m_all, pow15, off, scale=float(1 << codec.frac_bits),
        qh=codec.qmax >> 15, ql=codec.qmax & 0x7FFF,
        block_b=block_b, interpret=interpret,
    )
    if channel_major:
        return out[:, :B]
    return out[:, :B].T.reshape(*lead, len(m_all))


# ------------------------------------------------- Montgomery (dual-base)


@functools.lru_cache(maxsize=None)
def _mont_tables_np(baseB: RNSBase, baseBp: RNSBase,
                    lo_targets: tuple[int, ...]):
    """Host tables for the dual-base Montgomery kernels, cached per base
    pair + B-side channel layout (N-independent)."""
    from repro.core.montgomery import minv_residues

    for b in (baseB, baseBp):
        if b.bits > 15:
            raise ValueError("Pallas kernels require bits<=15 (int32 "
                             "lanes); use repro.core for wider bases")
    hi_t = tuple(int(m) for m in baseBp.moduli)
    return (
        np.asarray(baseB.inv_tri_np.T, np.int32),             # (n, n)
        np.asarray(lo_targets, np.int32)[:, None],            # (nch_lo, 1)
        np.asarray(baseB.betas_for(hi_t), np.int32),          # (n', n)
        np.asarray(baseBp.inv_tri_np.T, np.int32),            # (n', n')
        np.asarray(hi_t, np.int32)[:, None],                  # (n', 1)
        np.asarray(baseBp.betas_for(lo_targets), np.int32),   # (nch_lo, n')
        np.asarray(minv_residues(baseB, hi_t), np.int32)[:, None],
    )


def _mont_prep(d, lead, block_b):
    """DualRep -> padded channel-major (nch_lo, B) / (n_hi, B) tiles."""
    lo = jnp.broadcast_to(d.lo._cl().astype(jnp.int32),
                          (*lead, d.lo.n_channels))
    hi = jnp.broadcast_to(d.hi._cl().astype(jnp.int32),
                          (*lead, d.hi.base.n))
    lo_t, B = _pad_to(lo.reshape(-1, lo.shape[-1]).T, block_b, axis=1)
    hi_t, _ = _pad_to(hi.reshape(-1, hi.shape[-1]).T, block_b, axis=1)
    return lo_t, hi_t, B


def _mont_consts_prep(x, neg, n_hi, lead, block_b):
    neg = jnp.broadcast_to(jnp.asarray(neg, jnp.int32),
                           (*lead, x.lo.base.n))
    nhi = jnp.broadcast_to(jnp.asarray(n_hi, jnp.int32),
                           (*lead, x.hi.base.n))
    neg_t, _ = _pad_to(neg.reshape(-1, neg.shape[-1]).T, block_b, axis=1)
    nhi_t, _ = _pad_to(nhi.reshape(-1, nhi.shape[-1]).T, block_b, axis=1)
    return neg_t, nhi_t


def _mont_wrap(x, out_lo, out_hi, B, lead):
    from repro.core.montgomery import DualRep

    lo = out_lo[:, :B].T.reshape(*lead, -1).astype(x.lo.dtype)
    hi = out_hi[:, :B].T.reshape(*lead, -1).astype(x.hi.dtype)
    return DualRep(x.lo._wrap(lo, signed=False),
                   x.hi._wrap(hi, signed=False))


def mont_mul_op(x, y, neg, n_hi, *, block_b: int = 256,
                interpret: bool | None = None):
    """Batched Montgomery product MM(X, Y) via the fused Pallas kernel.

    ``x``/``y`` are ``DualRep`` operands (core/montgomery.py); ``neg`` /
    ``n_hi`` are the per-``N`` channel rows from ``mont_consts`` — arrays,
    not constants, broadcast against the batch.  Bitwise-identical to the
    pure-jnp ``_mont_mul_jnp`` reference.
    """
    interpret = _interpret_default() if interpret is None else interpret
    lo_targets = tuple(int(m) for m in x.lo.channel_moduli)
    tables = [jnp.asarray(t) for t in
              _mont_tables_np(x.lo.base, x.hi.base, lo_targets)]
    lead = jnp.broadcast_shapes(x.lo.shape, y.lo.shape,
                                jnp.shape(neg)[:-1], jnp.shape(n_hi)[:-1])
    xlo, xhi, B = _mont_prep(x, lead, block_b)
    ylo, yhi, _ = _mont_prep(y, lead, block_b)
    neg_t, nhi_t = _mont_consts_prep(x, neg, n_hi, lead, block_b)
    block_b = min(block_b, xlo.shape[1])
    out_lo, out_hi = mont_mul_kernel_call(
        xlo, xhi, ylo, yhi, neg_t, nhi_t, *tables,
        block_b=block_b, interpret=interpret)
    return _mont_wrap(x, out_lo, out_hi, B, lead)


def mont_ladder_op(r0, r1, bit, neg, n_hi, *, block_b: int = 256,
                   interpret: bool | None = None):
    """One fused Montgomery-ladder bit: two products + branchless select
    in a single kernel launch.  Returns the updated ``(r0, r1)`` pair."""
    interpret = _interpret_default() if interpret is None else interpret
    lo_targets = tuple(int(m) for m in r0.lo.channel_moduli)
    tables = [jnp.asarray(t) for t in
              _mont_tables_np(r0.lo.base, r0.hi.base, lo_targets)]
    lead = jnp.broadcast_shapes(r0.lo.shape, r1.lo.shape, jnp.shape(bit),
                                jnp.shape(neg)[:-1], jnp.shape(n_hi)[:-1])
    r0lo, r0hi, B = _mont_prep(r0, lead, block_b)
    r1lo, r1hi, _ = _mont_prep(r1, lead, block_b)
    neg_t, nhi_t = _mont_consts_prep(r0, neg, n_hi, lead, block_b)
    bit_b = jnp.broadcast_to(jnp.asarray(bit, jnp.int32), lead)
    bit_t, _ = _pad_to(bit_b.reshape(1, -1), block_b, axis=1)
    block_b = min(block_b, r0lo.shape[1])
    o0lo, o0hi, o1lo, o1hi = mont_ladder_kernel_call(
        r0lo, r0hi, r1lo, r1hi, bit_t, neg_t, nhi_t, *tables,
        block_b=block_b, interpret=interpret)
    return (_mont_wrap(r0, o0lo, o0hi, B, lead),
            _mont_wrap(r0, o1lo, o1hi, B, lead))
