"""Pallas TPU kernels for the RNS hot spots (validated in interpret mode).

Kernels: mrc (Alg. 2), modmul (ring product), rns_compare (fused Alg. 1).
Each has a pure-jnp oracle in ref.py and a public wrapper in ops.py.
"""
from .ops import (  # noqa: F401
    codec_decode_op,
    codec_encode_op,
    compare_op,
    modmul_op,
    mrc_op,
)
from .ref import ref_mrc, ref_modmul, ref_compare, ref_to_ma  # noqa: F401
