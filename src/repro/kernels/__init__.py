"""Pallas TPU kernels for the RNS hot spots (validated in interpret mode).

Kernels: mrc (Alg. 2), modmul (ring product), rns_compare (fused Alg. 1),
mont_ladder (dual-base Montgomery product + fused ladder bit).
Each has a pure-jnp oracle (ref.py or core/montgomery.py) and a public
wrapper in ops.py.
"""
from .ops import (  # noqa: F401
    codec_decode_op,
    codec_encode_op,
    compare_op,
    modmul_op,
    mont_ladder_op,
    mont_mul_op,
    mrc_op,
)
from .ref import ref_mrc, ref_modmul, ref_compare, ref_to_ma  # noqa: F401
