"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the straight-line definition of the math the kernel must
reproduce bit-exactly (integer kernels ⇒ exact equality, not allclose).
They delegate to the core library, which is itself validated against Python
big-int arithmetic in tests/test_core_rns.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import arith
from repro.core.base import RNSBase
from repro.core.compare import rns_compare_ge
from repro.core.convert import to_ma
from repro.core.mrc import mrc

__all__ = ["ref_modmul", "ref_mrc", "ref_compare", "ref_to_ma"]


def ref_modmul(base: RNSBase, x, y):
    """(..., n) channel-wise modular product."""
    return arith.mul(base, x, y)


def ref_mrc(base: RNSBase, x):
    """(..., n) residues -> mixed-radix digits (Alg. 2)."""
    return mrc(base, x)


def ref_to_ma(base: RNSBase, digits):
    """(..., n) digits -> X mod m_a (Alg. 3)."""
    return to_ma(base, digits)


def ref_compare(base: RNSBase, x1, xa1, x2, xa2):
    """Alg. 1 verdict tensor (bool)."""
    return rns_compare_ge(base, x1, xa1, x2, xa2)
