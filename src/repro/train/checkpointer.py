"""Policy-driven async checkpointer with RRNS repair-on-restore.

DESIGN.md §14.  Three layers:

1. **Policy** — ``SavePolicy`` combines overlapping step intervals
   (levanter-style ``every@until`` schedules, e.g. save often early, less
   often late) with a wall-clock interval; ``parse_policy`` reads the
   ``--ckpt-policy`` grammar (``"2@10,5,30s"``).

2. **Checkpointer** — ONE background writer thread fed by a bounded queue:
   ``maybe_save`` snapshots the tree to host and enqueues (blocking when
   the queue is full, so saves can overlap training but never pile up
   unboundedly); writer-thread exceptions are held and re-raised from the
   next ``wait()`` / ``close()`` / ``maybe_save()``, never dropped.  Each
   commit is write-to-``step_<N>.tmp`` + fsync + atomic rename
   (checkpoint.commit_dir), followed by retention GC (``keep`` newest).

3. **RRNS shard format** — each leaf is stored as the RRNS codeword of its
   raw bytes: the byte buffer, padded to a multiple of 4, is read as
   uint32 limbs ``q < 2**32``, and the wire file ``i.rns.npy`` holds
   ``wire[c, j] = q_j mod m_c`` for the 3 base + 2 redundant channels of
   ``GradCodec.make(world=1, correct=True)`` (int32, channel-major).
   Since ``q < 2**32 << qmax ~ 2**44`` the signed embedding is the
   identity and the encoding is LOSSLESS — restore decodes by CRT over
   the base channels and checks a sha256 content fingerprint end-to-end.
   On mismatch, ``fault.repair_packed`` locates and rebuilds the single
   corrupted channel per element (a bit flip anywhere in the file damages
   exactly one ``(channel, element)`` residue); multi-channel damage
   refuses (verdict -2) and restore falls back to the next restorable
   step.  Storage cost: 5 int32 channels per uint32 word = 5x — the price
   of single-channel self-healing without a second replica.

Crash injection for the kill-and-resume harness: set
``REPRO_CKPT_CRASH_STEP=<n>`` (and optionally
``REPRO_CKPT_CRASH_FILES=<k>``, default 1) and the writer SIGKILLs its own
process after the k-th leaf file of step n is written — before the
manifest and the atomic rename, leaving a torn ``step_<n>.tmp`` that
discovery never sees.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import queue
import shutil
import signal
import threading
import time

import jax
import numpy as np

from repro.core.base import RNSBase
from repro.dist.fault import repair_packed, tensor_fingerprint
from repro.dist.grad_codec import GradCodec
from repro.train.checkpoint import _flatten, _write_fsync, commit_dir

__all__ = [
    "StepInterval", "SavePolicy", "parse_policy",
    "CheckpointCorrupt", "ckpt_codec",
    "write_step_dir", "read_step_dir",
    "discover_steps", "discover_latest",
    "inject_channel_corruption", "Checkpointer",
]

FORMAT = "rrns-v1"
CRASH_STEP_ENV = "REPRO_CKPT_CRASH_STEP"
CRASH_FILES_ENV = "REPRO_CKPT_CRASH_FILES"


class CheckpointCorrupt(IOError):
    """A step directory whose damage exceeds single-channel repair —
    truncated/unloadable wire file, verdict -2 elements, or a content
    fingerprint that still mismatches after repair."""


# ---------------------------------------------------------------------------
# save policy


@dataclasses.dataclass(frozen=True)
class StepInterval:
    """Save every ``every`` steps while ``step <= until`` (None = forever)."""

    every: int
    until: int | None = None


@dataclasses.dataclass(frozen=True)
class SavePolicy:
    """Overlapping step-based and time-based save schedules.

    ``intervals`` are consulted in order: the FIRST whose ``until`` covers
    the step decides the step cadence (so ``2@10,5`` = every 2 steps up to
    step 10, every 5 after).  ``every_seconds`` fires independently of the
    step schedule — whichever is due first wins.

    >>> p = parse_policy("2@10,5,30s")
    >>> [s for s in range(1, 21) if p.step_due(s)]
    [2, 4, 6, 8, 10, 15, 20]
    >>> p.every_seconds
    30.0
    >>> p.time_due(now=61.0, last=30.0), p.time_due(now=40.0, last=30.0)
    (True, False)
    """

    intervals: tuple[StepInterval, ...] = ()
    every_seconds: float | None = None

    def step_due(self, step: int) -> bool:
        if step <= 0:
            return False
        for iv in self.intervals:
            if iv.until is None or step <= iv.until:
                return step % iv.every == 0
        return False

    def time_due(self, *, now: float, last: float) -> bool:
        return (self.every_seconds is not None
                and now - last >= self.every_seconds)


def parse_policy(spec) -> SavePolicy:
    """Parse the ``--ckpt-policy`` grammar: comma-separated terms, each
    ``N`` (every N steps), ``N@M`` (every N steps up to step M), ``Ns`` /
    ``Nm`` (every N seconds / minutes of wall clock; at most one).

    >>> parse_policy("5")
    SavePolicy(intervals=(StepInterval(every=5, until=None),), every_seconds=None)
    >>> parse_policy("45s").every_seconds
    45.0
    >>> parse_policy("2@10,5").intervals
    (StepInterval(every=2, until=10), StepInterval(every=5, until=None))
    >>> parse_policy("0")
    Traceback (most recent call last):
        ...
    ValueError: save interval must be >= 1 step: '0'
    """
    if isinstance(spec, SavePolicy):
        return spec
    intervals: list[StepInterval] = []
    secs = None
    for term in str(spec).split(","):
        term = term.strip()
        if not term:
            continue
        if term[-1] in "sm" and term[:-1]:
            if secs is not None:
                raise ValueError(f"more than one time term in policy {spec!r}")
            secs = float(term[:-1]) * (60.0 if term[-1] == "m" else 1.0)
            if secs <= 0:
                raise ValueError(f"time interval must be > 0: {term!r}")
            continue
        every, at, until = term.partition("@")
        if at and not until:
            raise ValueError(f"dangling '@' in policy term {term!r}")
        iv = StepInterval(int(every), int(until) if until else None)
        if iv.every < 1:
            raise ValueError(f"save interval must be >= 1 step: {term!r}")
        intervals.append(iv)
    # bounded intervals first, in increasing reach, so step_due's first
    # covering interval is the most specific one
    intervals.sort(key=lambda iv: (iv.until is None, iv.until or 0))
    if sum(iv.until is None for iv in intervals) > 1:
        raise ValueError(f"more than one unbounded step term in {spec!r}")
    return SavePolicy(tuple(intervals), secs)


# ---------------------------------------------------------------------------
# RRNS leaf wire format


@functools.lru_cache(maxsize=None)
def ckpt_codec() -> GradCodec:
    """The checkpoint codec: world=1 RRNS (3 base + m_a + m_b channels),
    jnp path (repair runs on whatever host/device is around)."""
    return GradCodec.make(world=1, correct=True, fused=False)


@functools.lru_cache(maxsize=None)
def _codec_for(moduli: tuple, ma: int, mb: int, bits: int) -> GradCodec:
    return GradCodec(base=RNSBase(moduli=moduli, ma=ma, bits=bits),
                     frac_bits=16, world=1, fused=False, mb=mb)


def codec_from_manifest(manifest: dict) -> GradCodec:
    """Rebuild the exact codec a manifest's wire files were written under —
    checkpoints stay readable if the default codec ever changes."""
    c = manifest["codec"]
    return _codec_for(tuple(c["moduli"]), c["ma"], c["mb"], c["bits"])


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered names like bfloat16

        return np.dtype(getattr(ml_dtypes, name))


def _all_moduli(codec: GradCodec) -> np.ndarray:
    return np.array(tuple(codec.base.moduli) + codec.redundant,
                    dtype=np.int64)


def leaf_to_wire(codec: GradCodec, arr) -> np.ndarray:
    """Lossless RRNS codeword of one host array's raw bytes.

    >>> codec = ckpt_codec()
    >>> w = leaf_to_wire(codec, np.arange(3, dtype=np.float32))
    >>> w.shape, w.dtype                       # 5 channels, 3 uint32 limbs
    ((5, 3), dtype('int32'))
    >>> a = wire_to_leaf(codec, w, "float32", (3,), 12)
    >>> a.tolist()
    [0.0, 1.0, 2.0]
    """
    a = np.ascontiguousarray(np.asarray(arr))
    raw = a.tobytes()
    raw += b"\x00" * ((-len(raw)) % 4)
    limbs = np.frombuffer(raw, dtype="<u4").astype(np.int64)
    return (limbs[None, :] % _all_moduli(codec)[:, None]).astype(np.int32)


def wire_to_leaf(codec: GradCodec, wire: np.ndarray, dtype, shape,
                 nbytes: int) -> np.ndarray:
    """CRT-decode a wire codeword back to the original array (base
    channels only — the redundant rows are for locate-and-correct)."""
    mods = [int(m) for m in codec.base.moduli]
    M = int(codec.base.M)
    acc = np.zeros(wire.shape[1], dtype=np.int64)
    for c, m in enumerate(mods):
        Mi = M // m
        inv = pow(Mi % m, -1, m)
        # t < m < 2**15 and t*Mi < M ~ 2**45: three terms stay in int64
        acc += ((wire[c].astype(np.int64) * inv) % m) * Mi
    q = acc % M
    raw = (q & 0xFFFFFFFF).astype("<u4").tobytes()[:nbytes]
    return np.frombuffer(raw, dtype=_np_dtype(str(dtype))).reshape(
        tuple(shape)).copy()


# ---------------------------------------------------------------------------
# step-dir IO


def _maybe_crash(step: int, files_written: int) -> None:
    want = os.environ.get(CRASH_STEP_ENV)
    if want is None or int(want) != step:
        return
    if files_written >= int(os.environ.get(CRASH_FILES_ENV, "1")):
        os.kill(os.getpid(), signal.SIGKILL)  # torn save, by design


def write_step_dir(ckpt_dir: str, step: int, tree, *,
                   extra: dict | None = None) -> str:
    """Atomic RRNS-format save of a pytree: ``step_<N>/{manifest.json,
    0.rns.npy, ...}`` committed by fsync + rename."""
    names, leaves, _ = _flatten(tree)
    # np.asarray, NOT ascontiguousarray: the latter promotes 0-d arrays to
    # (1,), which would round-trip scalars with the wrong rank
    host = [np.asarray(l) for l in leaves]
    codec = ckpt_codec()
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    metas = []
    for i, arr in enumerate(host):
        wire = leaf_to_wire(codec, arr)
        _write_fsync(os.path.join(tmp, f"{i}.rns.npy"),
                     lambda f, w=wire: np.save(f, w))
        _maybe_crash(step, i + 1)
        metas.append({
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "nbytes": arr.nbytes,
            "sha": tensor_fingerprint(arr),
        })
    manifest = {
        "format": FORMAT,
        "step": step,
        "names": names,
        "leaves": metas,
        "codec": {
            "moduli": [int(m) for m in codec.base.moduli],
            "ma": int(codec.base.ma),
            "mb": int(codec.mb),
            "bits": int(codec.base.bits),
        },
        "extra": extra or {},
    }
    _write_fsync(os.path.join(tmp, "manifest.json"),
                 lambda f: f.write(json.dumps(manifest).encode()))
    commit_dir(tmp, final)
    return final


def _read_manifest(path: str) -> dict:
    mp = os.path.join(path, "manifest.json")
    if not os.path.exists(mp):
        raise FileNotFoundError(f"no manifest under {path} (torn save?)")
    with open(mp) as f:
        return json.load(f)


def read_step_dir(path: str):
    """Load + verify + repair one RRNS step dir.

    Returns ``(manifest, {name: host array}, report)`` with ``report``
    counting ``{"leaves", "repaired_leaves", "repaired_elements",
    "unrecoverable"}``.  Raises FileNotFoundError for a torn save and
    CheckpointCorrupt when any leaf is beyond single-channel repair —
    callers fall back to the next restorable step.

    Legacy ``fault.load_step`` directories (plain ``.npy`` + sha
    fingerprints, no repair possible) are read transparently.
    """
    manifest = _read_manifest(path)
    if manifest.get("format") != FORMAT:
        from repro.dist.fault import load_step

        manifest, flat = load_step(path)
        return manifest, flat, {"leaves": len(flat), "repaired_leaves": 0,
                                "repaired_elements": 0, "unrecoverable": 0}
    codec = codec_from_manifest(manifest)
    report = {"leaves": len(manifest["names"]), "repaired_leaves": 0,
              "repaired_elements": 0, "unrecoverable": 0}
    flat = {}
    for i, (name, meta) in enumerate(zip(manifest["names"],
                                         manifest["leaves"])):
        fp = os.path.join(path, f"{i}.rns.npy")
        if not os.path.exists(fp):
            raise FileNotFoundError(f"{fp} missing (torn save?)")
        try:
            wire = np.load(fp)
        except Exception as e:  # truncated / mangled file body
            raise CheckpointCorrupt(f"{fp} unloadable: {e}") from e
        n_limbs = (meta["nbytes"] + 3) // 4
        if wire.shape != (codec.n_channels, n_limbs):
            raise CheckpointCorrupt(
                f"{fp} has shape {wire.shape}, expected "
                f"{(codec.n_channels, n_limbs)} (truncated?)")
        arr = wire_to_leaf(codec, wire, meta["dtype"], meta["shape"],
                           meta["nbytes"])
        if tensor_fingerprint(arr) == meta["sha"]:
            flat[name] = arr  # fast path: clean leaf, no repair pass
            continue
        import jax.numpy as jnp

        typed = codec.as_array(jnp.asarray(wire), channel_major=True)
        fixed, rep = repair_packed(codec, typed, wraps=0)
        if rep["unrecoverable"]:
            report["unrecoverable"] += rep["unrecoverable"]
            raise CheckpointCorrupt(
                f"leaf {name!r} of {path}: {rep['unrecoverable']} "
                f"element(s) with multi-channel damage — refusing "
                f"(falling back beats miscorrecting)")
        arr = wire_to_leaf(codec, np.asarray(fixed.residues), meta["dtype"],
                           meta["shape"], meta["nbytes"])
        if tensor_fingerprint(arr) != meta["sha"]:
            raise CheckpointCorrupt(
                f"leaf {name!r} of {path} fails its content fingerprint "
                f"even after repair")
        report["repaired_leaves"] += 1
        report["repaired_elements"] += rep["repaired"]
        flat[name] = arr
    return manifest, flat, report


def discover_steps(ckpt_dir: str) -> list[int]:
    """Committed step numbers under ``ckpt_dir``, ascending (``.tmp``
    remnants and non-checkpoint entries ignored)."""
    if not ckpt_dir or not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_", 1)[1]))
            except ValueError:
                continue
    return sorted(steps)


def discover_latest(ckpt_dir: str) -> int | None:
    """Newest committed step number (committed != verified: restore still
    walks backwards past corrupt steps)."""
    steps = discover_steps(ckpt_dir)
    return steps[-1] if steps else None


def inject_channel_corruption(path: str, *, leaf: int = 0,
                              channels=(0,), index: int = 0,
                              delta: int = 1) -> None:
    """Fault injection: modular-bump residues of one wire element in a
    committed step dir — each channel in ``channels`` moves by ``delta``
    mod its modulus, staying a syntactically valid residue.  One channel
    demonstrates locate-and-correct; two BASE channels (e.g. ``(0, 1)``)
    demonstrate the multi-channel refuse path."""
    manifest = _read_manifest(path)
    codec = codec_from_manifest(manifest)
    mods = _all_moduli(codec)
    fp = os.path.join(path, f"{leaf}.rns.npy")
    wire = np.load(fp)
    for c in channels:
        wire[c, index] = (int(wire[c, index]) + delta) % int(mods[c])
    np.save(fp, wire)


# ---------------------------------------------------------------------------
# the Checkpointer


class Checkpointer:
    """Background-threaded, policy-driven, self-healing checkpoint writer.

    One writer thread consumes a bounded queue of host-snapshotted trees;
    ``maybe_save`` is the train-loop hook (cheap no-op when the policy is
    not due).  Writer errors surface on the next ``wait()`` / ``close()``
    / ``maybe_save()`` — a failed save can never vanish silently.  After
    every commit, retention GC prunes to the ``keep`` newest steps.

    Use as a context manager; ``close()`` drains the queue and joins the
    thread.
    """

    def __init__(self, ckpt_dir: str, policy="10", *, keep: int | None = None,
                 queue_size: int = 2):
        if keep is not None and keep < 1:
            raise ValueError("keep must be >= 1 (or None for no GC)")
        self.dir = ckpt_dir
        self.policy = parse_policy(policy)
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._sweep_tmp()
        self._q: queue.Queue = queue.Queue(maxsize=max(1, queue_size))
        self._error: BaseException | None = None
        self._error_lock = threading.Lock()
        self._last_time = time.monotonic()
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="ckpt-writer")
        self._thread.start()

    # -- lifecycle --------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _sweep_tmp(self) -> None:
        """Clear torn ``step_*.tmp`` remnants of a crashed predecessor
        (single-writer protocol: nothing else may be writing here)."""
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, extra = item
            try:
                write_step_dir(self.dir, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:
                with self._error_lock:
                    if self._error is None:  # first failure wins
                        self._error = e
            finally:
                self._q.task_done()

    def _check_error(self) -> None:
        with self._error_lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # -- saving -----------------------------------------------------------

    def maybe_save(self, step: int, tree, *, extra: dict | None = None,
                   force: bool = False) -> bool:
        """Save iff the policy says ``step`` (or the wall clock) is due.
        Returns True when a save was enqueued."""
        self._check_error()
        now = time.monotonic()
        if not (force or self.policy.step_due(step)
                or self.policy.time_due(now=now, last=self._last_time)):
            return False
        self._last_time = now
        self._enqueue(step, tree, extra)
        return True

    def save(self, step: int, tree, *, extra: dict | None = None) -> None:
        """Unconditional async save (policy bypassed)."""
        self._check_error()
        self._last_time = time.monotonic()
        self._enqueue(step, tree, extra)

    def _enqueue(self, step, tree, extra) -> None:
        if self._closed:
            raise RuntimeError("Checkpointer is closed")
        # snapshot to host NOW: the training loop may mutate/donate these
        # buffers the moment we return
        names_leaves = _flatten(tree)
        host = [np.asarray(l) for l in names_leaves[1]]
        host_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), host)
        self._q.put((step, host_tree, extra))  # blocks when queue is full

    def wait(self) -> None:
        """Block until every enqueued save has committed; re-raise the
        first writer error if any save failed."""
        self._q.join()
        self._check_error()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._thread.join()
        self._check_error()

    def _gc(self) -> None:
        if self.keep is None:
            return
        for s in discover_steps(self.dir)[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------

    def restore(self, abstract_tree=None, shardings=None, *,
                step: int | None = None):
        return restore(self.dir, abstract_tree, shardings, step=step)


def restore(ckpt_dir: str, abstract_tree=None, shardings=None, *,
            step: int | None = None):
    """Restore the newest repairable step (or exactly ``step``).

    Walks committed steps newest-first; a torn, truncated, or
    multi-channel-damaged step is SKIPPED (counted in the report) and the
    walk falls back to the next one.  Single-channel damage is repaired in
    stride via the RRNS codeword (read_step_dir).

    ``abstract_tree`` (a pytree of ShapeDtypeStructs or arrays) fixes the
    structure; None rebuilds a nested dict from the saved ``a/b/c`` leaf
    names (dict-only trees).  ``shardings`` — a matching pytree of
    NamedShardings — device_puts each host array onto the CURRENT mesh,
    which is what makes restore elastic: the checkpoint stores full host
    arrays, so a ZeRO-1 state saved under one mesh reshards onto another.

    Returns ``(tree, step, extra, report)``; raises FileNotFoundError when
    nothing under ``ckpt_dir`` is restorable.
    """
    candidates = ([step] if step is not None
                  else list(reversed(discover_steps(ckpt_dir))))
    skipped = 0
    last_err: Exception | None = None
    for s in candidates:
        path = os.path.join(ckpt_dir, f"step_{s}")
        try:
            manifest, flat, report = read_step_dir(path)
        except (FileNotFoundError, CheckpointCorrupt, OSError,
                ValueError, KeyError) as e:
            if step is not None:
                raise
            skipped += 1
            last_err = e
            continue
        report = dict(report, steps_skipped=skipped)
        if abstract_tree is None:
            tree = _nest(manifest["names"], flat)
        else:
            names, _, _ = _flatten(abstract_tree)
            if names != manifest["names"]:
                raise ValueError(
                    "checkpoint tree mismatch: "
                    f"{set(names) ^ set(manifest['names'])}")
            arrays = [flat[k] for k in names]
            if shardings is not None:
                sh = jax.tree_util.tree_leaves(
                    shardings, is_leaf=lambda x: hasattr(x, "spec"))
                arrays = [jax.device_put(a, s_) for a, s_ in zip(arrays, sh)]
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(abstract_tree), arrays)
        return tree, manifest["step"], manifest.get("extra", {}), report
    detail = f" (skipped {skipped}: {last_err})" if skipped else ""
    raise FileNotFoundError(
        f"no restorable checkpoint under {ckpt_dir}{detail}")


def _nest(names: list[str], flat: dict) -> dict:
    tree: dict = {}
    for name in names:
        parts = name.split("/")
        d = tree
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = flat[name]
    return tree
