"""AdamW with global-norm clipping — pure-pytree implementation.

The optimizer update is elementwise, so moment tensors may carry ANY
sharding; giving them the ZeRO-1 specs (dist/sharding.opt_state_specs)
makes XLA materialize the reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    decay_steps: int = 10_000


def adamw_init(params, *, master: bool = False):
    """master=True keeps an f32 master copy in the optimizer state — the
    standard mixed-precision layout when params are bf16.  With ZeRO-1 specs
    the master/moments shard over 'data', so per-device optimizer memory is
    params*12/world instead of params*8 + f32 params."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    st = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        st["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return st


def _schedule(cfg: AdamWConfig, step):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup)
    prog = jnp.clip(
        (s - cfg.warmup) / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup, warm, 0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)))
            for l in jax.tree_util.tree_leaves(tree)
        )
    )


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, *,
                 grad_decode=None):
    """``grad_decode``, when given, maps the raw ``grads`` argument to the
    parameter-shaped gradient pytree before any use.  This is the seam the
    RNS gradient codec plugs into: the train step hands over the post-psum
    packed channel buffer and the fused Pallas decode (one HBM round-trip)
    runs HERE, at the optimizer boundary — the transport stays integer all
    the way to the update (DESIGN.md §9)."""
    if grad_decode is not None:
        grads = grad_decode(grads)
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    masters = opt_state.get("master", params)  # f32 masters when present

    def upd(p, base, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        base32 = base.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base32
        new_base = base32 - lr * delta
        return new_base.astype(p.dtype), new_base, m2, v2

    istup = lambda x: isinstance(x, tuple)
    out = jax.tree_util.tree_map(
        upd, params, masters, grads, opt_state["m"], opt_state["v"]
    )
    pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], out, is_leaf=istup)
    new_params = pick(0)
    new_state = {"m": pick(2), "v": pick(3), "step": step}
    if "master" in opt_state:
        new_state["master"] = pick(1)
    return new_params, new_state, gnorm
