"""Training substrate: optimizer, data, checkpointing, train step."""
from .optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .train_step import make_loss_fn, make_train_step  # noqa: F401
from .data import SyntheticLM, Prefetcher  # noqa: F401
from . import checkpoint  # noqa: F401
from . import checkpointer  # noqa: F401
from .checkpointer import Checkpointer, SavePolicy, parse_policy  # noqa: F401
