"""Loss and train step: next-token CE, grad accumulation, AdamW, metrics.

The step is a single jit-able function suitable for pjit lowering: batch in,
(params, opt_state, metrics) out.  Microbatching (grad accumulation) runs as
a lax.scan over batch splits — each microbatch's backward overlaps the
previous one's gradient reduction under XLA's scheduler (DESIGN.md §5).

The vocab axis stays model-sharded through the loss: log-sum-exp and label
gathers are computed on sharded logits (XLA inserts the small psums), so the
full (b, s, V) logits never materialize replicated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import train_logits
from .optimizer import AdamWConfig, adamw_update

__all__ = ["make_loss_fn", "make_train_step"]

AUX_COEF = 0.01


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        tokens = batch["tokens"]  # (b, s+1)
        inputs = dict(batch, tokens=tokens[:, :-1])
        labels = tokens[:, 1:]
        logits, aux = train_logits(cfg, params, inputs)  # (b, s, V)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        return ce + AUX_COEF * aux, (ce, aux)

    return loss_fn


def make_train_step(
    cfg, opt_cfg: AdamWConfig, *, microbatches: int = 1, grad_shardings=None,
    rns_codec=None, rns_axis: str = "data", rns_repair: bool = False,
    transport_hook=None,
):
    """grad_shardings: optional NamedSharding tree matching params.  Pins
    gradients to the PARAMETER sharding so ZeRO-1's differently-sharded
    optimizer moments reshard at the optimizer boundary (reduce-scatter /
    all-gather) instead of leaking their sharding into the backward pass
    (measured: un-pinned, the partitioner partially shards attention dots by
    head_dim and all-reduces every score block).

    rns_codec: optional ``dist.grad_codec.GradCodec``.  When given, the step
    must run under shard_map/pmap with a ``rns_axis`` mesh axis: local
    gradients encode to residue channels, the WHOLE pytree all-reduces in a
    single bucketed per-channel int32 psum (``tree_pack``), and the fused
    decode runs inside ``adamw_update`` at the optimizer boundary — the
    paper's exact, order-independent aggregation on the real hot path
    (DESIGN.md §9).  Loss metrics are pmean'd over the same axis.

    rns_repair: with a locate-and-correct codec (``make(correct=True)``),
    run RRNS repair on the local wire buffer before the psum: any single
    corrupted channel per element is rebuilt from the surviving channels in
    place instead of poisoning the all-reduce (DESIGN.md §10).  Adds a
    ``repaired`` metric (global count of corrected elements).

    transport_hook: optional ``buf -> buf`` applied to the packed
    channel-major wire buffer between encode and repair/psum — the seam
    where wire corruption lives, used by fault-injection tests and the
    ``--rns-correct`` smoke demo."""
    if rns_repair and (rns_codec is None or rns_codec.mb is None):
        raise ValueError(
            "rns_repair requires a locate-and-correct codec: "
            "GradCodec.make(correct=True)"
        )
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def pin(grads):
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
            grads = pin(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc_step(carry, mbatch):
                g_acc, l_acc, c_acc, a_acc = carry
                (l, (c, a)), g = grad_fn(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, c_acc + c, a_acc + a), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc_step, (zeros, 0.0, 0.0, 0.0), mb
            )
            inv = 1.0 / microbatches
            grads = pin(jax.tree_util.tree_map(lambda g: g * inv, grads))
            loss, ce, aux = loss * inv, ce * inv, aux * inv

        if rns_codec is None:
            params, opt_state, gnorm = adamw_update(
                opt_cfg, params, grads, opt_state
            )
        else:
            import dataclasses

            from repro.dist.grad_codec import tree_decode, tree_pack_rns

            # the wire buffer travels TYPED: one channel-major RnsArray
            # (layout BASE_MA/RRNS per the codec) from encode through
            # repair, psum, and the optimizer-boundary decode
            wire, meta = tree_pack_rns(rns_codec, grads)
            if transport_hook is not None:  # fault-injection seam (raw)
                wire = dataclasses.replace(
                    wire, residues=transport_hook(wire.residues)
                )
            repaired = unrepairable = None
            if rns_repair:
                # RRNS locate-and-correct on the local wire array: fresh
                # encodings (wraps=0), so single-channel location is exact
                # and the repaired buffer enters the psum as if the
                # corruption never happened
                wire, fault = rns_codec.correct_packed(wire)
                repaired = jax.lax.psum(
                    jnp.sum(fault >= 0).astype(jnp.int32), rns_axis
                )
                unrepairable = jax.lax.psum(
                    jnp.sum(fault == -2).astype(jnp.int32), rns_axis
                )
            summed = jax.lax.psum(wire, rns_axis)  # the ONLY grad collective
            nd = jax.lax.psum(1.0, rns_axis)      # trace-time constant
            params, opt_state, gnorm = adamw_update(
                opt_cfg, params, summed, opt_state,
                grad_decode=lambda s: tree_decode(
                    rns_codec, s, meta, denom=nd
                ),
            )
            loss, ce, aux = (
                jax.lax.pmean(x, rns_axis) for x in (loss, ce, aux)
            )
        # the optimizer's post-update step counter rides along so drivers
        # can sanity-check a checkpoint resume against the loop's own step
        metrics = {"loss": loss, "ce": ce, "aux": aux, "gnorm": gnorm,
                   "opt_step": opt_state["step"]}
        if rns_codec is not None and rns_repair:
            metrics["repaired"] = repaired
            metrics["unrepairable"] = unrepairable
        return params, opt_state, metrics

    return train_step
