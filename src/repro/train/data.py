"""Synthetic deterministic data pipeline with host-side prefetch.

Produces next-token-prediction batches: tokens (b, s+1) drawn from a
per-step-seeded PRNG (reproducible across restarts — the loader is keyed by
(seed, step) so resuming from a checkpoint replays the exact stream).
Modality stubs (whisper frames / vlm patches) are generated at the stated
shapes.  A background thread keeps `prefetch` batches ahead of the train
loop — the straggler-mitigation hook for input-bound steps.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["SyntheticLM", "Prefetcher"]


class SyntheticLM:
    """pattern: "random" (entropy-floor stream) or "arith" (t_{i+1} =
    (t_i + stride) mod vocab — learnable, used by convergence tests)."""

    def __init__(self, cfg, seq: int, batch: int, *, seed: int = 0,
                 pattern: str = "random"):
        self.cfg, self.seq, self.batch, self.seed = cfg, seq, batch, seed
        self.pattern = pattern

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        if self.pattern == "arith":
            start = rng.integers(0, self.cfg.vocab, size=(self.batch, 1))
            stride = rng.integers(1, 5, size=(self.batch, 1))
            idx = np.arange(self.seq + 1)[None, :]
            toks = ((start + stride * idx) % self.cfg.vocab).astype(np.int32)
            out = {"tokens": toks}
        else:
            out = {
                "tokens": rng.integers(
                    0, self.cfg.vocab, size=(self.batch, self.seq + 1),
                    dtype=np.int32,
                )
            }
        if self.cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (self.batch, self.cfg.n_patches, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (self.batch, self.cfg.enc_frames, self.cfg.d_model)
            ).astype(np.float32)
        return out


class Prefetcher:
    """Background-thread prefetch of a step-indexed loader."""

    def __init__(self, loader, start_step: int = 0, depth: int = 2):
        self.loader = loader
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.loader.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
