"""Checkpointing: atomic, async-capable, fingerprint-verified, elastic.

Layout:   <dir>/step_<N>/{0.npy, 1.npy, ..., manifest.json}
Atomicity: written into step_<N>.tmp, every file (and the directory entry)
fsync'd, then os.replace'd — a crash mid-save leaves no manifest at the
final path, so restore skips it, and a crash straddling the rename can
never publish half-flushed file contents.
Elasticity: restore() takes the CURRENT mesh's shardings and device_puts
each host array accordingly — a checkpoint written under a different mesh
(or device count) reshards transparently; tests cover 1-device <-> 8-device
round-trips.

``save_async`` returns an ``AsyncSave`` handle: exceptions raised on the
writer thread are captured and re-raised from ``join()`` — never silently
dropped — and a second async save to the same (dir, step) while the first
is still in flight is refused (RuntimeError) rather than letting two
writers race on one ``step_<N>.tmp``.

The policy-driven background-queue frontend over this module lives in
train/checkpointer.py (DESIGN.md §14).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.dist.fault import (
    find_restorable,
    load_step,
    scan_restorable,
    tree_fingerprints,
)

__all__ = ["save", "save_async", "restore", "latest_step", "find_restorable",
           "AsyncSave"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in leaves]
    return names, [leaf for _, leaf in leaves], treedef


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_fsync(path: str, writer) -> None:
    """Write ``path`` via ``writer(f)`` and flush it to stable storage."""
    with open(path, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())


def commit_dir(tmp: str, final: str) -> None:
    """Durably publish a fully-written ``tmp`` directory at ``final``:
    fsync the directory entry, atomically replace, fsync the parent so the
    rename itself survives a crash."""
    _fsync_path(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_path(os.path.dirname(final) or ".")


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Synchronous atomic save of a pytree of (host or device) arrays."""
    names, leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    for i, arr in enumerate(host):
        _write_fsync(os.path.join(tmp, f"{i}.npy"),
                     lambda f, a=arr: np.save(f, a))
    fps = tree_fingerprints(dict(zip(names, host)))
    manifest = {
        "step": step,
        "names": names,
        # index by name: the fingerprint dict's flatten order (sorted joined
        # strings) need not match the source tree's flatten order
        "fingerprints": [fps[n] for n in names],
        "extra": extra or {},
    }
    _write_fsync(os.path.join(tmp, "manifest.json"),
                 lambda f: f.write(json.dumps(manifest).encode()))
    commit_dir(tmp, final)
    return final


# async saves in flight, keyed by (abs ckpt dir, step) — the guard that
# makes two concurrent writers on one step_<N>.tmp impossible
_inflight: set[tuple[str, int]] = set()
_inflight_lock = threading.Lock()


class AsyncSave:
    """Handle for one in-flight async save.

    ``join()`` waits for the writer thread and RE-RAISES any exception it
    hit (a failed save must surface, never vanish with the thread);
    ``path`` holds the committed directory after a successful join."""

    def __init__(self, ckpt_dir: str, step: int, host_tree, extra):
        self.step = step
        self.path: str | None = None
        self._error: BaseException | None = None
        self._key = (os.path.abspath(ckpt_dir), step)
        with _inflight_lock:
            if self._key in _inflight:
                raise RuntimeError(
                    f"async save to step {step} of {ckpt_dir} already in "
                    f"flight — join() it before saving the same step again"
                )
            _inflight.add(self._key)
        self._thread = threading.Thread(
            target=self._run, args=(ckpt_dir, step, host_tree, extra),
            daemon=True,
        )
        self._thread.start()

    def _run(self, ckpt_dir, step, host_tree, extra):
        try:
            self.path = save(ckpt_dir, step, host_tree, extra=extra)
        except BaseException as e:  # surfaces from join()
            self._error = e
        finally:
            with _inflight_lock:
                _inflight.discard(self._key)

    def join(self, timeout: float | None = None) -> str | None:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"save of step {self.step} still running")
        if self._error is not None:
            raise self._error
        return self.path

    def done(self) -> bool:
        return not self._thread.is_alive()


def save_async(ckpt_dir: str, step: int, tree, *, extra=None) -> AsyncSave:
    """Fire-and-join-later save: leaves are fetched to host synchronously
    (cheap relative to the write) and the file I/O runs on a thread so the
    train loop's next step overlaps the disk write.  The returned handle's
    ``join()`` re-raises writer-thread exceptions; a concurrent save to the
    same (dir, step) raises RuntimeError immediately."""
    names, leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]  # device->host before returning
    host_tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), host
    )
    return AsyncSave(ckpt_dir, step, host_tree, extra)


def latest_step(ckpt_dir: str) -> int | None:
    path = find_restorable(ckpt_dir)
    return int(os.path.basename(path).split("_")[1]) if path else None


def restore(ckpt_dir: str, abstract_tree, shardings=None, *, step: int | None = None):
    """Load + verify + (re)shard a checkpoint onto the current mesh.

    abstract_tree: pytree of ShapeDtypeStructs (or arrays) giving structure.
    shardings: matching pytree of NamedShardings (None = host arrays).
    """
    if step is not None:
        path = os.path.join(ckpt_dir, f"step_{step}")
        manifest, flat = load_step(path)  # FileNotFoundError / IOError
    else:
        # scan returns the loaded-and-verified contents, so discovery and
        # restore cost ONE full read + hash of the checkpoint, not two
        found = scan_restorable(ckpt_dir)
        if found is None:
            raise FileNotFoundError(f"no restorable checkpoint under {ckpt_dir}")
        path, manifest, flat = found
    names, leaves, treedef = _flatten(abstract_tree)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(names) ^ set(manifest['names'])}"
        )
    arrays = [flat[k] for k in names]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_tree), arrays
    )
    return tree, manifest["step"], manifest.get("extra", {})
