"""Checkpointing: atomic, async-capable, fingerprint-verified, elastic.

Layout:   <dir>/step_<N>/{0.npy, 1.npy, ..., manifest.json}
Atomicity: written into step_<N>.tmp then os.rename'd — a crash mid-save
leaves no manifest at the final path, so restore skips it.
Elasticity: restore() takes the CURRENT mesh's shardings and device_puts
each host array accordingly — a checkpoint written under a different mesh
(or device count) reshards transparently; tests cover 1-device <-> 8-device
round-trips.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.dist.fault import (
    find_restorable,
    load_step,
    scan_restorable,
    tree_fingerprints,
)

__all__ = ["save", "save_async", "restore", "latest_step", "find_restorable"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in leaves]
    return names, [leaf for _, leaf in leaves], treedef


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Synchronous atomic save of a pytree of (host or device) arrays."""
    names, leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    for i, arr in enumerate(host):
        np.save(os.path.join(tmp, f"{i}.npy"), arr)
    fps = tree_fingerprints(dict(zip(names, host)))
    manifest = {
        "step": step,
        "names": names,
        # index by name: the fingerprint dict's flatten order (sorted joined
        # strings) need not match the source tree's flatten order
        "fingerprints": [fps[n] for n in names],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, extra=None) -> threading.Thread:
    """Fire-and-join-later save: leaves are fetched to host synchronously
    (cheap relative to the write) and the file I/O runs on a thread so the
    train loop's next step overlaps the disk write."""
    names, leaves, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]  # device->host before returning
    host_tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), host
    )
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree), kwargs={"extra": extra}
    )
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    path = find_restorable(ckpt_dir)
    return int(os.path.basename(path).split("_")[1]) if path else None


def restore(ckpt_dir: str, abstract_tree, shardings=None, *, step: int | None = None):
    """Load + verify + (re)shard a checkpoint onto the current mesh.

    abstract_tree: pytree of ShapeDtypeStructs (or arrays) giving structure.
    shardings: matching pytree of NamedShardings (None = host arrays).
    """
    if step is not None:
        path = os.path.join(ckpt_dir, f"step_{step}")
        manifest, flat = load_step(path)  # FileNotFoundError / IOError
    else:
        # scan returns the loaded-and-verified contents, so discovery and
        # restore cost ONE full read + hash of the checkpoint, not two
        found = scan_restorable(ckpt_dir)
        if found is None:
            raise FileNotFoundError(f"no restorable checkpoint under {ckpt_dir}")
        path, manifest, flat = found
    names, leaves, treedef = _flatten(abstract_tree)
    if names != manifest["names"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(names) ^ set(manifest['names'])}"
        )
    arrays = [flat[k] for k in names]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_tree), arrays
    )
    return tree, manifest["step"], manifest.get("extra", {})
