"""Attention: chunked flash-style training/prefill path + cached decode path.

Training/prefill uses a two-level loop with online softmax so activation
memory is O(chunk²) instead of O(s²); the inner fori_loop runs only over the
causally-reachable (and window-reachable) KV chunks — bounds may be traced,
so a scanned layer stack can mix local/global layers (gemma3 5:1) with a
per-layer window value.

``window`` convention: ``None`` (static) = no sliding window; otherwise an
int or traced scalar W meaning "attend to positions in (i-W, i]".

Decode attends one new token against a KV cache — either a full-length cache
with a validity mask, or a ring buffer of size W for sliding-window layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

from .layers import init_linear, rope

__all__ = [
    "init_attn",
    "flash_attention",
    "attn_forward",
    "decode_attention",
    "attn_decode",
    "attn_decode_paged",
]

NEG_INF = -1e30


def init_attn(key, d, heads, kv, hd, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, (d, heads, hd), dtype),
        "wk": init_linear(k2, (d, kv, hd), dtype),
        "wv": init_linear(k3, (d, kv, hd), dtype),
        "wo": init_linear(k4, (heads, hd, d), dtype),
    }


def flash_attention(
    q, k, v, *, causal: bool = True, window=None,
    q_chunk: int = 512, kv_chunk: int = 512, impl: str = "vjp",
):
    """q: (b, sq, h, hd); k, v: (b, skv, g, hd), h = g*r -> (b, sq, h, hd).

    impl:
      * "vjp" (training default): scan/fori forward + hand-written flash
        backward (recompute per chunk) — O(chunk²) live memory both ways and
        exact causal/window chunk skipping even with traced window values.
      * "scan" (prefill/inference): forward only; reverse-mode unsupported
        (traced loop bounds).
      * "unrolled" (the recorded §Perf BASELINE): statically unrolled
        autodiff path — backward saves every probability block (memory-
        hungry) and windows mask instead of skip.
    """
    b, sq, h, hd = q.shape
    _, skv, g, _ = k.shape
    r = h // g
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, "pad sequences to chunks"
    if causal:
        assert q_chunk == kv_chunk and sq == skv, "causal path assumes alignment"

    if impl == "vjp":
        wv = jnp.asarray(window if window is not None else (1 << 40))
        return _flash_vjp(q, k, v, causal, window is not None, q_chunk,
                          kv_chunk, wv)
    if impl == "scan":
        out, _ = _flash_fwd_chunks(
            q, k, v, causal=causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return out
    assert impl == "unrolled", impl
    qg = q.reshape(b, sq, g, r, hd)
    scale = hd ** -0.5

    def make_kv_step(q_blk, qi):
        def kv_step(ki, carry):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            s = jnp.einsum(
                "bqgrd,bkgd->bgrqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            )
            if causal or window is not None:
                ipos = qi * q_chunk + jnp.arange(q_chunk)
                jpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    mask &= jpos[None, :] <= ipos[:, None]
                if window is not None:
                    mask &= jpos[None, :] > ipos[:, None] - window
                s = jnp.where(mask, s, NEG_INF)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            pv = jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(v.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return m2, l2, acc * corr[..., None] + pv

        return kv_step

    def init_acc():
        return (
            jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, g, r, q_chunk), jnp.float32),
            jnp.zeros((b, g, r, q_chunk, hd), jnp.float32),
        )

    def finish(acc_tuple):
        m, l, acc = acc_tuple
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, hd).astype(q.dtype)

    blocks = []
    for qi in range(nq):  # static unroll: static fori_loop bounds
        q_blk = (qg[:, qi * q_chunk : (qi + 1) * q_chunk] * scale).astype(q.dtype)
        hi = qi + 1 if causal else nk
        acc = jax.lax.fori_loop(0, hi, make_kv_step(q_blk, qi), init_acc())
        blocks.append(finish(acc))
    return jnp.concatenate(blocks, axis=1)


def _flash_fwd_chunks(q, k, v, *, causal, window, q_chunk, kv_chunk):
    """Shared forward: returns (out, lse) with lse: (b, g, r, sq).

    scan over q chunks; inner fori_loop bounds may be traced (window can be
    a per-layer traced scalar) — legal here because gradients flow through
    the hand-written VJP below, never through this loop.
    """
    b, sq, h, hd = q.shape
    _, skv, g, _ = k.shape
    r = h // g
    nq, nk = sq // q_chunk, skv // kv_chunk
    qg = q.reshape(b, sq, g, r, hd)
    scale = hd ** -0.5

    def bounds(qi):
        if causal:
            hi = qi + 1
            lo = (
                jnp.maximum(0, (qi * q_chunk - window) // kv_chunk)
                if window is not None
                else 0
            )
        else:
            lo, hi = 0, nk
        return lo, hi

    def mask_for(qi, ki):
        if not (causal or window is not None):
            return None
        ipos = qi * q_chunk + jnp.arange(q_chunk)
        jpos = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            mask &= jpos[None, :] <= ipos[:, None]
        if window is not None:
            mask &= jpos[None, :] > ipos[:, None] - window
        return mask

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        q_blk = (q_blk * scale).astype(q.dtype)
        lo, hi = bounds(qi)

        def kv_step(ki, carry):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32)
            msk = mask_for(qi, ki)
            if msk is not None:
                s = jnp.where(msk, s, NEG_INF)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            return m2, l2, acc * corr[..., None] + pv

        m0 = jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, r, q_chunk, hd), jnp.float32)
        m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(q.dtype), lse)

    _, (blocks, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, g, r, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_vjp(q, k, v, causal, has_window, q_chunk, kv_chunk, window_val):
    out, _ = _flash_fwd_chunks(
        q, k, v, causal=causal,
        window=window_val if has_window else None,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    return out


def _flash_vjp_fwd(q, k, v, causal, has_window, q_chunk, kv_chunk, window_val):
    out, lse = _flash_fwd_chunks(
        q, k, v, causal=causal,
        window=window_val if has_window else None,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    return out, (q, k, v, out, lse, window_val)


def _flash_vjp_bwd(causal, has_window, q_chunk, kv_chunk, res, dout):
    """Flash backward: recompute p chunk-by-chunk; O(chunk²) live memory.

        delta_i = Σ_d dO_id · O_id
        p_ij    = exp(s_ij − lse_i)
        dv_j    = Σ_i p_ij dO_i          dp_ij = dO_i · v_j
        ds_ij   = p_ij (dp_ij − delta_i)
        dq_i    = scale Σ_j ds_ij k_j     dk_j = scale Σ_i ds_ij q_i
    """
    q, k, v, out, lse, window_val = res
    window = window_val if has_window else None
    b, sq, h, hd = q.shape
    _, skv, g, _ = k.shape
    r = h // g
    nq, nk = sq // q_chunk, skv // kv_chunk
    qg = q.reshape(b, sq, g, r, hd)
    og = out.reshape(b, sq, g, r, hd)
    dog = dout.reshape(b, sq, g, r, hd)
    scale = hd ** -0.5
    delta = jnp.einsum(
        "bsgrd,bsgrd->bgrs", dog.astype(jnp.float32), og.astype(jnp.float32)
    )

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        do_blk = jax.lax.dynamic_slice_in_dim(dog, qi * q_chunk, q_chunk, axis=1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, qi * q_chunk, q_chunk, axis=3)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qi * q_chunk, q_chunk, axis=3)
        if causal:
            hi = qi + 1
            lo = (
                jnp.maximum(0, (qi * q_chunk - window) // kv_chunk)
                if window is not None
                else 0
            )
        else:
            lo, hi = 0, nk

        def kv_step(ki, inner):
            dq_blk, dk_acc, dv_acc = inner
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal or window is not None:
                ipos = qi * q_chunk + jnp.arange(q_chunk)
                jpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    mask &= jpos[None, :] <= ipos[:, None]
                if window is not None:
                    mask &= jpos[None, :] > ipos[:, None] - window
                s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])                     # (b,g,r,q,k)
            dv = jnp.einsum("bgrqk,bqgrd->bkgd", p,
                            do_blk.astype(jnp.float32))
            dp = jnp.einsum("bqgrd,bkgd->bgrqk",
                            do_blk.astype(jnp.float32),
                            v_blk.astype(jnp.float32))
            ds = p * (dp - dl_blk[..., None])
            dq_blk = dq_blk + scale * jnp.einsum(
                "bgrqk,bkgd->bqgrd", ds, k_blk.astype(jnp.float32))
            dk = scale * jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                                    q_blk.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc,
                jax.lax.dynamic_slice_in_dim(dk_acc, ki * kv_chunk, kv_chunk, 1) + dk,
                ki * kv_chunk, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc,
                jax.lax.dynamic_slice_in_dim(dv_acc, ki * kv_chunk, kv_chunk, 1) + dv,
                ki * kv_chunk, axis=1)
            return dq_blk, dk_acc, dv_acc

        dq0 = jnp.zeros((b, q_chunk, g, r, hd), jnp.float32)
        dq_blk, dk_acc, dv_acc = jax.lax.fori_loop(
            lo, hi, kv_step, (dq0, dk_acc, dv_acc)
        )
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((b, skv, g, hd), jnp.float32)
    dv0 = jnp.zeros((b, skv, g, hd), jnp.float32)
    (dk, dv), dq_blocks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = dq_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(window_val))


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attn_forward(
    p, x, positions, *, heads, kv, hd, theta, causal=True, window=None,
    enc=None, q_chunk=512, kv_chunk=512, return_kv=False,
    impl="vjp",
):
    """Project -> rope -> attend -> project.  ``enc`` switches to cross
    attention against encoder states (no rope on keys)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    src = x if enc is None else enc
    k = jnp.einsum("bsd,dgk->bsgk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", src, p["wv"].astype(dt))
    # heads claim 'model' when divisible; otherwise the batch spreads over
    # data AND model (batch-parallel attention — no replicated compute).
    q = constrain(q, "?batch_plus", None, "heads", None)
    k = constrain(k, "?batch_plus", None, "kv", None)
    v = constrain(v, "?batch_plus", None, "kv", None)
    q = rope(q, positions, theta)
    if enc is None:
        k = rope(k, positions, theta)
    o = flash_attention(
        q, k, v, causal=causal and enc is None, window=window,
        q_chunk=q_chunk, kv_chunk=kv_chunk, impl=impl,
    )
    o = constrain(o, "?batch_plus", None, "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return (constrain(out, "batch", None, None), (k, v)) if return_kv else constrain(out, "batch", None, None)


def decode_attention(q, k_cache, v_cache, cur_len, *, window=None,
                     kscale=None, vscale=None):
    """Cached attention for one or more appended tokens over a linear cache.

    q: (b, sq, h, hd); caches: (b, S, g, hd); cur_len: tokens in cache
    including the newest — a scalar, or per-row ``(b,)`` when rows sit at
    different sequence positions (the continuous-batching slot layout,
    DESIGN.md §12).  Query i (of sq) lives at position cur_len - sq + i and
    attends causally: slots >= its position + 1 (and outside the window)
    are masked.  sq == 1 with scalar cur_len is the classic decode step;
    sq > 1 is the chunked prefill-extend path.

    int8-quantized caches pass kscale/vscale (b, g): HBM reads stay int8 and
    the per-(batch, kv-head) scale folds in AFTER the contraction.
    """
    b, S, g, hd = k_cache.shape
    sq, h = q.shape[1], q.shape[2]
    r = h // g
    cd = q.dtype if kscale is not None else k_cache.dtype
    qg = q.reshape(b, sq, g, r, hd) * (hd ** -0.5)
    s = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg.astype(cd), k_cache.astype(cd),
        preferred_element_type=jnp.float32,
    )
    if kscale is not None:
        s = s * kscale[:, :, None, None, None]
    jpos = jnp.arange(S)
    # qpos: (b|1, sq) position of each query row/token; scalar cur_len
    # reshapes to (1, 1) and broadcasts exactly like the historical path.
    qpos = jnp.reshape(jnp.asarray(cur_len), (-1, 1)) - sq + jnp.arange(sq)
    mask = jpos[None, None, :] <= qpos[..., None]
    if window is not None:
        mask &= jpos[None, None, :] > qpos[..., None] - window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgrqk,bkgd->bgrqd", p.astype(cd), v_cache.astype(cd),
        preferred_element_type=jnp.float32,
    )
    if vscale is not None:
        o = o * vscale[:, :, None, None, None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention_ring(q, k_cache, v_cache, pos):
    """Sliding-window decode over a ring buffer of size W; newest token was
    just written at slot pos % W.  Valid slots: logical position >= 0."""
    b, W, g, hd = k_cache.shape
    h = q.shape[2]
    r = h // g
    qg = q.reshape(b, 1, g, r, hd) * (hd ** -0.5)
    s = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg.astype(k_cache.dtype), k_cache,
        preferred_element_type=jnp.float32,
    )
    slots = jnp.arange(W)
    logical = pos - jnp.mod(pos - slots, W)  # logical position held by slot
    mask = logical >= 0
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgrqk,bkgd->bgrqd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, hd).astype(q.dtype)


def attn_decode(
    p, x, cache, pos, *, heads, kv, hd, theta, ring=False, window=None, enc=None
):
    """Cached decode for one block: one token, a chunk, per-row positions.

    cache: {"k": (b,S,g,hd), "v": ...} (S = window size when ring=True),
    optionally int8 with "ks"/"vs" (b, g) dequant scales.

    x is (b, s, d) with s >= 1 new tokens per row; ``pos`` is the logical
    position of the FIRST new token — a scalar (all rows aligned, the
    historical decode step) or a ``(b,)`` vector (each row at its own
    position: the continuous-batching slot layout, DESIGN.md §12).  s > 1
    is the chunked prefill-extend path: tokens land at pos..pos+s-1 with
    causal attention inside the chunk.  Ring (sliding-window) caches and
    cross-attention (enc != None) support the classic scalar/s==1 call
    only.  Cross-attention blocks have no cache to update.
    """
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    pos_arr = jnp.asarray(pos)
    per_row = pos_arr.ndim > 0
    positions = jnp.broadcast_to(
        jnp.reshape(pos_arr, (-1, 1)) + jnp.arange(s)[None, :], (b, s)
    )
    if enc is not None:
        assert s == 1 and not per_row, "cross-attention decode is one-token"
        k = jnp.einsum("bsd,dgk->bsgk", enc, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dgk->bsgk", enc, p["wv"].astype(dt))
        q = rope(q, positions, theta)
        o = decode_attention(q, k, v, k.shape[1])
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt)), cache
    k_new = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(dt))
    q = rope(q, positions, theta)
    k_new = rope(k_new, positions, theta)
    quant = "ks" in cache
    if quant:
        # quantize the incoming token with the prefill scales (fixed-scale
        # drift caveat documented in EXPERIMENTS §Perf)
        k_new = jnp.clip(
            jnp.round(k_new / cache["ks"][:, None, :, None]), -127, 127
        )
        v_new = jnp.clip(
            jnp.round(v_new / cache["vs"][:, None, :, None]), -127, 127
        )
    S = cache["k"].shape[1]
    if per_row:
        assert not ring, "per-row positions need a linear (non-ring) cache"
        # each row writes its s new tokens at its own offset
        row_update = jax.vmap(
            lambda c, u, st: jax.lax.dynamic_update_slice_in_dim(
                c, u, st, axis=0
            )
        )
        starts = pos_arr.astype(jnp.int32)
        kc = row_update(cache["k"], k_new.astype(cache["k"].dtype), starts)
        vc = row_update(cache["v"], v_new.astype(cache["v"].dtype), starts)
    else:
        slot = jnp.mod(pos, S) if ring else pos
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
        )
    if ring:
        assert s == 1, "ring caches decode one token at a time"
        o = decode_attention_ring(q, kc, vc, pos)
    else:
        o = decode_attention(
            q, kc, vc, pos_arr + s, window=window,
            kscale=cache.get("ks"), vscale=cache.get("vs"),
        )
    out_cache = {"k": kc, "v": vc}
    if quant:
        out_cache["ks"], out_cache["vs"] = cache["ks"], cache["vs"]
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt)), out_cache


def attn_decode_paged(
    p, x, k_pool, v_pool, pages, pos, *, page_size, heads, kv, hd, theta,
    window=None, valid_len=None, scratch=None,
):
    """Cached decode through a page-table indirection (DESIGN.md §13).

    Instead of one contiguous row per request, KV lives in a pooled buffer
    of fixed-size pages — ``k_pool``/``v_pool``: (P, page_size, g, hd) —
    and each row of ``pages`` (an int32 ``(b, n_pg)`` table, plain data so
    remapping never retraces) names the physical pages that back the row's
    logical positions 0..n_pg*page_size-1 in order.  Unmapped entries
    point at the reserved parking page 0.

    x: (b, s, d) with s >= 1 new tokens per row at per-row positions
    ``pos`` (b,).  The s new K/V project+rope exactly like ``attn_decode``
    and SCATTER to (page, offset) = (pos+i) divmod page_size through the
    table; reads GATHER the table back into a (b, n_pg*page_size, g, hd)
    logical row and reuse ``decode_attention`` unchanged.  Because
    n_pg*page_size == cache_len, the gathered row has the same length,
    ordering, and therefore reduction order as the monolithic layout —
    junk in parked/unwritten pages sits behind the same NEG_INF mask that
    hides unwritten cache zeros, so outputs are bitwise-identical to the
    un-paged path.  No ring/quant/cross-attention support (the serve
    engine lowers or gates those before reaching here).

    ``valid_len``/``scratch`` (both traced int32 DATA, so one graph per
    token-shape still serves every call) implement the padded write
    barrier for bucketed prefill: per row, only the first ``valid_len``
    of the s tokens write through the page table — the rest scatter into
    the row's ``scratch`` page, a throwaway physical page the caller
    frees right after the call.  Pad K/V never lands in a shared,
    registered, or retained page, so CoW/fingerprint invariants hold
    without inspecting pad content.  Pad positions may also run past the
    logical row (start+s > n_pg*page_size); their table lookup is clipped
    in-bounds and then discarded by the same mask.  Pad QUERIES still
    attend (their outputs are junk) — the caller's ``logit_index`` reads
    the last real position, and causal masking keeps real queries from
    ever seeing a pad key, because pad keys only exist in the scratch
    page which no table row names.
    """
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    pos_arr = jnp.asarray(pos)
    positions = jnp.broadcast_to(
        jnp.reshape(pos_arr, (-1, 1)) + jnp.arange(s)[None, :], (b, s)
    )
    k_new = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(dt))
    q = rope(q, positions, theta)
    k_new = rope(k_new, positions, theta)
    # scatter each new token to its (physical page, in-page offset)
    n_pg_tab = pages.shape[1]
    lp = jnp.clip(positions // page_size, 0, n_pg_tab - 1)  # pads may be OOB
    pid = jnp.take_along_axis(pages, lp, axis=1)  # (b,s)
    off = positions % page_size
    if valid_len is not None:
        # padded write barrier: pad rows (i >= valid_len) scatter into the
        # per-row scratch page instead of through the table
        keep = jnp.arange(s)[None, :] < jnp.reshape(
            jnp.asarray(valid_len, jnp.int32), (-1, 1))
        pid = jnp.where(keep, pid, jnp.reshape(
            jnp.asarray(scratch, jnp.int32), (-1, 1)))
    k_pool = k_pool.at[pid, off].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[pid, off].set(v_new.astype(v_pool.dtype))
    n_pg = pages.shape[1]
    k_rows = k_pool[pages].reshape(b, n_pg * page_size, kv, hd)
    v_rows = v_pool[pages].reshape(b, n_pg * page_size, kv, hd)
    o = decode_attention(q, k_rows, v_rows, pos_arr + s, window=window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt)), k_pool, v_pool
