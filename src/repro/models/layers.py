"""Shared neural-net layers: RMSNorm, RoPE, gated MLPs, embeddings.

Pure functions over parameter pytrees; all dtype-explicit (x64 is enabled
globally for the RNS core, so float dtypes must never be inferred).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

__all__ = [
    "rms_norm",
    "rope",
    "gated_mlp",
    "init_linear",
    "init_norm",
    "init_mlp",
    "embed",
    "unembed",
]


def rms_norm(x, scale, eps: float = 1e-6):
    """Mean-square reduction in f32; the normalize/scale multiplies stay in
    the input dtype so no full-width f32 copy of the hidden materializes
    (matters for compile-time memory accounting on long sequences)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return x * inv.astype(dt) * (1.0 + scale.astype(dt))


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x: (..., s, h, hd), positions: (..., s)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(jnp.float32(theta)) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )


def gated_mlp(x, wi, wo, act: str):
    """SwiGLU / GeGLU: wi: (d, 2, ff), wo: (ff, d).  x: (b, s, d)."""
    dt = x.dtype
    h = jnp.einsum("...d,dgf->...gf", x, wi.astype(dt))
    h = constrain(h, "batch", None, None, "ff")
    gate, up = h[..., 0, :], h[..., 1, :]
    g = jax.nn.gelu(gate) if act == "geglu" else jax.nn.silu(gate)
    out = jnp.einsum("...f,fd->...d", g * up, wo.astype(dt))
    return constrain(out, "batch", None, None)


# ----------------------------------------------------------------- init
def init_linear(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def init_norm(shape, dtype):
    return jnp.zeros(shape, dtype=dtype)


def init_mlp(key, d, ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_linear(k1, (d, 2, ff), dtype),
        "wo": init_linear(k2, (ff, d), dtype),
    }


def embed(tokens, table, dtype):
    """Token embedding with sqrt(d) scaling (gemma convention)."""
    d = table.shape[-1]
    x = table.astype(dtype)[tokens] * jnp.asarray(d, dtype) ** 0.5
    return constrain(x, "batch", None, None)


def unembed(x, table):
    """Logits against the (tied) embedding table: (..., d) x (V, d) -> (..., V).

    Logits stay VOCAB-SHARDED (the loss computes on sharded logits; the full
    (b, s, V) tensor never materializes replicated)."""
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    names = ["batch"] + [None] * (logits.ndim - 2) + ["vocab"]
    return constrain(logits, *names)
