"""Decoder-only transformer stacks (dense / moe / vlm) and the whisper-style
encoder-decoder — init, training forward, prefill, and decode.

Layers are scanned (stacked parameter pytrees) so the HLO stays O(1) in
depth; heterogeneity (gemma3's 5:1 local:global pattern) rides through the
scan as a per-layer flag driving a *traced* window value.  Activation
checkpointing wraps the scan body when cfg.remat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.act_sharding import constrain

from .attention import attn_decode, attn_decode_paged, attn_forward, init_attn
from .config import ModelConfig
from .layers import embed, gated_mlp, init_linear, init_mlp, init_norm, rms_norm, unembed
from .moe import init_moe, moe_forward

NO_WINDOW = 1 << 40  # "infinite" traced window for global layers


# --------------------------------------------------------------------- util
def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _pdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def global_flags(cfg: ModelConfig) -> np.ndarray:
    """(L,) bool: True where the layer is global-attention (gemma3 5:1)."""
    if not cfg.window:
        return np.ones(cfg.n_layers, dtype=bool)
    return np.asarray(
        [(i % cfg.global_every) == cfg.global_every - 1 for i in range(cfg.n_layers)]
    )


def layer_window(cfg, is_global):
    """Traced per-layer window value (None when the arch has no windows)."""
    if not cfg.window:
        return None
    return jnp.where(is_global, jnp.int64(NO_WINDOW), jnp.int64(cfg.window))


def _maybe_remat(f, cfg):
    if not cfg.remat:
        return f
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )
    return jax.checkpoint(f, prevent_cse=False, policy=policy)


# --------------------------------------------------------------------- init
def init_dense_block(key, cfg: ModelConfig, dt):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_norm((cfg.d_model,), dt),
        "attn": init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, dt),
        "ln2": init_norm((cfg.d_model,), dt),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(k2, cfg, dt)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def init_decoder_only(key, cfg: ModelConfig):
    dt = _pdtype(cfg)
    kE, kL = jax.random.split(key)
    layer_keys = jax.random.split(kL, cfg.n_layers)
    return {
        "embed": init_linear(kE, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "layers": jax.vmap(lambda k: init_dense_block(k, cfg, dt))(layer_keys),
        "final_norm": init_norm((cfg.d_model,), dt),
    }


def init_encdec(key, cfg: ModelConfig):
    dt = _pdtype(cfg)
    kE, kEnc, kDec = jax.random.split(key, 3)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": init_norm((cfg.d_model,), dt),
            "attn": init_attn(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, dt),
            "ln2": init_norm((cfg.d_model,), dt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": init_norm((cfg.d_model,), dt),
            "self_attn": init_attn(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, dt
            ),
            "ln2": init_norm((cfg.d_model,), dt),
            "cross_attn": init_attn(
                k2, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, dt
            ),
            "ln3": init_norm((cfg.d_model,), dt),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dt),
        }

    return {
        "embed": init_linear(kE, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "enc_layers": jax.vmap(enc_block)(jax.random.split(kEnc, cfg.enc_layers)),
        "enc_norm": init_norm((cfg.d_model,), dt),
        "dec_layers": jax.vmap(dec_block)(jax.random.split(kDec, cfg.n_layers)),
        "final_norm": init_norm((cfg.d_model,), dt),
    }


# ----------------------------------------------------------------- forward
def _attn_kwargs(cfg):
    return dict(
        heads=cfg.n_heads, kv=cfg.n_kv, hd=cfg.head_dim, theta=cfg.rope_theta
    )


def decoder_stack(cfg: ModelConfig, params, x, positions, *, collect_kv=False):
    """Run the scanned layer stack.  Returns (x, aux_loss, kv_stack|None)."""
    flags = jnp.asarray(global_flags(cfg))
    akw = _attn_kwargs(cfg)

    def body(carry, xs):
        x, aux = carry
        pl, is_global = xs
        wv = layer_window(cfg, is_global)
        if cfg.seq_parallel:
            # residual stream lives (batch x seq)-sharded between blocks;
            # the q/k/v and MLP constraints pull full sequences back in
            # (XLA materializes the AG/RS pair = the usual SP dataflow).
            x = constrain(x, "batch", "seq", None)
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        res = attn_forward(
            pl["attn"], h, positions, window=wv, return_kv=collect_kv,
            impl="scan" if collect_kv else cfg.attn_impl, **akw,
        )
        o, kv = res if collect_kv else (res, None)
        if collect_kv:
            # pin the collected KV stack so the prefill ys buffer materializes
            # cache-sharded (heads over model when divisible, else sequence).
            # The barrier stops the constraint propagating INTO the attention
            # loop (which must see the sequence unsharded).
            kv = jax.lax.optimization_barrier(kv)
            kv = tuple(constrain(t, "batch", "?seq", "kv", None) for t in kv)
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, a = moe_forward(pl["moe"], cfg, h2)
            aux = aux + a
        else:
            y = gated_mlp(h2, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act)
        return (x + y, aux), kv

    (x, aux), kvs = jax.lax.scan(
        _maybe_remat(body, cfg), (x, jnp.float32(0.0)), (params["layers"], flags)
    )
    return x, aux, kvs


def decoder_only_logits(cfg: ModelConfig, params, batch):
    """Training forward.  batch["tokens"]: (b, s) inputs; vlm gets
    batch["patches"]: (b, P, d) prepended.  Returns (logits, aux)."""
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dt)
    n_prefix = 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dt)
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, aux, _ = decoder_stack(cfg, params, x, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]  # logits over text positions only
    return unembed(x, params["embed"]), aux


def decoder_only_prefill(cfg: ModelConfig, params, batch, cache_len: int):
    """Prompt pass; returns (last-token logits, cache).

    Cache: {"k","v"}: (L, b, S, g, hd) (S = cache_len), plus lengths.
    When cfg.window and cfg.window_cache, local layers keep only a
    window-sized ring (stored in separate 'lk','lv' stacks) — the optimized
    layout; otherwise all layers use full-length caches.
    """
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dt)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(dt), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, kvs = decoder_stack(cfg, params, x, positions, collect_kv=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["embed"])[:, 0]

    k_new, v_new = kvs  # (L, b, s, g, hd)
    L = cfg.n_layers
    g, hd = cfg.n_kv, cfg.head_dim
    pad = cache_len - s
    if pad < 0:
        raise ValueError("cache_len < prompt length")
    if cfg.window and cfg.window_cache:
        return logits, _windowed_cache(cfg, k_new, v_new, s, cache_len)
    cache = {"len": jnp.int32(s)}
    if cfg.kv_quant:
        assert not cfg.window, "int8 KV + ring caches not combined"
        # per-(layer, batch, kv-head) symmetric int8 quantization
        ks = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=(2, 4)) / 127.0
        vs = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=(2, 4)) / 127.0
        ks = jnp.maximum(ks, 1e-6)
        vs = jnp.maximum(vs, 1e-6)
        k_new = jnp.clip(
            jnp.round(k_new / ks[:, :, None, :, None]), -127, 127
        ).astype(jnp.int8)
        v_new = jnp.clip(
            jnp.round(v_new / vs[:, :, None, :, None]), -127, 127
        ).astype(jnp.int8)
        cache["ks"], cache["vs"] = ks, vs
    cache["k"] = jnp.pad(k_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["v"] = jnp.pad(v_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, cache


def _windowed_cache(cfg, k_new, v_new, s: int, cache_len: int):
    """Grouped cache for sliding-window archs (gemma3 5:1): global layers
    keep the full sequence, local layers keep a W-slot RING holding the last
    W tokens (ring slot of logical position p is p % W) — 26 full caches
    collapse to 4 full + 22 windows (the §Perf memory win at 500k).
    """
    W = cfg.window
    flags = global_flags(cfg)
    gidx = tuple(int(i) for i in np.nonzero(flags)[0])
    lidx = tuple(int(i) for i in np.nonzero(~flags)[0])
    pad = cache_len - s
    gk = jnp.pad(k_new[jnp.asarray(gidx)],
                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    gv = jnp.pad(v_new[jnp.asarray(gidx)],
                 ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    lk = k_new[jnp.asarray(lidx)][:, :, max(0, s - W):]
    lv = v_new[jnp.asarray(lidx)][:, :, max(0, s - W):]
    if s < W:  # short prompts: slots 0..s-1 are just positions 0..s-1
        lk = jnp.pad(lk, ((0, 0), (0, 0), (0, W - s), (0, 0), (0, 0)))
        lv = jnp.pad(lv, ((0, 0), (0, 0), (0, W - s), (0, 0), (0, 0)))
    else:      # last W tokens land at slots (s-W+i) % W: a roll by s % W
        lk = jnp.roll(lk, s % W, axis=2)
        lv = jnp.roll(lv, s % W, axis=2)
    return {"gk": gk, "gv": gv, "lk": lk, "lv": lv, "len": jnp.int32(s)}


def _windowed_decode(cfg: ModelConfig, params, cache, tokens, pos):
    """Decode with grouped window caches: a statically-unrolled layer loop
    (decode graphs are one token — 26 unrolled layers stay small), local
    layers on the ring path, global layers on the linear path."""
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)
    akw = _attn_kwargs(cfg)
    flags = global_flags(cfg)
    gk, gv, lk, lv = cache["gk"], cache["gv"], cache["lk"], cache["lv"]
    gi = li = 0
    new_g, new_l = [], []
    for i in range(cfg.n_layers):
        pl = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        if flags[i]:
            o, nc = attn_decode(
                pl["attn"], h, {"k": gk[gi], "v": gv[gi]}, pos, **akw
            )
            new_g.append(nc)
            gi += 1
        else:
            o, nc = attn_decode(
                pl["attn"], h, {"k": lk[li], "v": lv[li]}, pos, ring=True,
                **akw,
            )
            new_l.append(nc)
            li += 1
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + gated_mlp(h2, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, 0], params["embed"])
    out = {
        "gk": jnp.stack([c["k"] for c in new_g]),
        "gv": jnp.stack([c["v"] for c in new_g]),
        "lk": jnp.stack([c["k"] for c in new_l]),
        "lv": jnp.stack([c["v"] for c in new_l]),
        "len": cache["len"] + 1,
    }
    return logits, out


def _linear_cache_stack(cfg: ModelConfig, params, cache, x, pos):
    """Scanned layer stack over a linear (non-ring) KV cache.

    Shared by the one-token decode step and the chunked prefill-extend
    path: x is (b, s, d) with s >= 1 new tokens starting at position
    ``pos`` (scalar, or per-row ``(b,)`` for the continuous-batching slot
    layout).  Returns (x after final norm, k cache stack, v cache stack).
    """
    flags = jnp.asarray(global_flags(cfg))
    akw = _attn_kwargs(cfg)

    quant = "ks" in cache

    def body(x, xs):
        if quant:
            pl, is_global, kc, vc, ks, vs = xs
            layer_cache = {"k": kc, "v": vc, "ks": ks, "vs": vs}
        else:
            pl, is_global, kc, vc = xs
            layer_cache = {"k": kc, "v": vc}
        wv = layer_window(cfg, is_global)
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        o, nc = attn_decode(pl["attn"], h, layer_cache, pos, window=wv, **akw)
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_forward(pl["moe"], cfg, h2)
        else:
            y = gated_mlp(h2, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act)
        return x + y, (nc["k"], nc["v"])

    xs = (params["layers"], flags, cache["k"], cache["v"])
    if quant:
        xs = xs + (cache["ks"], cache["vs"])
    x, (kc, vc) = jax.lax.scan(body, x, xs)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), kc, vc


def _paged_cache_stack(cfg: ModelConfig, params, pool, pages, x, pos,
                       page_size: int, valid_len=None, scratch=None):
    """Scanned layer stack over the PAGED KV pool (DESIGN.md §13).

    pool: {"k","v"}: (L, P, page_size, g, hd) — one pooled buffer of
    physical pages shared by every slot; ``pages``: (b, n_pg) int32 page
    table mapping each row's logical positions to physical pages.  The
    body mirrors ``_linear_cache_stack`` operation-for-operation (same
    norms, same residual order, same attention math on the gathered rows)
    so paged and monolithic layouts produce bitwise-identical activations.
    int8-quantized pools are not supported (the serve engine gates them).
    """
    assert "ks" not in pool, "paged pools are fp-only"
    flags = jnp.asarray(global_flags(cfg))
    akw = _attn_kwargs(cfg)

    def body(x, xs):
        pl, is_global, kc, vc = xs
        wv = layer_window(cfg, is_global)
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        o, kc, vc = attn_decode_paged(
            pl["attn"], h, kc, vc, pages, pos, page_size=page_size,
            window=wv, valid_len=valid_len, scratch=scratch, **akw,
        )
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_forward(pl["moe"], cfg, h2)
        else:
            y = gated_mlp(h2, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act)
        return x + y, (kc, vc)

    xs = (params["layers"], flags, pool["k"], pool["v"])
    x, (kc, vc) = jax.lax.scan(body, x, xs)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), kc, vc


def decoder_only_decode(cfg: ModelConfig, params, cache, tokens, pos,
                        pages=None, page_size=None):
    """One decode step.  tokens: (b, 1); pos: scalar position of the new
    token, or ``(b,)`` per-row positions (continuous-batching slots).
    With ``pages``/``page_size`` the cache is a paged pool and reads/
    writes route through the page-table indirection (DESIGN.md §13)."""
    if pages is not None:
        dt = _dtype(cfg)
        x = embed(tokens, params["embed"], dt)
        x, kc, vc = _paged_cache_stack(cfg, params, cache, pages, x, pos,
                                       page_size)
        logits = unembed(x[:, 0], params["embed"])
        out = dict(cache, k=kc, v=vc)
        out["len"] = cache["len"] + 1
        return logits, out
    if "lk" in cache:
        return _windowed_decode(cfg, params, cache, tokens, pos)
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)
    x, kc, vc = _linear_cache_stack(cfg, params, cache, x, pos)
    logits = unembed(x[:, 0], params["embed"])
    out = dict(cache, k=kc, v=vc)
    out["len"] = cache["len"] + 1
    return logits, out


def decoder_only_extend(cfg: ModelConfig, params, cache, tokens, pos,
                        logit_index=None, pages=None, page_size=None,
                        valid_len=None, scratch=None):
    """Chunked prefill-extend: append a CHUNK of tokens to a linear cache.

    tokens: (b, C) land at positions pos..pos+C-1 (pos scalar or per-row
    ``(b,)``) with causal attention inside the chunk and full attention
    over the cache prefix.  Returns (logits (b, C, V) over ALL C
    positions, updated cache); with ``logit_index`` (a scalar chunk
    position, may be traced) only that position is unembedded —
    (b, 1, V) — which is what the serve engine's admission loop reads
    (unembedding a whole chunk against a real vocab is the dominant
    prefill cost, and only the last REAL prompt position's row is ever
    used; DESIGN.md §12).  Ring (grouped sliding-window) caches are not
    supported; serve lowers such archs to the masked linear-cache layout.
    With ``pages``/``page_size`` the chunk lands in a paged pool through
    the page-table indirection instead (DESIGN.md §13); ``valid_len``/
    ``scratch`` (paged only) route per-row pad tokens past ``valid_len``
    into a throwaway scratch page instead of through the table — the
    padded write barrier for bucketed prefill over the pool.
    """
    if "lk" in cache:
        raise NotImplementedError(
            "extend over grouped ring caches is unsupported; build the "
            "cache with window_cache=False (full-length + window mask)"
        )
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)
    if pages is not None:
        x, kc, vc = _paged_cache_stack(cfg, params, cache, pages, x, pos,
                                       page_size, valid_len=valid_len,
                                       scratch=scratch)
    else:
        assert valid_len is None and scratch is None, \
            "the padded write barrier is a paged-pool construct"
        x, kc, vc = _linear_cache_stack(cfg, params, cache, x, pos)
    if logit_index is not None:
        x = jax.lax.dynamic_index_in_dim(x, logit_index, axis=1,
                                         keepdims=True)
    logits = unembed(x, params["embed"])
    out = dict(cache, k=kc, v=vc)
    out["len"] = cache["len"] + tokens.shape[1]
    return logits, out


# ------------------------------------------------------------------ encdec
def encode(cfg: ModelConfig, params, frames):
    """frames: (b, F, d) stub embeddings -> encoder states (b, F, d)."""
    dt = _dtype(cfg)
    x = frames.astype(dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    akw = _attn_kwargs(cfg)

    def body(x, pl):
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        x = x + attn_forward(
            pl["attn"], h, positions, causal=False, impl=cfg.attn_impl, **akw
        )
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        return x + gated_mlp(h2, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_stack(cfg, params, x, positions, enc, *, collect_kv=False):
    akw = _attn_kwargs(cfg)

    def body(x, pl):
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        res = attn_forward(
            pl["self_attn"], h, positions, return_kv=collect_kv,
            impl="scan" if collect_kv else cfg.attn_impl, **akw,
        )
        o, kv = res if collect_kv else (res, None)
        if collect_kv:
            kv = jax.lax.optimization_barrier(kv)
            kv = tuple(constrain(t, "batch", "?seq", "kv", None) for t in kv)
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        x = x + attn_forward(
            pl["cross_attn"], h2, positions, enc=enc, impl=cfg.attn_impl, **akw
        )
        h3 = rms_norm(x, pl["ln3"], cfg.norm_eps)
        return x + gated_mlp(h3, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act), kv

    return jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])


def encdec_logits(cfg: ModelConfig, params, batch):
    dt = _dtype(cfg)
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _ = _dec_stack(cfg, params, x, positions, enc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.float32(0.0)


def encdec_prefill(cfg: ModelConfig, params, batch, cache_len: int):
    dt = _dtype(cfg)
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, kvs = _dec_stack(cfg, params, x, positions, enc, collect_kv=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["embed"])[:, 0]
    k_new, v_new = kvs
    pad = cache_len - s
    kc = jnp.pad(k_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, {"k": kc, "v": vc, "enc": enc, "len": jnp.int32(s)}


def encdec_decode(cfg: ModelConfig, params, cache, tokens, pos):
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)
    enc = cache["enc"]
    akw = _attn_kwargs(cfg)

    def body(x, xs):
        pl, kc, vc = xs
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        o, nc = attn_decode(pl["self_attn"], h, {"k": kc, "v": vc}, pos, **akw)
        x = x + o
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        o2, _ = attn_decode(pl["cross_attn"], h2, None, pos, enc=enc, **akw)
        x = x + o2
        h3 = rms_norm(x, pl["ln3"], cfg.norm_eps)
        return x + gated_mlp(h3, pl["mlp"]["wi"], pl["mlp"]["wo"], cfg.act), (
            nc["k"],
            nc["v"],
        )

    x, (kc, vc) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, 0], params["embed"])
    return logits, {"k": kc, "v": vc, "enc": enc, "len": cache["len"] + 1}
