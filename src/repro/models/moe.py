"""Mixture-of-Experts FFN with top-k routing, capacity, and EP sharding.

Dispatch is PER-ROW (each batch row routes its own tokens): the
(token, expert) assignments are argsorted WITHIN a row, ranked, dropped
beyond the per-row capacity, and scattered into per-row expert buffers
(b, E, C, d).  Because rows never mix, the whole dispatch is local to the
data shard that owns the row — no cross-device sort networks.  The only
collectives left are the genuine expert-parallel ones at the einsum
boundary: buf is batch-sharded, expert weights are experts- (moonshot,
E%16==0) or expert-ff- (qwen, 60e) sharded over 'model', and XLA
materializes the all-to-all / psum pair exactly there.

(§Perf note: the first implementation sorted GLOBALLY across the sharded
token axis — measured 717 s of collective time per step on
moonshot-v1-16b-a3b train_4k, 99% of the step.  Per-row dispatch removes
it; see EXPERIMENTS.md.)

Shared experts (qwen2-moe: 4, moonlight: 2) run densely over all tokens.
Aux load-balance loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

from .layers import init_linear

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, cfg, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_dff
    ks = jax.random.split(key, 4)
    p = {
        "router": init_linear(ks[0], (d, E), jnp.float32),
        "wi": init_linear(ks[1], (E, d, 2, f), dtype),
        "wo": init_linear(ks[2], (E, f, d), dtype),
    }
    if cfg.n_shared:
        p["shared_wi"] = init_linear(ks[3], (d, 2, cfg.n_shared * f), dtype)
        p["shared_wo"] = init_linear(ks[0], (cfg.n_shared * f, d), dtype)
    return p


def _dispatch_row(x_row, idx_row, gates_row, E, C, K):
    """One row: x (s, d), idx (s, K), gates (s, K) -> buf (E, C, d),
    plus (dest, tok, weight) for the return scatter."""
    s, d = x_row.shape
    eflat = idx_row.reshape(-1)  # (s*K,)
    order = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(s * K) - seg_start[sorted_e]
    keep = rank < C
    tok = order // K
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> dump row
    buf = jnp.zeros((E * C + 1, d), x_row.dtype).at[dest].set(x_row[tok])
    w = (gates_row.reshape(-1)[order] * keep).astype(x_row.dtype)
    return buf[: E * C].reshape(E, C, d), dest, tok, w


def moe_forward(p, cfg, x):
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar f32)."""
    dt = x.dtype
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(s * K / E * cfg.capacity_factor))  # per-row capacity

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (b, s, E)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (b, s, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style aux loss (global): E * sum_e fraction_e * mean_prob_e
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (b, s, K, E)
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    aux = E * jnp.sum(frac / K * jnp.mean(probs, axis=(0, 1)))

    buf, dest, tok, w = jax.vmap(
        lambda xr, ir, gr: _dispatch_row(xr, ir, gr, E, C, K)
    )(x, idx, gate_vals)
    # buf: (b, E, C, d) batch-sharded; expert weights model-sharded -> the
    # contraction boundary below is where EP collectives materialize.
    buf = constrain(buf, "batch", "experts", None, None)

    h = jnp.einsum("becd,edgf->becgf", buf, p["wi"].astype(dt))
    h = constrain(h, "batch", "experts", None, None, "ff")
    act = jax.nn.gelu if cfg.act == "geglu" else jax.nn.silu
    h = act(h[..., 0, :]) * h[..., 1, :]
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    out_buf = constrain(out_buf, "batch", "experts", None, None)
    out_flat = out_buf.reshape(b, E * C, d)

    def gather_row(ob_row, dest_row, tok_row, w_row):
        padded = jnp.concatenate(
            [ob_row, jnp.zeros((1, d), dt)], axis=0
        )[dest_row]  # (s*K, d)
        y = jnp.zeros((s, d), dt).at[tok_row].add(padded * w_row[:, None])
        return y

    y = jax.vmap(gather_row)(out_flat, dest, tok, w)

    if cfg.n_shared:
        hs = jnp.einsum("bsd,dgf->bsgf", x, p["shared_wi"].astype(dt))
        hs = constrain(hs, "batch", None, None, "ff")
        hs = act(hs[..., 0, :]) * hs[..., 1, :]
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_wo"].astype(dt))

    return constrain(y, "batch", None, None), aux
