"""Model configuration — one dataclass covers all ten assigned families.

Families: dense (GQA/MQA transformer, optional sliding window), moe,
ssm (Mamba2/SSD), hybrid (Mamba2 + shared attention), encdec (whisper
backbone, stub audio frontend), vlm (LM backbone + stub patch embeddings).
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"       # swiglu | geglu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # sliding-window attention (gemma3): every `global_every`-th layer is
    # global, the rest attend within `window`.
    window: int = 0
    global_every: int = 0
    window_cache: bool = True   # grouped window-sized KV cache for local layers
                                # (False = full-length cache + mask only; the
                                # §Perf baseline)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    expert_dff: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # hybrid (zamba2): one shared attention block applied after every
    # `attn_every` SSM layers.
    attn_every: int = 0

    # enc-dec (whisper): encoder depth and stub-frontend frame count.
    enc_layers: int = 0
    enc_frames: int = 0

    # vlm (internvl): stub patch-embedding prefix length.
    n_patches: int = 0

    # numerics / training
    kv_quant: bool = False      # int8 KV cache (dense/vlm decode; §Perf)
    attn_impl: str = "vjp"      # vjp | unrolled (§Perf baseline) | scan
    dtype: str = "bfloat16"     # compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)
    seq_parallel: bool = False  # Korthikanti-style: residual/norm activations
                                # shard over (model x sequence); AG/RS pairs
                                # replace the TP all-reduce (same bytes, 16x
                                # smaller saved activations)
    zero1: bool = True

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv, 1)

    def validate(self):
        if self.n_heads and self.n_kv:
            assert self.n_heads % self.n_kv == 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0 and self.d_inner % self.ssm_headdim == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0 and self.expert_dff > 0
        if self.family == "encdec":
            assert self.enc_layers > 0 and self.enc_frames > 0
        if self.family == "vlm":
            assert self.n_patches > 0
        if self.window:
            assert self.global_every > 0
        return self

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 * max(self.global_every, self.attn_every, 1)),
            d_model=128,
            n_heads=max(4, min(self.n_heads, 4)),
            n_kv=1 if self.n_kv == 1 else 2,
            head_dim=32,
            d_ff=256,
            vocab=512,
            window=min(self.window, 64) if self.window else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared=min(self.n_shared, 1) if self.n_shared else 0,
            expert_dff=64 if self.expert_dff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 128,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_frames=min(self.enc_frames, 32) if self.enc_frames else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            zero1=False,
        )
