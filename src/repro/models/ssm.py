"""Mamba2 / SSD (state-space duality) block, pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): quadratic
attention-like computation inside fixed-size chunks (MXU-friendly einsums)
plus a linear inter-chunk state scan.  Decode is the O(1)-per-token SSM
recurrence over a (heads, dstate, headdim) state plus a depthwise-conv ring.

All einsums accumulate in f32 (preferred_element_type) with bf16 operands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

from .layers import init_linear, rms_norm

__all__ = ["init_mamba2", "mamba2_forward", "mamba2_decode", "init_ssm_state"]


def _dims(cfg):
    d_in = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_headdim
    ds = cfg.ssm_state
    conv_ch = d_in + 2 * ds  # x, B, C share the conv (n_groups = 1)
    return d_in, h, p, ds, conv_ch


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in, h, p, ds, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * ds + h  # z, x, B, C, dt
    return {
        "in_proj": init_linear(ks[0], (d, proj_out), dtype),
        "conv_w": init_linear(ks[1], (cfg.ssm_conv, conv_ch), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (h,), jnp.float32, 1.0, 16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        ks[3], (h,), jnp.float32,
                        jnp.log(1e-3), jnp.log(1e-1),
                    )
                )
            )
        ).astype(jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": init_linear(ks[1], (d_in, d), dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_in, h, p, ds, _ = _dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ds, 2 * d_in + 2 * ds], axis=-1
    )
    return z, x, B, C, dt


def _causal_depthwise_conv(x, w, b):
    """x: (b, s, c); w: (W, c); left-padded causal depthwise conv + silu."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b[None, None, :])


def mamba2_forward(params, cfg, u, *, initial_state=None):
    """u: (b, s, d) -> (b, s, d).  s must be a multiple of cfg.ssm_chunk."""
    dt_ = u.dtype
    b, s, d = u.shape
    d_in, h, p, ds, conv_ch = _dims(cfg)
    Q = min(cfg.ssm_chunk, s)
    assert s % Q == 0, "sequence must be a multiple of ssm_chunk"
    nc = s // Q

    zxbcdt = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(dt_))
    z, x, B, C, dtraw = _split_proj(cfg, zxbcdt)
    xBC_raw = jnp.concatenate([x, B, C], axis=-1)
    xBC = _causal_depthwise_conv(
        xBC_raw, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_)
    )
    x, B, C = jnp.split(xBC, [d_in, d_in + ds], axis=-1)

    x = x.reshape(b, s, h, p).astype(jnp.float32)
    x = constrain(x, "batch", None, "heads", None)
    B = B.astype(jnp.float32)  # (b, s, ds) single group
    C = C.astype(jnp.float32)
    dt = jax.nn.softplus(
        dtraw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )  # (b, s, h)
    A = -jnp.exp(params["A_log"])  # (h,)
    dA = dt * A[None, None, :]  # (b, s, h), negative

    # ---- chunked SSD ----
    xc = x.reshape(b, nc, Q, h, p)
    Bc = B.reshape(b, nc, Q, ds)
    Cc = C.reshape(b, nc, Q, ds)
    dtc = dt.reshape(b, nc, Q, h)
    dAc = dA.reshape(b, nc, Q, h)
    cum = jnp.cumsum(dAc, axis=2)  # (b, nc, Q, h) within-chunk cumulative decay

    # Intra-chunk ("diagonal") term: attention-like with decay mask.
    # L[i, j] = exp(cum_i - cum_j) for i >= j else 0.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b, nc, Q, Q, h)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcqs,bcks->bcqk", Cc, Bc)  # (b, nc, Q, Q)
    y_diag = jnp.einsum(
        "bcqk,bcqkh,bckh,bckhp->bcqhp", scores, L, dtc, xc
    )

    # Chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    suffix = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, nc, Q, h)
    S_c = jnp.einsum("bcks,bckh,bckhp->bchsp", Bc, suffix * dtc, xc)

    # Inter-chunk scan: S_prev_{c} = exp(total_c-1) * S_prev_{c-1} + S_{c-1}
    total = jnp.exp(cum[:, :, -1, :])  # (b, nc, h) per-chunk total decay

    def scan_fn(S, inp):
        S_chunk, tot = inp  # (b, h, ds, p), (b, h)
        S_next = S * tot[..., None, None] + S_chunk
        return S_next, S

    S0 = (
        jnp.zeros((b, h, ds, p), jnp.float32)
        if initial_state is None
        else initial_state
    )
    S_last, S_prevs = jax.lax.scan(
        scan_fn,
        S0,
        (S_c.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # (b, nc, h, ds, p)

    # Off-diagonal term: y_off[i] = exp(cum_i) * C_i . S_prev
    y_off = jnp.einsum(
        "bcqs,bchsp,bcqh->bcqhp", Cc, S_prevs, jnp.exp(cum)
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + params["D"][None, None, :, None] * x
    y = y.reshape(b, s, d_in)

    # gated output norm + projection
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_),
        params["norm"],
        cfg.norm_eps,
    )
    y = constrain(y, "batch", None, "dinner")
    out = constrain(
        jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_)),
        "batch", None, None,
    )
    # conv ring state: the last W-1 raw inputs, zero-left-padded when the
    # prompt is shorter (matching the causal conv's own left padding — a
    # negative slice start would silently hand decode a short window)
    W1 = cfg.ssm_conv - 1
    tail = xBC_raw[:, max(0, s - W1):, :]
    if s < W1:
        tail = jnp.pad(tail, ((0, 0), (W1 - s, 0), (0, 0)))
    state = {"S": S_last, "conv": tail}
    return out, state


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    _, h, p, ds, conv_ch = _dims(cfg)
    return {
        "S": jnp.zeros((batch, h, ds, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba2_decode(params, cfg, u, state):
    """One-token step.  u: (b, 1, d); state: {"S","conv"}.  Returns (y, state)."""
    dt_ = u.dtype
    b = u.shape[0]
    d_in, h, p, ds, conv_ch = _dims(cfg)

    zxbcdt = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(dt_))
    z, x, B, C, dtraw = _split_proj(cfg, zxbcdt)
    xBC = jnp.concatenate([x, B, C], axis=-1)[:, 0]  # (b, conv_ch)

    # conv ring: window = [conv_state, new]
    win = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)  # (b, W, c)
    w = params["conv_w"].astype(dt_)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", win, w) + params["conv_b"].astype(dt_)
    )
    new_conv = win[:, 1:, :]
    x, B, C = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)

    x = x.reshape(b, h, p).astype(jnp.float32)
    B = B.astype(jnp.float32)  # (b, ds)
    C = C.astype(jnp.float32)
    dt = jax.nn.softplus(
        dtraw[:, 0].astype(jnp.float32) + params["dt_bias"][None, :]
    )  # (b, h)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (b, h)

    S = state["S"] * decay[..., None, None] + jnp.einsum(
        "bs,bh,bhp->bhsp", B, dt, x
    )
    y = jnp.einsum("bs,bhsp->bhp", C, S) + params["D"][None, :, None] * x
    y = y.reshape(b, 1, d_in)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_),
        params["norm"],
        cfg.norm_eps,
    )
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    return out, {"S": S, "conv": new_conv}
