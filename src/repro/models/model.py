"""Model dispatcher: one API over all six families.

    init_params(cfg, key)                        -> param pytree
    train_logits(cfg, params, batch)             -> (logits, aux_loss)
    prefill(cfg, params, batch, cache_len)       -> (last_logits, cache)
    decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
    extend_step(cfg, params, cache, tokens, pos) -> (chunk_logits, cache)

Every function is jit/lower-compatible (init works under jax.eval_shape for
the allocation-free dry-run).  ``decode_step`` additionally accepts per-row
``(b,)`` positions on linear-cache families, and ``extend_step`` appends a
whole token CHUNK to such a cache — together they are the substrate of the
continuous-batching serve engine (DESIGN.md §12).
"""
from __future__ import annotations

import jax

from .config import ModelConfig
from . import ssm_models, transformer

__all__ = ["init_params", "train_logits", "prefill", "decode_step",
           "extend_step", "abstract_params"]

_DENSE = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key):
    cfg.validate()
    if cfg.family in _DENSE:
        return transformer.init_decoder_only(key, cfg)
    if cfg.family == "encdec":
        return transformer.init_encdec(key, cfg)
    if cfg.family in ("ssm", "hybrid"):
        return ssm_models.init_ssm_stack(key, cfg)
    raise ValueError(cfg.family)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters — no allocation."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def train_logits(cfg: ModelConfig, params, batch):
    if cfg.family in _DENSE:
        return transformer.decoder_only_logits(cfg, params, batch)
    if cfg.family == "encdec":
        return transformer.encdec_logits(cfg, params, batch)
    if cfg.family == "ssm":
        return ssm_models.ssm_logits(cfg, params, batch)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_logits(cfg, params, batch)
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params, batch, cache_len: int):
    if cfg.family in _DENSE:
        return transformer.decoder_only_prefill(cfg, params, batch, cache_len)
    if cfg.family == "encdec":
        return transformer.encdec_prefill(cfg, params, batch, cache_len)
    if cfg.family == "ssm":
        return ssm_models.ssm_prefill(cfg, params, batch, cache_len)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_prefill(cfg, params, batch, cache_len)
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                pages=None, page_size=None):
    if pages is not None and cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged decode supports text-only linear-KV transformer "
            f"families (dense/moe), not {cfg.family}"
        )
    if cfg.family in _DENSE:
        return transformer.decoder_only_decode(
            cfg, params, cache, tokens, pos, pages=pages, page_size=page_size
        )
    if cfg.family == "encdec":
        return transformer.encdec_decode(cfg, params, cache, tokens, pos)
    if cfg.family == "ssm":
        return ssm_models.ssm_decode(cfg, params, cache, tokens, pos)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_decode(cfg, params, cache, tokens, pos)
    raise ValueError(cfg.family)


def extend_step(cfg: ModelConfig, params, cache, tokens, pos,
                logit_index=None, pages=None, page_size=None,
                valid_len=None, scratch=None):
    """Append a token chunk (b, C) at positions pos..pos+C-1 to a linear
    KV cache; returns (logits over all C positions — or just position
    ``logit_index`` when given — and the cache).  Text-only linear-cache
    transformer families — SSM/hybrid/encdec prefill state is not
    chunk-extendable through this API, and vlm is excluded because its
    cache layout reserves positions 0..n_patches-1 for the patch prefix
    that only a full prefill can place.  ``pages``/``page_size`` route
    the chunk through the paged pool layout (DESIGN.md §13);
    ``valid_len``/``scratch`` (paged only) are the padded write barrier —
    per-row pad tokens past ``valid_len`` scatter into the throwaway
    ``scratch`` page instead of through the table."""
    if cfg.family in ("dense", "moe"):
        return transformer.decoder_only_extend(
            cfg, params, cache, tokens, pos, logit_index=logit_index,
            pages=pages, page_size=page_size, valid_len=valid_len,
            scratch=scratch,
        )
    raise NotImplementedError(
        f"extend_step supports text-only linear-KV transformer families "
        f"(dense/moe), not {cfg.family}"
    )
