"""Model dispatcher: one API over all six families.

    init_params(cfg, key)                        -> param pytree
    train_logits(cfg, params, batch)             -> (logits, aux_loss)
    prefill(cfg, params, batch, cache_len)       -> (last_logits, cache)
    decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)

Every function is jit/lower-compatible (init works under jax.eval_shape for
the allocation-free dry-run).
"""
from __future__ import annotations

import jax

from .config import ModelConfig
from . import ssm_models, transformer

__all__ = ["init_params", "train_logits", "prefill", "decode_step", "abstract_params"]

_DENSE = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key):
    cfg.validate()
    if cfg.family in _DENSE:
        return transformer.init_decoder_only(key, cfg)
    if cfg.family == "encdec":
        return transformer.init_encdec(key, cfg)
    if cfg.family in ("ssm", "hybrid"):
        return ssm_models.init_ssm_stack(key, cfg)
    raise ValueError(cfg.family)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters — no allocation."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def train_logits(cfg: ModelConfig, params, batch):
    if cfg.family in _DENSE:
        return transformer.decoder_only_logits(cfg, params, batch)
    if cfg.family == "encdec":
        return transformer.encdec_logits(cfg, params, batch)
    if cfg.family == "ssm":
        return ssm_models.ssm_logits(cfg, params, batch)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_logits(cfg, params, batch)
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params, batch, cache_len: int):
    if cfg.family in _DENSE:
        return transformer.decoder_only_prefill(cfg, params, batch, cache_len)
    if cfg.family == "encdec":
        return transformer.encdec_prefill(cfg, params, batch, cache_len)
    if cfg.family == "ssm":
        return ssm_models.ssm_prefill(cfg, params, batch, cache_len)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_prefill(cfg, params, batch, cache_len)
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    if cfg.family in _DENSE:
        return transformer.decoder_only_decode(cfg, params, cache, tokens, pos)
    if cfg.family == "encdec":
        return transformer.encdec_decode(cfg, params, cache, tokens, pos)
    if cfg.family == "ssm":
        return ssm_models.ssm_decode(cfg, params, cache, tokens, pos)
    if cfg.family == "hybrid":
        return ssm_models.hybrid_decode(cfg, params, cache, tokens, pos)
    raise ValueError(cfg.family)
