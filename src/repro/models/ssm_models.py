"""SSM (mamba2) and hybrid (zamba2) stacks: init / forward / prefill / decode.

The hybrid follows Zamba2's shape: groups of ``attn_every`` Mamba2 layers
punctuated by ONE weight-shared attention+MLP block (simplification of the
2-block rotation, see DESIGN.md §7); leftover layers form an attention-free
tail.  Nested scans keep the HLO depth-independent; the shared block's
weights are closed over (identical on every invocation) while each
invocation owns a distinct KV cache slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.act_sharding import constrain

from .attention import attn_decode, attn_forward, init_attn
from .config import ModelConfig
from .layers import embed, gated_mlp, init_mlp, init_linear, init_norm, rms_norm, unembed
from .ssm import init_mamba2, init_ssm_state, mamba2_decode, mamba2_forward
from .transformer import _dtype, _maybe_remat, _pdtype, _attn_kwargs


def _hybrid_split(cfg: ModelConfig):
    g = cfg.attn_every
    groups = cfg.n_layers // g
    tail = cfg.n_layers - groups * g
    return groups, g, tail


def init_ssm_stack(key, cfg: ModelConfig):
    dt = _pdtype(cfg)
    kE, kL, kS = jax.random.split(key, 3)

    def blk(k):
        return {
            "ln": init_norm((cfg.d_model,), dt),
            "mamba": init_mamba2(k, cfg, dt),
        }

    p = {
        "embed": init_linear(kE, (cfg.vocab, cfg.d_model), dt, scale=0.02),
        "final_norm": init_norm((cfg.d_model,), dt),
    }
    if cfg.family == "ssm":
        p["layers"] = jax.vmap(blk)(jax.random.split(kL, cfg.n_layers))
        return p

    groups, g, tail = _hybrid_split(cfg)
    keys = jax.random.split(kL, (groups, g))
    p["groups"] = jax.vmap(jax.vmap(blk))(keys)
    if tail:
        p["tail"] = jax.vmap(blk)(jax.random.split(kS, tail))
    ks1, ks2 = jax.random.split(jax.random.fold_in(key, 7))
    p["shared"] = {
        "ln1": init_norm((cfg.d_model,), dt),
        "attn": init_attn(ks1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, dt),
        "ln2": init_norm((cfg.d_model,), dt),
        "mlp": init_mlp(ks2, cfg.d_model, cfg.d_ff, dt),
    }
    return p


# ----------------------------------------------------------------- forward
def _mamba_body(cfg, collect_state: bool):
    def body(x, pl):
        h = rms_norm(x, pl["ln"], cfg.norm_eps)
        o, st = mamba2_forward(pl["mamba"], cfg, h)
        return x + o, (st if collect_state else None)

    return body


def ssm_logits(cfg: ModelConfig, params, batch):
    dt = _dtype(cfg)
    x = embed(batch["tokens"], params["embed"], dt)
    body = _maybe_remat(_mamba_body(cfg, False), cfg)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.float32(0.0)


def ssm_prefill(cfg: ModelConfig, params, batch, cache_len: int):
    dt = _dtype(cfg)
    x = embed(batch["tokens"], params["embed"], dt)
    body = _maybe_remat(_mamba_body(cfg, True), cfg)
    x, states = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["embed"])[:, 0]
    return logits, {"ssm": states, "len": jnp.int32(batch["tokens"].shape[1])}


def ssm_decode(cfg: ModelConfig, params, cache, tokens, pos):
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)

    def body(x, xs):
        pl, st = xs
        h = rms_norm(x, pl["ln"], cfg.norm_eps)
        o, st2 = mamba2_decode(pl["mamba"], cfg, h, st)
        return x + o, st2

    x, states = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x[:, 0], params["embed"]), {"ssm": states, "len": cache["len"] + 1}


# ------------------------------------------------------------------ hybrid
def _shared_attn_fwd(cfg, shared, x, positions, *, collect_kv=False):
    akw = _attn_kwargs(cfg)
    h = rms_norm(x, shared["ln1"], cfg.norm_eps)
    res = attn_forward(
        shared["attn"], h, positions, return_kv=collect_kv,
        impl="scan" if collect_kv else cfg.attn_impl, **akw,
    )
    o, kv = res if collect_kv else (res, None)
    if collect_kv:
        kv = jax.lax.optimization_barrier(kv)
        kv = tuple(constrain(t, "batch", "?seq", "kv", None) for t in kv)
    x = x + o
    h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
    x = x + gated_mlp(h2, shared["mlp"]["wi"], shared["mlp"]["wo"], cfg.act)
    return x, kv


def hybrid_logits(cfg: ModelConfig, params, batch):
    dt = _dtype(cfg)
    x = embed(batch["tokens"], params["embed"], dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    inner = _mamba_body(cfg, False)

    def group_body(x, gp):
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = _shared_attn_fwd(cfg, params["shared"], x, positions)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(group_body, cfg), x, params["groups"])
    if "tail" in params:
        x, _ = jax.lax.scan(_maybe_remat(inner, cfg), x, params["tail"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["embed"]), jnp.float32(0.0)


def hybrid_prefill(cfg: ModelConfig, params, batch, cache_len: int):
    dt = _dtype(cfg)
    x = embed(batch["tokens"], params["embed"], dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    inner = _mamba_body(cfg, True)

    def group_body(x, gp):
        x, sts = jax.lax.scan(inner, x, gp)
        x, kv = _shared_attn_fwd(cfg, params["shared"], x, positions, collect_kv=True)
        return x, (sts, kv)

    x, (gstates, kvs) = jax.lax.scan(group_body, x, params["groups"])
    cache = {"groups": gstates, "len": jnp.int32(s)}
    k_new, v_new = kvs  # (G, b, s, g, hd)
    pad = cache_len - s
    cache["k"] = jnp.pad(k_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["v"] = jnp.pad(v_new, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if "tail" in params:
        x, tstates = jax.lax.scan(inner, x, params["tail"])
        cache["tail"] = tstates
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["embed"])[:, 0]
    return logits, cache


def hybrid_decode(cfg: ModelConfig, params, cache, tokens, pos):
    dt = _dtype(cfg)
    x = embed(tokens, params["embed"], dt)
    akw = _attn_kwargs(cfg)

    def inner(x, xs):
        pl, st = xs
        h = rms_norm(x, pl["ln"], cfg.norm_eps)
        o, st2 = mamba2_decode(pl["mamba"], cfg, h, st)
        return x + o, st2

    def group_body(x, xs):
        gp, gst, kc, vc = xs
        x, sts = jax.lax.scan(inner, x, (gp, gst))
        h = rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
        o, nc = attn_decode(
            params["shared"]["attn"], h, {"k": kc, "v": vc}, pos, **akw
        )
        x = x + o
        h2 = rms_norm(x, params["shared"]["ln2"], cfg.norm_eps)
        x = x + gated_mlp(
            h2, params["shared"]["mlp"]["wi"], params["shared"]["mlp"]["wo"], cfg.act
        )
        return x, (sts, nc["k"], nc["v"])

    x, (gstates, kc, vc) = jax.lax.scan(
        group_body, x, (params["groups"], cache["groups"], cache["k"], cache["v"])
    )
    out_cache = {"groups": gstates, "k": kc, "v": vc, "len": cache["len"] + 1}
    if "tail" in params:
        x, tstates = jax.lax.scan(inner, x, (params["tail"], cache["tail"]))
        out_cache["tail"] = tstates
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x[:, 0], params["embed"]), out_cache
