"""Model zoo: configs and family implementations (see DESIGN.md §6)."""
from .config import ModelConfig  # noqa: F401
from .model import (  # noqa: F401
    abstract_params,
    decode_step,
    extend_step,
    init_params,
    prefill,
    train_logits,
)
