"""PartitionSpec trees for parameters, optimizer state, batches, and caches.

``param_specs`` walks the abstract parameter pytree and applies ``_rule`` per
leaf.  Rules are expressed with NEGATIVE axis indices against the leaf's
CANONICAL (unstacked) rank, so scanned-layer stacks — which prepend one or
two stack dims — can never be sharded by accident:

    attn  wq/wk/wv (..., d, h, hd)   -> heads at -2
    attn  wo       (..., h, hd, d)   -> heads at -3
    mlp   wi       (..., d, 2, ff)   -> ff    at -1
    mlp   wo       (..., ff, d)      -> ff    at -2
    moe   wi       (..., E, d, 2, f) -> E at -4, else expert-ff at -1
    moe   wo       (..., E, f, d)    -> E at -3, else expert-ff at -2
    embed          (V, d)            -> vocab at -2 (vocab is padded to 128)
    mamba in_proj / out_proj         -> column / row parallel

Every assignment is guarded by divisibility against the model-axis size;
head_dim and stack dims are never sharded.  ZeRO-1 optimizer specs
additionally shard the first still-replicated divisible axis over the data
axes (``opt_state_specs``), which is what makes XLA materialize the
reduce-scatter/all-gather pair at the optimizer boundary (DESIGN.md §8).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "named_shardings",
]

_ATTN_PARENTS = ("attn", "self_attn", "cross_attn")


# ----------------------------------------------------------------- rules
def _rule(name, shape, model: int, *, parent=None, n_experts: int = 0):
    """Sharding rule for one leaf: list of mesh-axis names (len == rank)."""
    spec = [None] * len(shape)
    rank = len(shape)

    def shard(ax: int):
        """Shard negative axis ``ax`` over 'model' when valid & divisible."""
        if -ax <= rank and model > 1 and shape[ax] % model == 0:
            spec[rank + ax] = "model"

    if n_experts and name in ("wi", "wo"):
        # MoE expert weights: canonical wi (E, d, 2, f) / wo (E, f, d).
        e_ax = -4 if name == "wi" else -3
        if -e_ax <= rank and shape[e_ax] == n_experts and n_experts % model == 0:
            shard(e_ax)
        else:  # experts indivisible (qwen 60) -> shard the expert-ff dim
            shard(-1 if name == "wi" else -2)
        return spec
    if parent in _ATTN_PARENTS:
        if name in ("wq", "wk", "wv"):
            shard(-2)
        elif name == "wo":
            shard(-3)
        return spec
    if parent == "mlp":
        if name == "wi":
            shard(-1)
        elif name == "wo":
            shard(-2)
        return spec
    if parent == "mamba":
        if name == "in_proj":
            shard(-1)  # column-parallel over the packed zxBCdt projection
        elif name == "out_proj":
            shard(-2)  # row-parallel over d_inner
        return spec
    if name == "embed":
        shard(-2)  # vocab axis; padded to a multiple of 128
        return spec
    if name == "router":
        shard(-1)
        return spec
    return spec  # norms, biases, scalars: replicated


def _keys_of(path) -> list[str]:
    return [str(getattr(k, "key", k)) for k in path]


def _parent_of(keys) -> str | None:
    for k in reversed(keys[:-1]):
        if k in _ATTN_PARENTS:
            return "attn"
        if k in ("mlp", "moe", "mamba"):
            return k
    return None


def param_specs(params_abs, mesh, *, n_experts: int = 0):
    """PartitionSpec pytree matching ``params_abs`` for ``mesh``."""
    model = dict(mesh.shape).get("model", 1)

    def leaf_spec(path, leaf):
        keys = _keys_of(path)
        name, parent = keys[-1], _parent_of(keys)
        if parent == "moe":
            # shared experts are dense mlp weights living under the moe dict
            if name in ("shared_wi", "shared_wo"):
                return P(*_rule("w" + name[-1], leaf.shape, model, parent="mlp"))
            ne = n_experts if name in ("wi", "wo") else 0
            return P(*_rule(name, leaf.shape, model, n_experts=ne))
        return P(*_rule(name, leaf.shape, model, parent=parent))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat]
    )


# -------------------------------------------------------------- optimizer
def opt_state_specs(params_abs, pspecs, mesh, *, zero1: bool = True):
    """Specs for per-parameter optimizer tensors (m/v/f32 masters).

    With ``zero1`` the first axis that is still replicated in the parameter
    spec and divides the data-axis product additionally shards over the data
    axes — classic ZeRO-1 state partitioning on top of tensor parallelism.
    """
    sizes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = math.prod(sizes[a] for a in data_axes) if data_axes else 1

    def z(leaf, spec):
        if not zero1 or dsize <= 1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % dsize == 0 and leaf.shape[i] > 0:
                entries[i] = data_axes[0] if len(data_axes) == 1 else data_axes
                break
        return P(*entries)

    return jax.tree_util.tree_map(
        z, params_abs, pspecs, is_leaf=lambda x: isinstance(x, P)
    )


# ------------------------------------------------------------------ batch
def _is_abstract(x) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def batch_specs(batch_abs, mesh):
    """Shard the leading (global-batch) axis of every leaf over the data axes."""
    sizes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(leaf):
        if not leaf.shape:
            return P()
        axes = data_axes
        while axes and leaf.shape[0] % math.prod(sizes[a] for a in axes):
            axes = axes[:-1]
        if not axes:
            return P(*([None] * len(leaf.shape)))
        first = axes[0] if len(axes) == 1 else axes
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_abs, is_leaf=_is_abstract)


# ------------------------------------------------------------------ cache
# canonical (unstacked) rank and (batch_axis, model_axis) per cache leaf name;
# model_axis None = never tensor-sharded.  Leading extra dims are layer /
# group stacks and stay unsharded.
_CACHE_RULES = {
    "k": (4, 0, 2),     # (b, S, g, hd): batch at 0, kv heads at 2
    "v": (4, 0, 2),
    "gk": (4, 0, 2),
    "gv": (4, 0, 2),
    "lk": (4, 0, 2),
    "lv": (4, 0, 2),
    "ks": (2, 0, 1),    # int8 dequant scales (b, g)
    "vs": (2, 0, 1),
    "enc": (3, 0, None),  # encoder states (b, F, d)
    "S": (4, 0, None),    # SSM state (b, h, ds, p)
    "conv": (3, 0, None),  # conv ring (b, W, c)
}


def cache_specs(cache_abs, mesh, *, paged_pool: bool = False):
    """PartitionSpec tree for a decode cache: batch over data, KV heads over
    model when divisible; scan-stack dims and scalars replicated.

    ``paged_pool=True`` reads the k/v leaves as the PAGED pool layout
    (L, n_pages, page_size, g, hd) — same canonical rank with the page
    pool standing in for the batch axis and the within-page axis for the
    sequence axis (DESIGN.md §13).  The rules carry over unchanged except
    the GQA fallback: within-page offsets are far too small to shard, so
    indivisible KV heads fall back on the page-POOL axis instead.
    """
    sizes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = math.prod(sizes[a] for a in data_axes) if data_axes else 1
    model = sizes.get("model", 1)

    def spec(path, leaf):
        name = _keys_of(path)[-1]
        rank = len(leaf.shape)
        rule = _CACHE_RULES.get(name)
        if rule is None or rank < rule[0]:
            return P(*([None] * rank))
        canon, b_ax, m_ax = rule
        extra = rank - canon
        entries = [None] * rank
        if dsize > 1 and leaf.shape[extra + b_ax] % dsize == 0:
            entries[extra + b_ax] = (
                data_axes[0] if len(data_axes) == 1 else data_axes
            )
        if m_ax is not None and model > 1:
            if leaf.shape[extra + m_ax] % model == 0:
                entries[extra + m_ax] = "model"
            elif canon == 4 and paged_pool:
                # paged-pool GQA fallback: pages are interchangeable, so
                # spread the page-pool axis over "model" (stacking on top
                # of any data-axis assignment when the divisibility holds)
                # rather than the tiny within-page axis.
                cur = entries[extra + b_ax]
                if cur is None:
                    if leaf.shape[extra + b_ax] % model == 0:
                        entries[extra + b_ax] = "model"
                elif leaf.shape[extra + b_ax] % (dsize * model) == 0:
                    prev = cur if isinstance(cur, tuple) else (cur,)
                    entries[extra + b_ax] = prev + ("model",)
            elif canon == 4 and leaf.shape[extra + 1] % model == 0:
                # KV heads don't divide the model axis (GQA with few KV
                # heads, e.g. 8 heads on a 16-wide axis): shard the SEQUENCE
                # axis of the (b, S, g, hd) cache instead.  Attention over a
                # seq-sharded cache partitions as partial scores + the
                # softmax-stat reductions XLA inserts; the decode-step
                # cache update at a dynamic position lowers to a
                # shard-local dynamic-update-slice.  Without this fallback
                # such caches replicate over the whole model axis — 16x the
                # HBM for the dominant decode buffer.
                entries[extra + 1] = "model"
        return P(*entries)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


# ------------------------------------------------------------------- misc
def named_shardings(specs, mesh):
    """Map a pytree of PartitionSpecs (or one bare spec) to NamedShardings."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
