"""Distribution layer: activation sharding, spec trees, the RNS gradient
codec, and checkpoint fault detection (DESIGN.md §8).

Modules:
    act_sharding  logical-axis activation constraints (no-ops off-mesh)
    sharding      PartitionSpec trees for params / optimizer / batch / cache
    grad_codec    exact RNS gradient all-reduce with redundant channels
                  (detect with one, locate-and-correct with two)
    fault         tensor fingerprints + elastic checkpoint discovery +
                  in-place RRNS buffer repair
"""
from .act_sharding import constrain, current_mesh, use_mesh  # noqa: F401
from .fault import (  # noqa: F401
    WireStore,
    find_restorable,
    repair_packed,
    tensor_fingerprint,
    tree_fingerprints,
    verify_fingerprints,
)
from .grad_codec import GradCodec, rns_psum, rns_psum_tree  # noqa: F401
from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    named_shardings,
    opt_state_specs,
    param_specs,
)
