"""Distribution layer: activation sharding, spec trees, the RNS gradient
codec, and checkpoint fault detection (DESIGN.md §8).

Modules:
    act_sharding  logical-axis activation constraints (no-ops off-mesh)
    sharding      PartitionSpec trees for params / optimizer / batch / cache
    grad_codec    exact RNS gradient all-reduce with the redundant channel
    fault         tensor fingerprints + elastic checkpoint discovery
"""
from .act_sharding import constrain, current_mesh, use_mesh  # noqa: F401
from .fault import (  # noqa: F401
    find_restorable,
    tensor_fingerprint,
    tree_fingerprints,
    verify_fingerprints,
)
from .grad_codec import GradCodec, rns_psum  # noqa: F401
from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    named_shardings,
    opt_state_specs,
    param_specs,
)
