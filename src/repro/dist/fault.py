"""Fault detection and repair for checkpoints and tensor transport.

Content fingerprints (sha256 over dtype/shape/bytes) catch single-bit flips
in saved or relayed tensors; ``find_restorable`` walks a checkpoint
directory newest-first and returns the first step whose manifest AND tensor
contents verify — torn saves (no manifest after the atomic-rename protocol
in train/checkpoint.py) and corrupt steps are skipped, which is what makes
resume elastic to mid-save crashes (DESIGN.md §8).

``repair_packed`` is the finer-grained companion for RNS-codec state: where
a fingerprint mismatch can only trigger a rollback to the previous verified
checkpoint, a codec built with ``GradCodec.make(correct=True)`` carries two
redundant residue channels, so a single corrupted channel per element is
located and CORRECTED in place (DESIGN.md §10) and the step keeps going.

``WireStore`` packages the detect/locate-and-correct plumbing as a keyed
store of typed ``RnsArray`` wire fingerprints — the serve engine keys it by
request id (monolithic slot rows, DESIGN.md §12) or by physical cache page
(the paged pool, DESIGN.md §13), where one stored codeword serves every
reader of a shared page.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "tensor_fingerprint",
    "tree_fingerprints",
    "verify_fingerprints",
    "load_step",
    "load_verified",
    "scan_restorable",
    "find_restorable",
    "repair_packed",
    "WireStore",
]


def repair_packed(codec, packed, *, wraps: int = 0,
                  channel_major: bool = False):
    """Locate-and-correct a packed RNS codec buffer (wire or checkpoint).

    ``packed`` is leaf-major ``(..., n_channels)`` by default or the wire's
    channel-major ``(n_channels, B)`` with ``channel_major=True``; ``wraps``
    is 0 for fresh encodings / normalized sums / checkpointed codec state
    and ``world - 1`` for a raw post-psum buffer (see
    ``GradCodec.locate_fault``).

    Returns ``(repaired, report)`` where ``report`` is a host-side dict:
    ``repaired`` counts elements whose single bad channel was rebuilt from
    the survivors, ``unrecoverable`` counts elements with multi-channel
    corruption (left untouched — those still need the ``find_restorable``
    rollback path).  A clean buffer returns bitwise-unchanged with both
    counts zero.

    ``packed`` may also be a typed ``RnsArray`` (core/array.py) — its own
    channel axis then wins over ``channel_major``, and the repaired buffer
    comes back typed.
    """
    from repro.core.array import RnsArray

    if isinstance(packed, RnsArray):
        fixed, fault = codec.correct_packed(packed, wraps=wraps)
    else:
        buf = packed.T if channel_major else packed
        fixed, fault = codec.correct_packed(buf, wraps=wraps)
        fixed = fixed.T if channel_major else fixed
    report = {
        "repaired": int(jnp.sum(fault >= 0)),
        "unrecoverable": int(jnp.sum(fault == -2)),
    }
    return fixed, report


class WireStore:
    """Keyed store of typed RRNS wire fingerprints with detect/repair.

    Each entry is a channel-major ``RnsArray`` codeword (the output of
    ``codec.encode_array(..., channel_major=True)``) under an arbitrary
    hashable key — the serve engine uses request ids for monolithic slot
    rows and physical page ids for the paged pool, where ONE stored
    codeword covers every reader of a shared page: corrupt it and every
    reader's verify fails; repair it once and every reader re-verifies.

    ``stats`` accumulates across the store's lifetime:
      verified / failed           — ``matches`` outcomes (content checks)
      wire_ok / wire_corrupt      — ``ok`` outcomes (codeword self-checks)
      repaired / unrecoverable    — summed ``repair`` reports
    """

    def __init__(self, codec):
        self.codec = codec
        self.raw: dict = {}
        self.stats = {"verified": 0, "failed": 0, "wire_ok": 0,
                      "wire_corrupt": 0, "repaired": 0, "unrecoverable": 0}

    def __contains__(self, key) -> bool:
        return key in self.raw

    def __len__(self) -> int:
        return len(self.raw)

    def keys(self):
        return self.raw.keys()

    def put(self, key, arr) -> None:
        self.raw[key] = arr

    def get(self, key):
        return self.raw[key]

    def pop(self, key, default=None):
        return self.raw.pop(key, default)

    def clear(self) -> None:
        self.raw.clear()

    def matches(self, key, fresh) -> bool:
        """Bitwise compare a freshly encoded codeword against the stored
        one — the content-integrity check (recomputed fingerprint vs the
        fingerprint taken when the data froze)."""
        ok = bool(jnp.array_equal(fresh.residues, self.raw[key].residues))
        self.stats["verified" if ok else "failed"] += 1
        return ok

    def ok(self, key) -> bool:
        """Codeword self-consistency of the stored buffer (redundant-
        channel check) — detects corruption of the stored fingerprint
        itself, without touching the fingerprinted data."""
        good = bool(jnp.all(self.codec.verify_packed(self.raw[key])))
        self.stats["wire_ok" if good else "wire_corrupt"] += 1
        return good

    def repair(self, key) -> dict:
        """Locate-and-correct the stored codeword in place via
        ``repair_packed``; returns the per-call report dict."""
        fixed, report = repair_packed(self.codec, self.raw[key], wraps=0)
        self.raw[key] = fixed
        self.stats["repaired"] += report["repaired"]
        self.stats["unrecoverable"] += report["unrecoverable"]
        return report

    def corrupt(self, key, channel: int = 0, delta: int = 1,
                index: int = 0) -> None:
        """Fault injection for tests/drivers: modular-bump one residue of
        the stored codeword (stays a syntactically valid residue, so only
        the redundant channels can catch it)."""
        arr = self.raw[key]
        mods = tuple(self.codec.base.moduli) + self.codec.redundant
        m = mods[channel]
        res = arr.residues
        res = res.at[channel, index].set(
            (res[channel, index] + jnp.int32(delta)) % m
        )
        self.raw[key] = dataclasses.replace(arr, residues=res)


def tensor_fingerprint(arr) -> str:
    """Content hash of one (host or device) array: dtype, shape, raw bytes."""
    a = np.ascontiguousarray(np.asarray(arr))
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:32]


def _flat_named(tree) -> list[tuple[str, object]]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        ("/".join(str(getattr(k, "key", k)) for k in path), leaf)
        for path, leaf in leaves
    ]


def tree_fingerprints(tree) -> dict[str, str]:
    """{name: fingerprint} for every leaf, in flattening order."""
    return {name: tensor_fingerprint(leaf) for name, leaf in _flat_named(tree)}


def verify_fingerprints(tree, fingerprints: dict[str, str]) -> list[str]:
    """Names of leaves whose content does NOT match ``fingerprints``.

    A missing expected fingerprint counts as a mismatch; an empty list means
    the tree verifies clean.
    """
    bad = []
    for name, leaf in _flat_named(tree):
        if fingerprints.get(name) != tensor_fingerprint(leaf):
            bad.append(name)
    return bad


def load_step(path: str):
    """Load + verify one ``step_<N>`` dir: (manifest, {name: array}).

    Raises FileNotFoundError for a torn save (no manifest survived the
    atomic rename) or missing tensor file, IOError naming the bad leaves on
    fingerprint mismatch."""
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        raise FileNotFoundError(f"no manifest under {path} (torn save?)")
    with open(manifest_path) as f:
        manifest = json.load(f)
    flat = {
        name: np.load(os.path.join(path, f"{i}.npy"))
        for i, name in enumerate(manifest["names"])
    }
    bad = verify_fingerprints(
        flat, dict(zip(manifest["names"], manifest["fingerprints"]))
    )
    if bad:
        raise IOError(f"checkpoint {path} corrupt: {bad}")
    return manifest, flat


def load_verified(path: str):
    """Quiet variant of ``load_step``: None for torn/unreadable/corrupt."""
    try:
        return load_step(path)
    except Exception:
        return None


def scan_restorable(ckpt_dir: str):
    """Newest fully-verified step: (path, manifest, {name: array}) or None.

    Returns the loaded-and-verified contents so callers (checkpoint.restore)
    don't pay a second full read + hash of a multi-GB checkpoint."""
    if not ckpt_dir or not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append((int(d.split("_", 1)[1]), d))
            except ValueError:
                continue
    for _, d in sorted(steps, reverse=True):
        path = os.path.join(ckpt_dir, d)
        loaded = load_verified(path)
        if loaded is not None:
            return (path,) + loaded
    return None


def find_restorable(ckpt_dir: str) -> str | None:
    """Path of the newest fully-verified ``step_<N>`` directory, else None."""
    found = scan_restorable(ckpt_dir)
    return found[0] if found else None
