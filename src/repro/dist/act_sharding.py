"""Mesh-aware activation sharding constraints (DESIGN.md §8).

Model code annotates activations with LOGICAL axis names::

    x = constrain(x, "batch", None, "heads", None)

and this module translates them to ``jax.lax.with_sharding_constraint``
against the mesh installed by ``use_mesh`` — or does nothing at all when no
mesh is active, so the same model code runs unmodified on a laptop CPU and
under a 512-chip pjit lowering (the levanter/MaxText logical-axis pattern).

Logical -> physical mapping:

    batch                  -> the data axes ("pod", "data"), outermost kept
                              on divisibility fallback
    heads/kv/ff/dinner/
    experts/vocab/seq      -> "model"
    ?seq                   -> "model", soft: only if no other axis in the
                              same call claimed it (KV stacks: heads take
                              'model' when divisible, else the sequence does)
    ?batch_plus            -> data axes PLUS "model" when unclaimed (batch-
                              parallel attention for indivisible head counts)

Every assignment is divisibility-checked against the global dim, and a mesh
axis is never assigned twice within one call, so constraints can never make
a program ill-formed — they only inform the partitioner.
"""
from __future__ import annotations

import contextlib
import contextvars
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "current_mesh", "constrain", "logical_to_physical"]

_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_active_mesh", default=None
)

# logical names that map to the tensor-parallel axis
_MODEL_NAMES = frozenset(
    {"heads", "kv", "ff", "dinner", "experts", "vocab", "embed", "model", "seq"}
)


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the active mesh for ``constrain`` calls.

    Composes with the jax mesh context manager (``with mesh, use_mesh(mesh)``)
    and nests; ``use_mesh(None)`` explicitly disables constraints inside an
    outer active mesh.
    """
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def current_mesh():
    """The mesh installed by the innermost ``use_mesh``, or None."""
    return _ACTIVE_MESH.get()


def _data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(dim: int, axes: tuple[str, ...], sizes) -> tuple[str, ...]:
    """Longest prefix-preserving assignment: drop axes from the END until the
    remaining product divides ``dim`` (keeps 'data' when 'model' doesn't fit,
    keeps 'pod' before 'data', etc.)."""
    while axes:
        prod = math.prod(sizes[a] for a in axes)
        if prod <= 1 or dim % prod == 0:
            return axes if prod > 1 else ()
        axes = axes[:-1]
    return ()


def logical_to_physical(mesh, names, shape):
    """Resolve logical axis names to a PartitionSpec for ``shape`` on ``mesh``.

    Hard names resolve first (left to right), soft ``?``-prefixed names claim
    whatever is left.  Returns None when nothing shards.
    """
    if len(names) != len(shape):
        raise ValueError(f"{len(names)} names for rank-{len(shape)} tensor")
    sizes = dict(mesh.shape)
    entries: list = [None] * len(names)
    claimed: set[str] = set()

    def assign(i, axes):
        axes = _fit(shape[i], tuple(a for a in axes if a not in claimed), sizes)
        if axes:
            entries[i] = axes[0] if len(axes) == 1 else axes
            claimed.update(axes)

    for i, nm in enumerate(names):
        if nm is None or nm.startswith("?"):
            continue
        if nm == "batch":
            assign(i, _data_axes(mesh))
        elif nm in _MODEL_NAMES:
            if "model" in sizes:
                assign(i, ("model",))
        else:
            raise ValueError(f"unknown logical axis {nm!r}")

    for i, nm in enumerate(names):
        if nm is None or not nm.startswith("?"):
            continue
        key = nm[1:]
        if key == "batch_plus":
            cand = _data_axes(mesh)
            if "model" in sizes:
                cand = cand + ("model",)
            assign(i, cand)
        elif key in _MODEL_NAMES:
            if "model" in sizes:
                assign(i, ("model",))
        else:
            raise ValueError(f"unknown logical axis {nm!r}")

    if all(e is None for e in entries):
        return None
    return P(*entries)


def constrain(x, *names):
    """Apply a logical sharding constraint to ``x`` — no-op off-mesh.

    ``names`` has one entry per tensor axis: a logical name, a soft
    ``"?"``-prefixed name, or None.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_physical(mesh, names, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
