"""RNS gradient codec: exact distributed gradient aggregation (paper §4-5).

fp32 gradients quantize to fixed point (``frac_bits`` fractional bits), embed
signed into the RNS ring (residue channels for the base B plus the paper's
redundant ``m_a`` channel), and all-reduce PER CHANNEL as plain int32 sums.
Because the channel sum of encodings equals the encoding of the sum (ring
homomorphism, as long as the summed magnitude stays below M/2), decode after
the psum recovers the EXACT integer sum of the quantized per-replica
gradients — bitwise reproducible regardless of reduction order, unlike fp32
all-reduce.

The redundant channel rides along through every ring op, so sign tests,
magnitude clips, and consistency checks are single Algorithm-1 comparisons
(``compare_packed_ge``) — no reconstruction (DESIGN.md §4, §8).  With a
SECOND redundant modulus (``make(correct=True)``) the code becomes a
Redundant RNS that can *locate and correct* any single corrupted channel,
not just detect it: ``locate_fault`` / ``correct_packed`` (DESIGN.md §10).

Dynamic range budget (defaults): n=3 moduli of 15 bits gives M ~ 2**45;
``qmax = (M-1) // (2*world)`` guarantees ``world`` summed replicas stay
inside the signed embedding, so the decode is exact and the fused Pallas
kernels' 3-limb arithmetic (kernels/codec_{encode,decode}.py) applies.

Layouts — two appear throughout this module and the kernels:

* **leaf-major** ``(..., n_channels)``: channels last, one packed vector per
  gradient element.  The algebraic API (``fold``/``normalize``/``decode``/
  ``verify_packed``/``locate_fault``) speaks this layout.
* **channel-major** ``(n_channels, B)``: one contiguous row per channel —
  the kernels' native tile layout and the wire format of the bucketed
  transport (each row all-reduces as an independent int32 stream).

Both lift into the typed frontend ``repro.core.RnsArray`` (layout BASE_MA
for detect-only codecs, RRNS for locate-and-correct; ``channel_axis=0`` is
the wire layout): ``encode_array``/``as_array`` construct it, every
algebraic method here accepts it and returns it in kind, and the bucketed
transport (``tree_pack_rns``/``rns_psum_tree``) carries it end-to-end.

Transport comes in two granularities (DESIGN.md §9):

* ``rns_psum``     — one tensor, one per-channel psum (the original path).
* ``rns_psum_tree``— the WHOLE grad pytree flattened into one contiguous
  channel-major int32 buffer, moved in a single per-channel psum
  (NCCL-style bucketing) and unflattened after the fused decode.  One
  collective per step instead of one per leaf.

Both dispatch encode/decode to the fused Pallas kernels when the codec's
``fused`` knob is on and the base qualifies (bits <= 15 and M < 2**45 —
the 3x15-bit limb discipline); otherwise they fall back to the exact jnp
path automatically.

Doctest tour (see individual methods for details)::

    >>> import jax.numpy as jnp
    >>> from repro.dist.grad_codec import GradCodec
    >>> codec = GradCodec.make(world=2)          # 3 base channels + m_a
    >>> codec.n_channels
    4
    >>> packed = codec.encode(jnp.asarray([1.5, -0.25]))   # leaf-major
    >>> packed.shape
    (2, 4)
    >>> codec.decode(codec.fold(packed)).tolist()
    [1.5, -0.25]
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core.array import Layout, RnsArray
from repro.core.base import RNSBase, gen_coprime_moduli, make_base
from repro.core.compare import compare_packed_ge
from repro.core.convert import mrs_dot_mod, rns_to_tensor
from repro.core.mrc import mrc_unrolled, mrs_ge
from repro.core.signed import abs_ge_threshold, encode_signed, is_negative

__all__ = ["GradCodec", "rns_psum", "rns_psum_tree", "tree_pack",
           "tree_pack_rns", "tree_decode"]


@functools.lru_cache(maxsize=None)
def _survivor_tables(moduli: tuple, redundant: tuple, bits: int, wraps: int):
    """Static per-candidate-channel tables for RRNS fault location.

    For each channel c of the (base + redundant) set, build the *survivor*
    base (every modulus except m_c, with m_c as its Alg.-3 target) and the
    mixed-radix digits of the legitimate bound R = (wraps+1)*M in that base.
    A reconstruction-excluding-c lands below R iff c is consistent with the
    survivors — the locate test of DESIGN.md §10.
    """
    chans = tuple(moduli) + tuple(redundant)
    M = math.prod(moduli)
    R = (wraps + 1) * M
    tables = []
    for c, mc in enumerate(chans):
        surv = tuple(m for i, m in enumerate(chans) if i != c)
        if R >= math.prod(surv):
            raise ValueError(
                f"RRNS locate: legitimate range (wraps+1)*M = {R} does not "
                f"fit the survivor product of channel {c}; lower wraps "
                f"(usually world-1) or widen the redundant moduli"
            )
        sb = RNSBase(moduli=surv, ma=mc, bits=bits)
        digits, x = [], R
        for m in surv:
            digits.append(x % m)
            x //= m
        tables.append((sb, tuple(digits)))
    return tuple(tables)


@dataclasses.dataclass(frozen=True)
class GradCodec:
    """Static codec configuration; hashable, closed over by jitted steps.

    ``mb`` is the optional SECOND redundant modulus (``make(correct=True)``):
    with it, the packed layout grows to ``(..., n+2)`` and the codec can
    locate-and-correct a single corrupted channel (``correct_packed``), not
    just detect one (``verify_packed``).
    """

    base: RNSBase
    frac_bits: int
    world: int
    fused: bool = True
    mb: int | None = None

    @classmethod
    def make(cls, *, world: int, n: int = 3, bits: int = 15,
             frac_bits: int = 16, fused: bool = True,
             correct: bool = False) -> "GradCodec":
        """Codec sized for ``world`` replicas: per-replica magnitudes up to
        ``qmax`` sum without leaving the signed range (-M/2, M/2).

        ``fused`` enables the Pallas encode/decode kernels on the transport
        path when the base qualifies (see ``use_fused``); the jnp path is
        always available and bitwise identical.

        ``correct=True`` adds the second redundant modulus ``m_b``.  The
        redundant pair is then the TWO LARGEST primes of the generated set
        (base moduli the next n down): the locate test's exactness needs
        ``m_a * m_b > m_c * m_e`` for every pair of surviving channels
        (DESIGN.md §10), which "redundant = largest" guarantees.

        >>> GradCodec.make(world=2).n_channels          # detect-only
        4
        >>> rrns = GradCodec.make(world=2, correct=True)
        >>> rrns.n_channels, rrns.mb is not None        # locate-and-correct
        (5, True)
        """
        if world < 1:
            raise ValueError("world must be >= 1")
        mb = None
        if correct:
            ms = gen_coprime_moduli(n + 2, bits=bits)  # descending primes
            base = RNSBase(moduli=tuple(ms[2:]), ma=ms[0], bits=bits)
            mb = ms[1]
        else:
            base = make_base(n, bits=bits)
        codec = cls(base=base, frac_bits=frac_bits, world=world, fused=fused,
                    mb=mb)
        if codec.qmax < 1:
            raise ValueError(
                f"world={world} leaves no dynamic range for base M={base.M}"
            )
        return codec

    @property
    def redundant(self) -> tuple[int, ...]:
        """The redundant moduli, in channel order: (m_a,) or (m_a, m_b)."""
        return (self.base.ma,) if self.mb is None else (self.base.ma, self.mb)

    @property
    def layout(self) -> Layout:
        """The ``RnsArray`` layout this codec's buffers carry:
        detect-only -> BASE_MA, locate-and-correct -> RRNS."""
        return Layout.BASE_MA if self.mb is None else Layout.RRNS

    def as_array(self, buf, *, channel_major: bool = False) -> RnsArray:
        """Lift a raw packed codec buffer (leaf-major ``(..., n_channels)``
        or wire-layout ``(n_channels, B)``) into a typed ``RnsArray``."""
        return RnsArray.from_packed(
            self.base, buf, signed=True, mb=self.mb,
            channel_axis=0 if channel_major else -1,
        )

    def _split(self, p):
        """(channels-last buffer, RnsArray-or-None) for dual-API methods."""
        if isinstance(p, RnsArray):
            return p.to_packed(), p
        return p, None

    @staticmethod
    def _rejoin(buf_cl, proto):
        """Rebuild the caller's type: RnsArray (matching ``proto``'s storage
        layout) when the input was typed, the raw buffer otherwise."""
        if proto is None:
            return buf_cl
        return RnsArray(
            buf_cl, proto.base, layout=proto.layout, signed=proto.signed,
            channel_axis=-1, mb=proto.mb,
        ).with_channel_axis(proto.channel_axis)

    @property
    def n_channels(self) -> int:
        """Total packed channels: n base + 1 or 2 redundant."""
        return self.base.n + len(self.redundant)

    @property
    def use_fused(self) -> bool:
        """True when transport runs the fused Pallas kernels: the knob is on
        AND the base fits the kernels' limb discipline (15-bit int32 lanes,
        M < 2**45 for the 3x15-bit Horner).  Wider bases silently take the
        exact jnp path — same bits on the wire, more HBM round-trips.

        >>> from repro.dist.grad_codec import GradCodec
        >>> GradCodec.make(world=2).use_fused        # 3x15-bit: kernels on
        True
        >>> GradCodec.make(world=2, n=4).use_fused   # M ~ 2**60: jnp path
        False

        An explicit ``repro.core.backend(...)`` context overrides the
        codec's own ``fused`` flag (read at trace time, DESIGN.md §11):
        "jnp" forces the reference path, "pallas" opts qualifying bases in
        even when the codec was built with ``fused=False``.

        >>> from repro.core import backend
        >>> with backend("jnp"):
        ...     GradCodec.make(world=2).use_fused
        False
        """
        from repro.core.dispatch import get_backend

        setting = get_backend()
        if setting == "jnp":
            return False
        want = self.fused or setting == "pallas"
        return want and self.base.bits <= 15 and self.base.M < (1 << 45)

    @property
    def qmax(self) -> int:
        """Max per-replica quantized magnitude (world of them sum exactly)."""
        return (self.base.M - 1) // (2 * self.world)

    @property
    def clip(self) -> float:
        """Float clip range implied by qmax at the quantization step."""
        return self.qmax / (1 << self.frac_bits)

    # ----------------------------------------------------------- transport
    def encode(self, g):
        """fp32 tensor (...,) -> packed int32 residue tensor, leaf-major
        ``(..., n_channels)``.

        Quantization happens in f64 so the clip at ``qmax`` (~2**35 for
        world=512) is exact; the residues themselves are exact integer
        arithmetic from there on.  Requires global x64 (repro/__init__
        enables it) — without it jax silently degrades f64 to f32 and the
        clip/residues go wrong, so refuse loudly.  The fused kernel path
        (``encode_packed`` with ``use_fused``) has no such dependency.

        >>> import jax.numpy as jnp
        >>> from repro.dist.grad_codec import GradCodec
        >>> codec = GradCodec.make(world=2)
        >>> codec.encode(jnp.asarray([0.5])).shape    # needs x64: see above
        (1, 4)
        """
        if not jax.config.jax_enable_x64:
            raise RuntimeError(
                "GradCodec.encode requires jax_enable_x64: the exact f64 "
                "quantize/clip silently degrades to f32 without it "
                "(import repro enables x64, or use the fused kernel path)"
            )
        q = jnp.clip(
            jnp.round(g.astype(jnp.float64) * (1 << self.frac_bits)),
            -float(self.qmax), float(self.qmax),
        ).astype(jnp.int64)
        packed = encode_signed(self.base, q)
        if self.mb is None:
            return packed
        # second redundant channel: (q mod M) mod m_b, same signed shift
        xb = jnp.mod(q, self.mb)
        xb = jnp.where(
            q < 0, jnp.mod(xb + self.base.M % self.mb, self.mb), xb
        )
        return jnp.concatenate(
            [packed, xb[..., None].astype(packed.dtype)], axis=-1
        )

    def encode_packed(self, g, *, channel_major: bool = False):
        """Transport-path encode: the fused Pallas kernel when ``use_fused``
        else the jnp path — bitwise-identical residues either way.

        ``channel_major=True`` returns the kernel-native ``(n_channels, B)``
        layout for a flat ``(B,)`` input (the bucketed pipeline's wire
        format); the default is leaf-major ``(..., n_channels)``.

        >>> import jax.numpy as jnp
        >>> from repro.dist.grad_codec import GradCodec
        >>> codec = GradCodec.make(world=2)
        >>> codec.encode_packed(jnp.ones((2, 3))).shape       # leaf-major
        (2, 3, 4)
        >>> codec.encode_packed(jnp.ones((6,)),
        ...                     channel_major=True).shape     # wire layout
        (4, 6)
        """
        if self.use_fused:
            from repro.kernels import codec_encode_op

            return codec_encode_op(self, g, channel_major=channel_major)
        if channel_major:
            # match the kernel's layout exactly: ravel THEN transpose, so
            # non-1D inputs don't come out axis-reversed on the fallback
            return self.encode(jnp.ravel(g)).T
        return self.encode(g)

    def encode_array(self, g, *, channel_major: bool = False) -> RnsArray:
        """Typed transport-path encode: ``encode_packed`` lifted into an
        ``RnsArray`` (layout BASE_MA or RRNS per the codec, ``signed=True``,
        channel-major storage for the wire format).

        >>> import jax.numpy as jnp
        >>> from repro.dist.grad_codec import GradCodec
        >>> arr = GradCodec.make(world=2, correct=True).encode_array(
        ...     jnp.ones((6,)), channel_major=True)
        >>> arr.layout.name, arr.residues.shape      # wire layout, typed
        ('RRNS', (5, 6))
        """
        return self.as_array(
            self.encode_packed(g, channel_major=channel_major),
            channel_major=channel_major,
        )

    def decode_summed(self, summed, *, channel_major: bool = False):
        """Transport-path decode of post-psum channel sums: fused
        fold->MRC->Horner->sign->scale kernel when ``use_fused`` else the
        jnp fold+decode — bitwise-identical f32 either way.  ``summed`` may
        be raw (``channel_major`` says which layout) or an ``RnsArray``
        (layout read off the type)."""
        if isinstance(summed, RnsArray):
            channel_major = summed.channel_axis == 0
            summed = summed.residues
        if self.use_fused:
            from repro.kernels import codec_decode_op

            return codec_decode_op(self, summed, channel_major=channel_major)
        folded = self.fold(summed.T if channel_major else summed)
        return self.decode(folded)

    def fold(self, summed):
        """Reduce per-channel sums back into canonical residues (< m_i).
        Accepts the raw packed buffer or an ``RnsArray`` (returned in
        kind)."""
        summed, proto = self._split(summed)
        m = jnp.asarray(
            tuple(self.base.moduli) + self.redundant, dtype=summed.dtype
        )
        return self._rejoin(jnp.mod(summed, m), proto)

    def decode(self, folded):
        """Folded packed tensor (raw or ``RnsArray``) -> f32 values (exact
        up to the f32 cast)."""
        folded, _ = self._split(folded)
        v = rns_to_tensor(self.base, folded[..., : self.base.n])
        half = (self.base.M + 1) // 2
        v = jnp.where(v >= half, v - self.base.M, v)
        return (v.astype(jnp.float64) * (2.0 ** -self.frac_bits)).astype(
            jnp.float32
        )

    # ------------------------------------------- Algorithm-1 ring queries
    def _alg1_view(self, folded):
        """The (..., n+1) slice Algorithm-1 queries consume: base residues
        plus the m_a channel (the m_b channel, when present, is correction
        metadata and plays no part in comparisons)."""
        folded, _ = self._split(folded)
        return folded[..., : self.base.n + 1]

    def is_negative(self, folded):
        """Sign test without reconstruction: one Alg.-1 comparison.

        Requires a CONSISTENT redundant channel (fresh encodings are; sums of
        W > 1 replicas need ``normalize`` first — the summed embeddings wrap
        mod M while the carried m_a channel does not)."""
        return is_negative(self.base, self._alg1_view(folded))

    def abs_ge(self, folded, thr: int):
        """|value| >= thr (in quantized units): two Alg.-1 comparisons.
        Same consistency requirement as ``is_negative``."""
        return abs_ge_threshold(self.base, self._alg1_view(folded), int(thr))

    def normalize(self, folded):
        """Rebuild consistent redundant channels from the base residues
        (one MRC + one Alg.-3 dot per redundant channel — the cost of a
        comparison).  Identity on fresh encodings; after a W-replica psum it
        re-anchors m_a (and m_b) to the wrapped value so Alg.-1 queries
        apply to the sum.

        NOTE: normalize overwrites the redundant channels from the base
        residues, so it forfeits their error-detection/correction power —
        run ``verify_packed`` / ``correct_packed`` BEFORE normalizing."""
        folded, proto = self._split(folded)
        x = folded[..., : self.base.n]
        digits = mrc_unrolled(self.base, x)
        xr = mrs_dot_mod(self.base, digits, self.redundant)
        return self._rejoin(
            jnp.concatenate([x, xr.astype(x.dtype)], axis=-1), proto
        )

    def verify_packed(self, folded):
        """Redundant-channel consistency check (transit corruption detector).

        Each replica encodes with consistent channels, so after summing W
        replicas ``carried - recomputed`` must equal ``k * (M mod m_r)`` mod
        m_r where k < W counts the embeddings' wraps mod M.  Any other offset
        means a channel was corrupted in transit — the codec-level analogue
        of dist/fault fingerprints, at one MRC per element.

        With the second redundant modulus the check sharpens: both channels
        must recover the SAME wrap count k, so corruption of either
        redundant channel is always caught (the other still holds the true
        k), and base-channel corruption must fool two independent moduli at
        once to slip through.

        Discriminating power requires ``world < m_a``: with more replicas
        than residues the offset family covers the whole group and every
        channel value is accepted (the check degenerates to always-True)."""
        folded, _ = self._split(folded)
        x = folded[..., : self.base.n]
        digits = mrc_unrolled(self.base, x)
        recomputed = mrs_dot_mod(self.base, digits, self.redundant)

        def wrap_count(carried, rec, mr: int):
            delta = jnp.mod(
                carried.astype(jnp.int64) - rec.astype(jnp.int64), mr
            )
            # gcd(M, m_r) = 1, so the wrap count is recoverable in O(1):
            # k = delta * (M mod m_r)^{-1} mod m_r, valid iff k <= world
            inv = pow(self.base.M % mr, -1, mr)
            return jnp.mod(delta * inv, mr)

        ka = wrap_count(folded[..., self.base.n], recomputed[..., 0],
                        self.base.ma)
        ok = ka <= min(self.world, self.base.ma - 1)
        if self.mb is not None:
            kb = wrap_count(folded[..., self.base.n + 1],
                            recomputed[..., 1], self.mb)
            ok = ok & (kb <= min(self.world, self.mb - 1)) & (ka == kb)
        return ok

    # ------------------------------------------- RRNS locate-and-correct
    def _fault_scan(self, folded, wraps: int):
        """Per-channel (consistent?, corrected-residue) candidates.

        For each channel c: MRC over the n+1 SURVIVING channels, compare the
        reconstruction against R = (wraps+1)*M in mixed radix (int32-safe
        lexicographic compare — no big-int arithmetic on device), and keep
        the Alg.-3 extension of the reconstruction back to m_c as the
        replacement residue should c turn out to be the faulty one.
        """
        folded, _ = self._split(folded)
        if self.mb is None:
            raise ValueError(
                "fault location needs the second redundant modulus: build "
                "the codec with GradCodec.make(correct=True)"
            )
        tables = _survivor_tables(
            self.base.moduli, self.redundant, self.base.bits, int(wraps)
        )
        chans = tuple(self.base.moduli) + self.redundant
        oks, fixes = [], []
        for c, (sb, r_digits) in enumerate(tables):
            xs = jnp.concatenate(
                [folded[..., :c], folded[..., c + 1:]], axis=-1
            )
            d = mrc_unrolled(sb, xs)
            bound = jnp.broadcast_to(
                jnp.asarray(r_digits, dtype=d.dtype), d.shape
            )
            oks.append(~mrs_ge(d, bound))  # reconstruction-sans-c < R
            fixes.append(mrs_dot_mod(sb, d, (chans[c],))[..., 0])
        return jnp.stack(oks, axis=-1), jnp.stack(fixes, axis=-1)

    def _verdict(self, ok):
        """Per-element fault verdict from the exclusion flags: -1 clean
        (every exclusion lands in range), channel index on a unique hit,
        -2 uncorrectable otherwise.  Shared by locate_fault/correct_packed
        so the two can never disagree on the same buffer."""
        cnt = jnp.sum(ok, axis=-1)
        return jnp.where(
            cnt == self.n_channels,
            jnp.int32(-1),
            jnp.where(cnt == 1, jnp.argmax(ok, axis=-1).astype(jnp.int32),
                      jnp.int32(-2)),
        )

    def locate_fault(self, folded, *, wraps: int = 0):
        """Locate a single corrupted channel per element: int32 tensor over
        ``folded.shape[:-1]`` holding the channel index in [0, n_channels),
        ``-1`` for a clean codeword, or ``-2`` for an uncorrectable one
        (more than one channel corrupted, or location ambiguous).

        ``wraps`` bounds the legitimate value range at (wraps+1)*M: 0 for
        fresh encodings, normalized sums, and checkpointed codec state;
        ``world - 1`` for a raw post-psum buffer (whose channel sums
        represent an integer below world*M).  Location is EXACT at wraps=0
        (DESIGN.md §10); at wraps>0 a corruption can occasionally look
        consistent with more than one channel, which reports -2 (refuse)
        rather than ever miscorrecting silently.

        >>> import jax.numpy as jnp
        >>> from repro.dist.grad_codec import GradCodec
        >>> rrns = GradCodec.make(world=2, correct=True)
        >>> buf = rrns.encode(jnp.asarray([3.0, -2.0]))
        >>> bad = buf.at[0, 1].add(5)            # corrupt channel 1, elt 0
        >>> rrns.locate_fault(bad).tolist()      # elt 1 stays clean
        [1, -1]
        """
        ok, _ = self._fault_scan(folded, wraps)
        return self._verdict(ok)

    def correct_packed(self, folded, *, wraps: int = 0):
        """Locate-and-correct: returns ``(corrected, fault)`` where
        ``fault`` is ``locate_fault``'s verdict and ``corrected`` equals
        ``folded`` with each single-fault element's bad channel rebuilt by
        base extension from the n+1 surviving channels (clean and
        uncorrectable elements pass through untouched).

        >>> import jax.numpy as jnp
        >>> from repro.dist.grad_codec import GradCodec
        >>> rrns = GradCodec.make(world=2, correct=True)
        >>> buf = rrns.encode(jnp.asarray([3.0, -2.0]))
        >>> fixed, fault = rrns.correct_packed(buf.at[0, 1].add(5))
        >>> bool(jnp.all(fixed == buf))
        True
        """
        folded, proto = self._split(folded)
        ok, fixes = self._fault_scan(folded, wraps)
        fault = self._verdict(ok)
        hit = fault[..., None] == jnp.arange(self.n_channels, dtype=jnp.int32)
        fixed = jnp.where(hit, fixes.astype(folded.dtype), folded)
        return self._rejoin(fixed, proto), fault

    def range_ok(self, p1, p2):
        """Packed-ge usable as an overflow guard: (p1 >= p2) per Alg. 1."""
        return compare_packed_ge(
            self.base, self._alg1_view(p1), self._alg1_view(p2)
        )


def rns_psum(codec: GradCodec, g, axis_name: str):
    """Exact mean-gradient all-reduce over a shard_map/pmap axis.

    encode -> per-channel int32 psum -> fold -> decode -> / axis size.
    The channel psum is the ONLY collective; everything else is local, and
    encode/decode run fused (Pallas) when the codec qualifies.
    """
    packed = codec.encode_packed(g)
    summed = jax.lax.psum(packed, axis_name)
    # psum of an unmapped constant folds to the static axis size at trace
    # time — no collective is emitted for it
    nd = jax.lax.psum(1.0, axis_name)
    return codec.decode_summed(summed) / nd


# ------------------------------------------------------ bucketed transport
@dataclasses.dataclass(frozen=True)
class _TreeMeta:
    """Trace-time bookkeeping for the single-buffer layout (static)."""

    treedef: object
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[object, ...]

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(math.prod(s) for s in self.shapes)


def tree_pack(codec: GradCodec, grads):
    """Flatten a grad pytree into ONE contiguous channel-major
    ``(n_channels, B_total)`` int32 wire buffer (encode fused when the codec
    qualifies).

    Returns ``(buf, meta)``; ``meta`` is static trace-time layout info for
    ``tree_decode``.  This is the NCCL-style bucketing move: the whole tree
    then all-reduces in a single per-channel psum instead of one collective
    per leaf.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if not leaves:
        raise ValueError("tree_pack: empty gradient pytree")
    meta = _TreeMeta(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves),
        dtypes=tuple(l.dtype for l in leaves),
    )
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return codec.encode_packed(flat, channel_major=True), meta


def tree_pack_rns(codec: GradCodec, grads):
    """``tree_pack`` with a typed wire buffer: the whole grad pytree as ONE
    channel-major ``RnsArray`` (layout BASE_MA/RRNS per the codec).  This is
    what the train step carries between encode, fault repair, and the psum —
    the repair path (``correct_packed``) and the optimizer-boundary decode
    consume the type directly instead of transposing raw buffers."""
    buf, meta = tree_pack(codec, grads)
    return codec.as_array(buf, channel_major=True), meta


def tree_decode(codec: GradCodec, summed, meta: _TreeMeta, denom=1.0):
    """Post-psum channel-major ``(n_channels, B_total)`` sums (raw or
    ``RnsArray``) -> grad pytree / ``denom``.

    Decode runs fused (one HBM round-trip) when the codec qualifies; the
    flat result is sliced back into leaves with ``meta``'s layout and cast
    to each leaf's original dtype.
    """
    # decode_summed reads the layout off RnsArray inputs itself; the kwarg
    # only matters for raw buffers
    flat = codec.decode_summed(summed, channel_major=True) / denom
    leaves, off = [], 0
    for shape, dtype, size in zip(meta.shapes, meta.dtypes, meta.sizes):
        leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(meta.treedef, leaves)


def rns_psum_tree(codec: GradCodec, grads, axis_name: str):
    """Exact mean-gradient all-reduce of an ENTIRE pytree in one collective.

    tree_pack_rns -> one per-channel int32 psum over the channel-major
    ``RnsArray`` bucket (a pytree with one int32 leaf, so the psum is still
    a single collective) -> fused decode -> unflatten.  Exactness is per
    element, so bucketing changes nothing semantically — it only amortizes
    collective latency that the per-leaf path pays once per tensor.
    """
    arr, meta = tree_pack_rns(codec, grads)
    summed = jax.lax.psum(arr, axis_name)  # the ONLY collective
    nd = jax.lax.psum(1.0, axis_name)      # folds to a constant at trace
    return tree_decode(codec, summed, meta, denom=nd)
