"""RNS gradient codec: exact distributed gradient aggregation (paper §4-5).

fp32 gradients quantize to fixed point (``frac_bits`` fractional bits), embed
signed into the RNS ring (residue channels for the base B plus the paper's
redundant ``m_a`` channel), and all-reduce PER CHANNEL as plain int32 sums.
Because the channel sum of encodings equals the encoding of the sum (ring
homomorphism, as long as the summed magnitude stays below M/2), decode after
the psum recovers the EXACT integer sum of the quantized per-replica
gradients — bitwise reproducible regardless of reduction order, unlike fp32
all-reduce.

The redundant channel rides along through every ring op, so sign tests,
magnitude clips, and consistency checks are single Algorithm-1 comparisons
(``compare_packed_ge``) — no reconstruction (DESIGN.md §4, §8).

Dynamic range budget (defaults): n=3 moduli of 15 bits gives M ~ 2**45;
``qmax = (M-1) // (2*world)`` guarantees ``world`` summed replicas stay
inside the signed embedding, so the decode is exact and the fused Pallas
kernels' 3-limb arithmetic (kernels/codec_{encode,decode}.py) applies.

Transport comes in two granularities (DESIGN.md §9):

* ``rns_psum``     — one tensor, one per-channel psum (the original path).
* ``rns_psum_tree``— the WHOLE grad pytree flattened into one contiguous
  (n+1, B_total) int32 buffer, moved in a single per-channel psum
  (NCCL-style bucketing) and unflattened after the fused decode.  One
  collective per step instead of one per leaf.

Both dispatch encode/decode to the fused Pallas kernels when the codec's
``fused`` knob is on and the base qualifies (bits <= 15 and M < 2**45 —
the 3x15-bit limb discipline); otherwise they fall back to the exact jnp
path automatically.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.base import RNSBase, make_base
from repro.core.compare import compare_packed_ge
from repro.core.convert import rns_to_tensor, to_ma
from repro.core.mrc import mrc_unrolled
from repro.core.signed import abs_ge_threshold, encode_signed, is_negative

__all__ = ["GradCodec", "rns_psum", "rns_psum_tree", "tree_pack",
           "tree_decode"]


@dataclasses.dataclass(frozen=True)
class GradCodec:
    """Static codec configuration; hashable, closed over by jitted steps."""

    base: RNSBase
    frac_bits: int
    world: int
    fused: bool = True

    @classmethod
    def make(cls, *, world: int, n: int = 3, bits: int = 15,
             frac_bits: int = 16, fused: bool = True) -> "GradCodec":
        """Codec sized for ``world`` replicas: per-replica magnitudes up to
        ``qmax`` sum without leaving the signed range (-M/2, M/2).

        ``fused`` enables the Pallas encode/decode kernels on the transport
        path when the base qualifies (see ``use_fused``); the jnp path is
        always available and bitwise identical.
        """
        if world < 1:
            raise ValueError("world must be >= 1")
        base = make_base(n, bits=bits)
        codec = cls(base=base, frac_bits=frac_bits, world=world, fused=fused)
        if codec.qmax < 1:
            raise ValueError(
                f"world={world} leaves no dynamic range for base M={base.M}"
            )
        return codec

    @property
    def use_fused(self) -> bool:
        """True when transport runs the fused Pallas kernels: the knob is on
        AND the base fits the kernels' limb discipline (15-bit int32 lanes,
        M < 2**45 for the 3x15-bit Horner).  Wider bases silently take the
        exact jnp path — same bits on the wire, more HBM round-trips."""
        return (
            self.fused and self.base.bits <= 15 and self.base.M < (1 << 45)
        )

    @property
    def qmax(self) -> int:
        """Max per-replica quantized magnitude (world of them sum exactly)."""
        return (self.base.M - 1) // (2 * self.world)

    @property
    def clip(self) -> float:
        """Float clip range implied by qmax at the quantization step."""
        return self.qmax / (1 << self.frac_bits)

    # ----------------------------------------------------------- transport
    def encode(self, g):
        """fp32 tensor (...,) -> packed int32 residue tensor (..., n+1).

        Quantization happens in f64 so the clip at ``qmax`` (~2**35 for
        world=512) is exact; the residues themselves are exact integer
        arithmetic from there on.  Requires global x64 (repro/__init__
        enables it) — without it jax silently degrades f64 to f32 and the
        clip/residues go wrong, so refuse loudly.  The fused kernel path
        (``encode_packed`` with ``use_fused``) has no such dependency.
        """
        if not jax.config.jax_enable_x64:
            raise RuntimeError(
                "GradCodec.encode requires jax_enable_x64: the exact f64 "
                "quantize/clip silently degrades to f32 without it "
                "(import repro enables x64, or use the fused kernel path)"
            )
        q = jnp.clip(
            jnp.round(g.astype(jnp.float64) * (1 << self.frac_bits)),
            -float(self.qmax), float(self.qmax),
        ).astype(jnp.int64)
        return encode_signed(self.base, q)

    def encode_packed(self, g, *, channel_major: bool = False):
        """Transport-path encode: the fused Pallas kernel when ``use_fused``
        else the jnp path — bitwise-identical residues either way.

        channel_major=True returns the kernel-native (n+1, B) layout for a
        flat (B,) input (the bucketed pipeline's wire format)."""
        if self.use_fused:
            from repro.kernels import codec_encode_op

            return codec_encode_op(self, g, channel_major=channel_major)
        if channel_major:
            # match the kernel's layout exactly: ravel THEN transpose, so
            # non-1D inputs don't come out axis-reversed on the fallback
            return self.encode(jnp.ravel(g)).T
        return self.encode(g)

    def decode_summed(self, summed, *, channel_major: bool = False):
        """Transport-path decode of post-psum channel sums: fused
        fold->MRC->Horner->sign->scale kernel when ``use_fused`` else the
        jnp fold+decode — bitwise-identical f32 either way."""
        if self.use_fused:
            from repro.kernels import codec_decode_op

            return codec_decode_op(self, summed, channel_major=channel_major)
        folded = self.fold(summed.T if channel_major else summed)
        return self.decode(folded)

    def fold(self, summed):
        """Reduce per-channel sums back into canonical residues (< m_i)."""
        m = jnp.asarray(
            tuple(self.base.moduli) + (self.base.ma,), dtype=summed.dtype
        )
        return jnp.mod(summed, m)

    def decode(self, folded):
        """Folded packed tensor -> f32 values (exact up to the f32 cast)."""
        v = rns_to_tensor(self.base, folded[..., :-1])
        half = (self.base.M + 1) // 2
        v = jnp.where(v >= half, v - self.base.M, v)
        return (v.astype(jnp.float64) * (2.0 ** -self.frac_bits)).astype(
            jnp.float32
        )

    # ------------------------------------------- Algorithm-1 ring queries
    def is_negative(self, folded):
        """Sign test without reconstruction: one Alg.-1 comparison.

        Requires a CONSISTENT redundant channel (fresh encodings are; sums of
        W > 1 replicas need ``normalize`` first — the summed embeddings wrap
        mod M while the carried m_a channel does not)."""
        return is_negative(self.base, folded)

    def abs_ge(self, folded, thr: int):
        """|value| >= thr (in quantized units): two Alg.-1 comparisons.
        Same consistency requirement as ``is_negative``."""
        return abs_ge_threshold(self.base, folded, int(thr))

    def normalize(self, folded):
        """Rebuild a consistent redundant channel from the base residues
        (one MRC + one Alg.-3 dot — the cost of a single comparison).
        Identity on fresh encodings; after a W-replica psum it re-anchors
        m_a to the wrapped value so Alg.-1 queries apply to the sum."""
        x = folded[..., :-1]
        xa = to_ma(self.base, mrc_unrolled(self.base, x))
        return jnp.concatenate([x, xa[..., None].astype(x.dtype)], axis=-1)

    def verify_packed(self, folded):
        """Redundant-channel consistency check (transit corruption detector).

        Each replica encodes with a consistent channel, so after summing W
        replicas ``carried - recomputed`` must equal ``k * (M mod m_a)`` mod
        m_a where k < W counts the embeddings' wraps mod M.  Any other offset
        means a channel was corrupted in transit — the codec-level analogue
        of dist/fault fingerprints, at one MRC per element.

        Discriminating power requires ``world < m_a``: with more replicas
        than residues the offset family covers the whole group and every
        channel value is accepted (the check degenerates to always-True)."""
        x, xa = folded[..., :-1], folded[..., -1]
        recomputed = to_ma(self.base, mrc_unrolled(self.base, x))
        delta = jnp.mod(
            xa.astype(jnp.int64) - recomputed.astype(jnp.int64), self.base.ma
        )
        # gcd(M, m_a) = 1, so the wrap count is recoverable in O(1):
        # k = delta * (M mod m_a)^{-1} mod m_a, valid iff k <= world
        inv = pow(self.base.M_mod_ma, -1, self.base.ma)
        k = jnp.mod(delta * inv, self.base.ma)
        return k <= min(self.world, self.base.ma - 1)

    def range_ok(self, p1, p2):
        """Packed-ge usable as an overflow guard: (p1 >= p2) per Alg. 1."""
        return compare_packed_ge(self.base, p1, p2)


def rns_psum(codec: GradCodec, g, axis_name: str):
    """Exact mean-gradient all-reduce over a shard_map/pmap axis.

    encode -> per-channel int32 psum -> fold -> decode -> / axis size.
    The channel psum is the ONLY collective; everything else is local, and
    encode/decode run fused (Pallas) when the codec qualifies.
    """
    packed = codec.encode_packed(g)
    summed = jax.lax.psum(packed, axis_name)
    # psum of an unmapped constant folds to the static axis size at trace
    # time — no collective is emitted for it
    nd = jax.lax.psum(1.0, axis_name)
    return codec.decode_summed(summed) / nd


# ------------------------------------------------------ bucketed transport
@dataclasses.dataclass(frozen=True)
class _TreeMeta:
    """Trace-time bookkeeping for the single-buffer layout (static)."""

    treedef: object
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[object, ...]

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(math.prod(s) for s in self.shapes)


def tree_pack(codec: GradCodec, grads):
    """Flatten a grad pytree into ONE contiguous (n+1, B_total) int32 wire
    buffer (encode fused when the codec qualifies).

    Returns ``(buf, meta)``; ``meta`` is static trace-time layout info for
    ``tree_decode``.  This is the NCCL-style bucketing move: the whole tree
    then all-reduces in a single per-channel psum instead of one collective
    per leaf.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if not leaves:
        raise ValueError("tree_pack: empty gradient pytree")
    meta = _TreeMeta(
        treedef=treedef,
        shapes=tuple(tuple(l.shape) for l in leaves),
        dtypes=tuple(l.dtype for l in leaves),
    )
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return codec.encode_packed(flat, channel_major=True), meta


def tree_decode(codec: GradCodec, summed, meta: _TreeMeta, denom=1.0):
    """Post-psum (n+1, B_total) channel sums -> grad pytree / ``denom``.

    Decode runs fused (one HBM round-trip) when the codec qualifies; the
    flat result is sliced back into leaves with ``meta``'s layout and cast
    to each leaf's original dtype.
    """
    flat = codec.decode_summed(summed, channel_major=True) / denom
    leaves, off = [], 0
    for shape, dtype, size in zip(meta.shapes, meta.dtypes, meta.sizes):
        leaves.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(meta.treedef, leaves)


def rns_psum_tree(codec: GradCodec, grads, axis_name: str):
    """Exact mean-gradient all-reduce of an ENTIRE pytree in one collective.

    tree_pack -> one per-channel int32 psum over the (n+1, B_total) bucket
    -> fused decode -> unflatten.  Exactness is per element, so bucketing
    changes nothing semantically — it only amortizes collective latency
    that the per-leaf path pays once per tensor.
    """
    buf, meta = tree_pack(codec, grads)
    summed = jax.lax.psum(buf, axis_name)  # the ONLY collective
    nd = jax.lax.psum(1.0, axis_name)      # folds to a constant at trace
    return tree_decode(codec, summed, meta, denom=nd)
