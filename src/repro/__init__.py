"""repro — RNS-comparison framework (Didier et al.) on JAX/TPU.

x64 is enabled globally: the RNS core needs genuine int64 lanes for 31-bit
moduli profiles and for the tensor<->RNS codecs.  All model/training code is
dtype-explicit (bf16/f32/int32) so this does not change numerics elsewhere.
"""
import jax

jax.config.update("jax_enable_x64", True)
