"""Signed-value embedding on top of the RNS ring, with sign/magnitude tests
driven by the paper's comparison (Algorithm 1).

A signed v with |v| < M/2 embeds as X = v mod M.  Then:

    v >= 0   <=>   X < ceil(M/2)   <=>   NOT RNSComp_ge(X, ceil(M/2))

so *sign detection costs exactly one comparison* — one MRC — instead of a
full reconstruction.  This is the primitive the gradient codec uses for
overflow checks and magnitude clipping (DESIGN.md §4).

The typed frontend is ``RnsArray.encode_signed`` / ``.is_negative`` /
``.abs_ge`` (core/array.py); the public functions here are legacy shims.
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import RNSBase
from .compare import _compare_ge_impl

__all__ = ["encode_signed", "is_negative", "abs_ge_threshold"]


def _encode_signed_impl(base: RNSBase, v):
    """Signed int tensor -> packed residue tensor (..., n+1), last = m_a."""
    from .convert import tensor_to_rns

    res = tensor_to_rns(base, v)
    # redundant channel must hold (v mod M) mod m_a == v mod m_a shifted into
    # [0, m_a) the same way (m_a does NOT divide M, so correct via M mod m_a).
    v64 = v.astype(jnp.int64)
    xa = jnp.mod(v64, base.ma)
    xa = jnp.where(v64 < 0, jnp.mod(xa + base.M_mod_ma, base.ma), xa)
    return jnp.concatenate([res, xa[..., None].astype(res.dtype)], axis=-1)


def _is_negative_impl(base: RNSBase, packed):
    """True where the packed value encodes v < 0.  One Alg.-1 comparison."""
    x, xa = packed[..., :-1], packed[..., -1]
    t = jnp.asarray(base.half_M_residues, dtype=x.dtype)
    t = jnp.broadcast_to(t, x.shape)
    ta = jnp.asarray(base.half_M_ma, dtype=xa.dtype)
    ta = jnp.broadcast_to(ta, xa.shape)
    return _compare_ge_impl(base, x, xa, t, ta, unroll=True)  # X >= ceil(M/2)


def _abs_ge_impl(base: RNSBase, packed, thr: int):
    """True where |v| >= thr (0 < thr < M/2).  Two Alg.-1 comparisons:

        v >= 0:  X >= thr
        v <  0:  X <= M - thr   i.e.  NOT (X >= M - thr + 1)
    """
    x, xa = packed[..., :-1], packed[..., -1]

    def cmp_const(c: int):
        cr = jnp.broadcast_to(jnp.asarray(base.residues_of(c), dtype=x.dtype), x.shape)
        ca = jnp.broadcast_to(jnp.asarray(c % base.ma, dtype=xa.dtype), xa.shape)
        return _compare_ge_impl(base, x, xa, cr, ca, unroll=True)

    neg = _is_negative_impl(base, packed)
    ge_thr = cmp_const(thr)                    # pos case: X >= thr
    ge_mirror = cmp_const(base.M - thr + 1)    # neg case: X > M - thr fails
    return jnp.where(neg, ~ge_mirror, ge_thr)


# ------------------------------------------------------------ legacy shims
def encode_signed(base: RNSBase, v):
    """Signed int tensor -> packed residue tensor (..., n+1), last = m_a.
    Legacy shim over ``RnsArray.encode_signed``."""
    from .array import RnsArray

    return RnsArray.encode_signed(base, v).to_packed()


def is_negative(base: RNSBase, packed):
    """True where the packed value encodes v < 0.  Legacy shim over
    ``RnsArray.is_negative``."""
    from .array import RnsArray

    return RnsArray.from_packed(base, packed, signed=True).is_negative()


def abs_ge_threshold(base: RNSBase, packed, thr: int):
    """True where |v| >= thr (0 < thr < M/2).  Legacy shim over
    ``RnsArray.abs_ge``."""
    from .array import RnsArray

    return RnsArray.from_packed(base, packed, signed=True).abs_ge(thr)
