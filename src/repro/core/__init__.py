"""Core RNS library — the paper's contribution as composable JAX modules.

Public API re-exports; see DESIGN.md §2 for the inventory.

The typed frontend is ``RnsArray`` (+ the ``backend`` context manager for
jnp/pallas dispatch, DESIGN.md §11); the loose functions below it are the
implementations it routes through, kept public as legacy shims.
"""
from .array import Layout, RnsArray  # noqa: F401
from .dispatch import (  # noqa: F401
    backend,
    get_backend,
    interpret_default,
    resolve_backend,
)
from .base import RNSBase, gen_coprime_moduli, make_base  # noqa: F401
from .arith import add, sub, mul, neg, mul_const  # noqa: F401
from .mrc import mrc, mrc_unrolled, mrs_ge, mrs_to_int  # noqa: F401
from .mrc_tree import mrc_tree  # noqa: F401
from .convert import (  # noqa: F401
    to_ma,
    mrs_dot_mod,
    int_to_rns,
    rns_to_int,
    tensor_to_rns,
    rns_to_tensor,
)
from .compare import (  # noqa: F401
    rns_compare_ge,
    classic_compare_ge,
    approx_crt_ge,
    compare_packed_ge,
)
from .extend import extend_mrc, extend_shenoy, extend_kawamura  # noqa: F401
from .signed import encode_signed, is_negative, abs_ge_threshold  # noqa: F401
from .division import (  # noqa: F401
    pack,
    unpack,
    divmod_rns,
    halve,
    scale_pow2,
    parity,
)
from .montgomery import (  # noqa: F401
    RNSMontgomery,
    DualRep,
    mont_mul,
    ladder_step,
    mont_consts,
)
