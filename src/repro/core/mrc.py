"""Mixed-Radix Conversion (Alg. 2 of the paper) and MRS utilities.

``mrc`` computes the mixed-radix digits a_1..a_n of X from its residues:

    X = a_1 + a_2 m_1 + a_3 m_1 m_2 + ... + a_n m_1...m_{n-1}     (eq. 2)

The triangular recurrence is inherently sequential in j but fully parallel in
the channel index i and across batch elements.  The JAX implementation runs
the j-loop as a ``fori_loop`` (depth n-1) and vectorizes everything else —
the paper's "parallel inner loop ⇒ O(n) time", with batch elements on VPU
lanes providing the throughput (DESIGN.md §3).

Work: n(n-1)/2 modular multiplications — exactly the paper's Table 1 count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import RNSBase

__all__ = ["mrc", "mrs_ge", "mrs_to_int", "mrc_unrolled"]


def mrc(base: RNSBase, x):
    """Mixed-radix digits of a batched residue tensor ``x: (..., n)``.

    Returns digits ``(..., n)`` with 0 <= a_i < m_i.  Layout is leaf-major
    (channels on the LAST axis), matching all of ``repro.core``; the Pallas
    kernels use the transposed channel-major tiles (see kernels/ops.py).

    >>> import jax.numpy as jnp
    >>> from repro.core.base import RNSBase
    >>> from repro.core.mrc import mrc, mrs_to_int
    >>> base = RNSBase(moduli=(3, 5, 7), ma=11, bits=15)
    >>> x = jnp.asarray([[52 % 3, 52 % 5, 52 % 7]])  # residues of X = 52
    >>> digits = mrc(base, x)
    >>> digits.tolist()                              # 52 = 1 + 2*3 + 3*15
    [[1, 2, 3]]
    >>> mrs_to_int(base, digits[0])
    52
    """
    m = jnp.asarray(base.moduli_np, dtype=x.dtype)
    inv = jnp.asarray(base.inv_tri_np, dtype=x.dtype)  # inv[j, i] = m_j^{-1} mod m_i
    n = base.n
    idx = jnp.arange(n)

    def body(j, w):
        a_j = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=-1)  # (..., 1)
        inv_j = jax.lax.dynamic_index_in_dim(inv, j, axis=0, keepdims=False)
        d = w - a_j
        d = jnp.where(d < 0, d + m, d)          # (w - a_j) mod m_i, branch-free
        upd = jnp.mod(d * inv_j, m)             # < 2**30 in int32 lanes
        return jnp.where(idx > j, upd, w)       # freeze digits a_1..a_j

    return jax.lax.fori_loop(0, n - 1, body, x) if n > 1 else x


def mrc_unrolled(base: RNSBase, x):
    """Unrolled variant (identical math).  Better for tiny n where the
    fori_loop's dynamic slicing dominates; used by the gradient codec.

    >>> import jax.numpy as jnp
    >>> from repro.core.base import RNSBase
    >>> from repro.core.mrc import mrc, mrc_unrolled
    >>> base = RNSBase(moduli=(3, 5, 7), ma=11, bits=15)
    >>> x = jnp.asarray([[1, 2, 3], [0, 4, 6]])
    >>> bool((mrc_unrolled(base, x) == mrc(base, x)).all())
    True
    """
    m = jnp.asarray(base.moduli_np, dtype=x.dtype)
    inv = base.inv_tri_np
    n = base.n
    w = x
    cols = [w[..., 0]]
    for j in range(n - 1):
        a_j = cols[j][..., None]
        d = w - a_j
        d = jnp.where(d < 0, d + m, d)
        w = jnp.mod(d * jnp.asarray(inv[j], dtype=x.dtype), m)
        cols.append(w[..., j + 1])
    return jnp.stack(cols, axis=-1)


def mrs_ge(d1, d2):
    """Lexicographic >= on mixed-radix digit tensors ``(..., n)``.

    MRS is positional with a_n most significant, so compare at the most
    significant differing digit.  This is the digit-compare step of the
    classical (Szabo–Tanaka / Flores) method — our baseline (and the
    range test of the RRNS fault locator, DESIGN.md §10).

    >>> import jax.numpy as jnp
    >>> from repro.core.mrc import mrs_ge
    >>> d52 = jnp.asarray([1, 2, 3])   # digits of 52 in base (3, 5, 7)
    >>> d51 = jnp.asarray([0, 2, 3])   # digits of 51
    >>> bool(mrs_ge(d52, d51)), bool(mrs_ge(d51, d52))
    (True, False)
    """
    neq = d1 != d2
    n = d1.shape[-1]
    # Highest differing position: argmax over reversed mask finds the first
    # True from the most significant end.
    rev_first = jnp.argmax(neq[..., ::-1], axis=-1)
    pos = n - 1 - rev_first
    a = jnp.take_along_axis(d1, pos[..., None], axis=-1)[..., 0]
    b = jnp.take_along_axis(d2, pos[..., None], axis=-1)[..., 0]
    any_neq = jnp.any(neq, axis=-1)
    return jnp.where(any_neq, a > b, True)


def mrs_to_int(base: RNSBase, digits) -> int:
    """Exact Python-int value of a single digit vector (tests/debug only)."""
    digits = list(int(v) for v in digits)
    acc, w = 0, 1
    for a, m in zip(digits, base.moduli):
        acc += a * w
        w *= m
    return acc
