"""Channel-wise RNS arithmetic on batched residue tensors.

Residue tensors have shape ``(..., n)`` — the trailing axis is the RNS
channel axis.  All ops are exact ring operations mod m_i per channel and are
vectorization-friendly: on TPU the batch dims map onto VPU lanes while the
small channel axis stays in-register (DESIGN.md §3).

Overflow discipline (the reason ``bits<=15`` ⇒ int32 lanes is safe):
  * add/sub intermediates are in (-m, 2m) ⊂ int32,
  * products of two reduced residues are < 2**30,
  * data-parallel psum of <=2**16 residues is < 2**31.
"""
from __future__ import annotations

import jax.numpy as jnp

from .base import RNSBase

__all__ = ["add", "sub", "mul", "neg", "mul_const", "modt"]


def _m(base: RNSBase, like):
    return jnp.asarray(base.moduli_np, dtype=like.dtype)


def modt(base: RNSBase, x):
    """Reduce an (over-ranged but in-dtype) tensor channel-wise mod m_i."""
    return jnp.mod(x, _m(base, x))


def add(base: RNSBase, x, y):
    m = _m(base, x)
    s = x + y
    return jnp.where(s >= m, s - m, s)


def sub(base: RNSBase, x, y):
    m = _m(base, x)
    d = x - y
    return jnp.where(d < 0, d + m, d)


def neg(base: RNSBase, x):
    m = _m(base, x)
    return jnp.where(x == 0, x, m - x)


def mul(base: RNSBase, x, y):
    """Product of reduced residues; fits the lane dtype by construction."""
    return jnp.mod(x * y, _m(base, x))


def mul_const(base: RNSBase, x, c):
    """x * c with c a per-channel constant vector (n,) of reduced residues."""
    c = jnp.asarray(c, dtype=x.dtype)
    return jnp.mod(x * c, _m(base, x))
