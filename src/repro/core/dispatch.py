"""Backend dispatch: ONE resolver for the jnp-vs-Pallas decision.

Before this module, every call site carried its own knob — ``unroll=`` on the
comparisons, ``interpret=`` on each kernel wrapper, ``fused=`` on the codec —
and three ops re-derived "are we on TPU?" independently.  Now a single
context-managed setting governs all of them:

    with repro.core.backend("pallas"):
        a >= b                    # RnsArray ops route to the fused kernels

Governed call sites: the ``RnsArray`` methods (compare/extend/mrc/mul),
the codec encode/decode paths, and the dual-base Montgomery ops in
``core.montgomery`` (``mont_mul`` / ``ladder_step`` route to the fused
``kernels.mont_ladder`` pair the same way the codec ops route to theirs).

Settings (resolution order, DESIGN.md §11):

* ``"jnp"``    — always the pure-jnp reference implementations.
* ``"pallas"`` — always the Pallas kernels (interpret-mode off TPU, so the
  same call site runs the Mosaic kernel on TPU and the interpreter on CPU).
* ``"auto"``   — the default: Pallas on TPU, jnp elsewhere (the interpreter
  is a debugging tool, not a fast path, so CPU hosts take the jitted jnp
  route).

The setting is read at TRACE time: a jitted function captures whatever
backend was active when it was traced, exactly like the static ``fused``
flag on ``GradCodec``.  Re-trace (new jit, or different static args) to
change the route of an already-compiled function.

``interpret_default()`` is the single home of the "interpret off-TPU" rule
that ``kernels/ops.py`` wrappers consult; the per-call ``interpret=``
kwargs remain as explicit overrides for tests.
"""
from __future__ import annotations

import contextlib
import threading

import jax

__all__ = ["backend", "get_backend", "resolve_backend", "interpret_default"]

_SETTINGS = ("jnp", "pallas", "auto")

# Thread-local so trace-time reads are safe under pjit's threaded tracing.
_state = threading.local()


def get_backend() -> str:
    """The raw active setting: "jnp" | "pallas" | "auto" (default)."""
    return getattr(_state, "setting", "auto")


def resolve_backend() -> str:
    """The effective backend for the current process: "jnp" | "pallas".

    >>> from repro.core.dispatch import backend, resolve_backend
    >>> resolve_backend() in ("jnp", "pallas")   # "auto": depends on host
    True
    >>> with backend("jnp"):
    ...     resolve_backend()
    'jnp'
    """
    setting = get_backend()
    if setting != "auto":
        return setting
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def interpret_default() -> bool:
    """Pallas kernels run interpreted off-TPU (there is no Mosaic lowering
    to run); this is the ONE definition all kernel wrappers share."""
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def backend(setting: str):
    """Scoped backend override — the replacement for per-call dispatch knobs.

    >>> from repro.core.dispatch import backend, get_backend
    >>> with backend("pallas"):
    ...     get_backend()
    'pallas'
    >>> get_backend()
    'auto'
    """
    if setting not in _SETTINGS:
        raise ValueError(f"backend must be one of {_SETTINGS}, got {setting!r}")
    prev = get_backend()
    _state.setting = setting
    try:
        yield
    finally:
        _state.setting = prev
