"""``RnsArray`` — the paper's representation as a first-class JAX type.

The paper's contribution is a *representation*: residues in a base
``B = {m_1..m_n}`` plus the redundant modulus ``m_a`` that makes full-range
comparison (and hence sign, scaling, division) possible.  Historically this
repo exposed it as ~30 loose functions over three incompatible buffer
conventions — separate ``(x, xa)`` argument pairs, packed ``(..., n+1)``
tensors, and the codec's channel-major ``(n_channels, B)`` wire buffers.
``RnsArray`` lifts all three into ONE typed frontend:

* ``residues`` — the only dynamic leaf: an int tensor carrying every
  channel, either channels-LAST (``channel_axis=-1``, the algebraic
  layout) or channels-FIRST (``channel_axis=0``, the kernels' native tile
  / wire layout).  Everything else is static aux data, so instances flow
  through ``jax.jit`` / ``vmap`` / ``lax.psum`` / ``tree_map`` as ordinary
  pytrees.
* ``layout`` — how many redundant channels ride along: ``BASE`` (none),
  ``BASE_MA`` (the paper's ``m_a``), ``RRNS`` (``m_a`` + ``m_b``: the
  locate-and-correct pair of DESIGN.md §10; ``mb`` holds the second
  modulus since ``RNSBase`` only carries ``m_a``).
* ``signed`` — whether the value uses the signed embedding ``v -> v mod M``
  with ``|v| < M/2`` (DESIGN.md §4).

Every method routes through the SAME implementations the legacy functions
use — pure-jnp ``core.*`` or the Pallas kernels in ``kernels/ops.py`` —
selected once per op by the active backend (``repro.core.backend``,
see dispatch.py) instead of per-call ``interpret=``/``unroll=`` knobs.
The legacy entry points survive as thin shims that lift their arguments
into ``RnsArray`` and deconstruct the result, so both APIs are
bitwise-identical by construction (asserted in tests/test_rns_array.py).

Doctest tour::

    >>> import jax, jax.numpy as jnp
    >>> from repro.core import RnsArray, Layout, make_base
    >>> base = make_base(4, bits=8)
    >>> a = RnsArray.encode(base, jnp.asarray([1000, 77]))
    >>> b = RnsArray.encode(base, jnp.asarray([999, 78]))
    >>> a.layout, a.n_channels                  # residues + m_a channel
    (<Layout.BASE_MA: 'base_ma'>, 5)
    >>> (a >= b).tolist()                       # Algorithm 1, one MRC each
    [True, False]
    >>> (a - b).to_int().tolist()               # exact; signed result view
    [1, -1]
    >>> q, r = a.divmod(b)                      # comparison-driven division
    >>> q.to_int().tolist(), r.to_int().tolist()
    ([1, 0], [1, 77])
    >>> jax.tree_util.tree_leaves(a)[0].shape          # it's a pytree
    (2, 5)
    >>> s = RnsArray.encode_signed(base, jnp.asarray([-3, 5]))
    >>> s.is_negative().tolist()                # sign = ONE comparison
    [True, False]
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp
import numpy as np

from .base import RNSBase
from .dispatch import resolve_backend

__all__ = ["Layout", "RnsArray"]


class Layout(enum.Enum):
    """Channel inventory of an ``RnsArray`` buffer.

    BASE     — ``n`` base residue channels only (ring arithmetic, MRC).
    BASE_MA  — ``n + 1``: base + the paper's redundant ``m_a`` channel
               (enables Algorithm-1 comparison and everything built on it).
    RRNS     — ``n + 2``: base + ``m_a`` + ``m_b``, the locate-and-correct
               redundant pair of the gradient codec (DESIGN.md §10).
    """

    BASE = "base"
    BASE_MA = "base_ma"
    RRNS = "rrns"

    @property
    def n_redundant(self) -> int:
        return {Layout.BASE: 0, Layout.BASE_MA: 1, Layout.RRNS: 2}[self]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class RnsArray:
    """A batched RNS value: one residue tensor + static representation info.

    Construct via the classmethods (``encode``, ``encode_signed``,
    ``from_packed``, ``from_parts``) rather than the raw constructor —
    they compute consistent redundant channels for you.
    """

    residues: jax.Array
    base: RNSBase
    layout: Layout = Layout.BASE_MA
    signed: bool = False
    channel_axis: int = -1          # -1 = channels-last, 0 = channel-major
    mb: int | None = None           # second redundant modulus (RRNS only)

    def __post_init__(self):
        if self.channel_axis not in (0, -1):
            raise ValueError("channel_axis must be 0 or -1")
        if self.layout is Layout.RRNS and self.mb is None:
            raise ValueError("RRNS layout needs the second redundant "
                             "modulus: pass mb=")
        if self.layout is not Layout.RRNS and self.mb is not None:
            raise ValueError(f"mb is only meaningful for RRNS, not "
                             f"{self.layout}")
        shape = getattr(self.residues, "shape", None)
        if shape is not None and len(shape) > 0:
            if shape[self.channel_axis] != self.n_channels:
                raise ValueError(
                    f"residues carry {shape[self.channel_axis]} channels at "
                    f"axis {self.channel_axis}, but layout {self.layout} on "
                    f"an n={self.base.n} base needs {self.n_channels}"
                )

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        aux = (self.base, self.layout, self.signed, self.channel_axis,
               self.mb)
        return (self.residues,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        # Bypass __post_init__: transforms unflatten with tracers and
        # internal placeholder objects that have no shape to validate.
        obj = object.__new__(cls)
        for name, val in zip(
            ("base", "layout", "signed", "channel_axis", "mb"), aux
        ):
            object.__setattr__(obj, name, val)
        object.__setattr__(obj, "residues", children[0])
        return obj

    # -------------------------------------------------------- shape & views
    @property
    def n_channels(self) -> int:
        return self.base.n + self.layout.n_redundant

    @property
    def redundant_moduli(self) -> tuple[int, ...]:
        """Redundant channel moduli in channel order: (), (m_a,) or
        (m_a, m_b)."""
        return ((), (self.base.ma,), (self.base.ma, self.mb))[
            self.layout.n_redundant
        ]

    @property
    def channel_moduli(self) -> np.ndarray:
        """(n_channels,) modulus per channel, base then redundant."""
        return np.concatenate(
            [self.base.moduli_np,
             np.asarray(self.redundant_moduli, dtype=self.base.dtype)]
        ) if self.redundant_moduli else self.base.moduli_np

    @property
    def shape(self) -> tuple[int, ...]:
        """Batch shape (the channel axis removed)."""
        s = self.residues.shape
        return s[1:] if self.channel_axis == 0 else s[:-1]

    @property
    def dtype(self):
        return self.residues.dtype

    def _cl(self):
        """Residues with channels LAST regardless of storage layout."""
        if self.channel_axis == 0:
            return jnp.moveaxis(self.residues, 0, -1)
        return self.residues

    def _wrap(self, buf_cl, **overrides):
        """Rebuild an RnsArray from a channels-last buffer, preserving the
        storage layout and aux (unless overridden)."""
        aux = dict(layout=self.layout, signed=self.signed,
                   channel_axis=self.channel_axis, mb=self.mb)
        aux.update(overrides)
        if aux["channel_axis"] == 0:
            buf_cl = jnp.moveaxis(buf_cl, -1, 0)
        return RnsArray(buf_cl, self.base, **aux)

    @property
    def x(self):
        """Base residue channels, channels-last ``(..., n)``."""
        return self._cl()[..., : self.base.n]

    @property
    def xa(self):
        """The redundant ``m_a`` channel ``(...,)`` (BASE_MA/RRNS only)."""
        self._need_ma("xa")
        return self._cl()[..., self.base.n]

    def to_packed(self):
        """The legacy leaf-major buffer: ``(..., n_channels)`` channels-last
        (``(..., n+1)`` packed convention for BASE_MA)."""
        return self._cl()

    def with_channel_axis(self, axis: int) -> "RnsArray":
        """Same value, channels moved to ``axis`` (0 or -1)."""
        if axis == self.channel_axis:
            return self
        return self._wrap(self._cl(), channel_axis=axis)

    def __repr__(self):
        return (f"RnsArray(residues={self.residues!r}, n={self.base.n}, "
                f"layout={self.layout.name}, signed={self.signed}, "
                f"channel_axis={self.channel_axis})")

    def _need_ma(self, what: str):
        if self.layout is Layout.BASE:
            raise ValueError(
                f"{what} needs the redundant m_a channel: this RnsArray has "
                f"layout BASE — use .normalize(Layout.BASE_MA) to extend"
            )

    def _m_like(self, ref):
        return jnp.asarray(self.channel_moduli, dtype=ref.dtype)

    # --------------------------------------------------- ring arithmetic
    def _lift(self, other) -> "RnsArray":
        if isinstance(other, RnsArray):
            if other.base is not self.base and other.base != self.base:
                raise ValueError("RnsArray ops need matching bases")
            if other.layout is not self.layout or other.mb != self.mb:
                raise ValueError(
                    f"RnsArray ops need matching layouts: "
                    f"{self.layout} vs {other.layout}"
                )
            return other.with_channel_axis(self.channel_axis)
        if isinstance(other, (int, np.integer)):
            # channel-wise residues of the constant, broadcast over batch
            v = int(other) % self.base.M
            res = [v % int(m) for m in self.channel_moduli]
            return RnsArray(
                jnp.broadcast_to(
                    jnp.asarray(res, dtype=self.dtype),
                    (*self.shape, self.n_channels),
                ),
                self.base, layout=self.layout, signed=self.signed,
                channel_axis=-1, mb=self.mb,
            ).with_channel_axis(self.channel_axis)
        return NotImplemented

    def __add__(self, other) -> "RnsArray":
        other = self._lift(other)
        if other is NotImplemented:
            return NotImplemented
        a, b = self._cl(), other._cl()
        m = self._m_like(a)
        s = a + b
        out = jnp.where(s >= m, s - m, s)   # both reduced => s in [0, 2m)
        return self._wrap(out, signed=self.signed or other.signed)

    def __sub__(self, other) -> "RnsArray":
        other = self._lift(other)
        if other is NotImplemented:
            return NotImplemented
        a, b = self._cl(), other._cl()
        m = self._m_like(a)
        d = a - b
        out = jnp.where(d < 0, d + m, d)
        return self._wrap(out, signed=True)

    def __neg__(self) -> "RnsArray":
        a = self._cl()
        m = self._m_like(a)
        return self._wrap(jnp.where(a == 0, a, m - a), signed=True)

    def __mul__(self, other) -> "RnsArray":
        other = self._lift(other)
        if other is NotImplemented:
            return NotImplemented
        if resolve_backend() == "pallas" and self.base.bits <= 15:
            from repro.kernels.ops import modmul_op

            return modmul_op(self, other)
        a, b = self._cl(), other._cl()
        out = jnp.mod(a * b, self._m_like(a))
        return self._wrap(out, signed=self.signed or other.signed)

    __radd__ = __add__
    __rmul__ = __mul__

    def __rsub__(self, other):
        lifted = self._lift(other)
        if lifted is NotImplemented:
            return NotImplemented
        return lifted - self

    # NOTE on redundant channels under arithmetic: each channel computes in
    # its OWN modulus, so after the base value wraps mod M the carried
    # m_a/m_b channels track the UN-wrapped integer — the exact discipline
    # division.py and the gradient codec rely on.  Re-anchor with
    # ``normalize()`` before Algorithm-1 queries if wraps may have occurred
    # (GradCodec.verify_packed exploits the discrepancy to detect faults).

    # ------------------------------------------------------- comparisons
    def compare_ge(self, other, *, unroll: bool = False):
        """Algorithm 1 / Theorem 1: elementwise ``self >= other`` over the
        full range [0, M).  One MRC + one Alg.-3 dot; routed to the fused
        Pallas kernel under the ``pallas`` backend."""
        self._need_ma("compare_ge")
        other = self._lift(other)
        if other is NotImplemented:
            raise TypeError("compare_ge needs an RnsArray (or int) operand")
        if resolve_backend() == "pallas" and self.base.bits <= 15:
            from repro.kernels.ops import compare_op

            return compare_op(self, other)
        from .compare import _compare_ge_impl

        return _compare_ge_impl(
            self.base, self.x, self.xa, other.x, other.xa, unroll=unroll
        )

    def __ge__(self, other):
        lifted = self._lift(other)
        if lifted is NotImplemented:
            return NotImplemented
        return self.compare_ge(lifted)

    def __le__(self, other):
        other = self._lift(other)
        if other is NotImplemented:
            return NotImplemented
        return other.compare_ge(self)

    def __gt__(self, other):
        le = self.__le__(other)
        return NotImplemented if le is NotImplemented else ~le

    def __lt__(self, other):
        ge = self.__ge__(other)
        return NotImplemented if ge is NotImplemented else ~ge

    def is_negative(self):
        """Sign of a signed-embedded value: ONE Alg.-1 comparison against
        ceil(M/2) (DESIGN.md §4)."""
        self._need_ma("is_negative")
        if not self.signed:
            raise ValueError("is_negative needs signed=True (the unsigned "
                             "range [0, M) has no sign)")
        from .signed import _is_negative_impl

        return _is_negative_impl(self.base, self._alg1_packed())

    def abs_ge(self, thr: int):
        """|value| >= thr for signed embeddings: two Alg.-1 comparisons."""
        self._need_ma("abs_ge")
        if not self.signed:
            raise ValueError("abs_ge needs signed=True")
        from .signed import _abs_ge_impl

        return _abs_ge_impl(self.base, self._alg1_packed(), int(thr))

    def _alg1_packed(self):
        """The (..., n+1) channels-last slice Algorithm-1 consumers eat —
        base residues + m_a (the RRNS m_b channel is correction metadata
        and plays no part in comparisons)."""
        return self._cl()[..., : self.base.n + 1]

    # ------------------------------------------------------- conversions
    def to_mrs(self):
        """Mixed-radix digits ``(..., n)`` (Alg. 2; kernel under pallas)."""
        if resolve_backend() == "pallas" and self.base.bits <= 15:
            from repro.kernels.ops import mrc_op

            return mrc_op(self)
        from .mrc import mrc

        return mrc(self.base, self.x)

    def to_int(self):
        """Exact int64 values (requires M < 2**62; signed-aware).

        >>> import jax.numpy as jnp
        >>> from repro.core import RnsArray, make_base
        >>> base = make_base(3, bits=15)
        >>> v = jnp.asarray([123456789, -42])
        >>> RnsArray.encode_signed(base, v).to_int().tolist()
        [123456789, -42]
        """
        from .convert import rns_to_tensor

        v = rns_to_tensor(self.base, self.x)
        if self.signed:
            half = (self.base.M + 1) // 2
            v = jnp.where(v >= half, v - self.base.M, v)
        return v

    def extend(self, targets: tuple[int, ...]):
        """Exact MRC base extension: residues of the value mod each target
        modulus, shape ``(..., T)`` (kernel MRC under pallas)."""
        targets = tuple(int(t) for t in targets)
        if resolve_backend() == "pallas" and self.base.bits <= 15:
            from .convert import mrs_dot_mod

            return mrs_dot_mod(self.base, self.to_mrs(), targets)
        from .extend import _extend_mrc_impl

        return _extend_mrc_impl(self.base, self.x, targets)

    def normalize(self, layout: Layout | None = None, *,
                  mb: int | None = None) -> "RnsArray":
        """Recompute the redundant channels from the base residues (one MRC
        + one Alg.-3 dot per channel).  Re-anchors m_a/m_b after ring wraps;
        also converts BETWEEN layouts (pass ``layout=``, and ``mb=`` when
        lifting to RRNS)."""
        layout = self.layout if layout is None else layout
        if layout is Layout.RRNS:
            mb = self.mb if mb is None else mb
            if mb is None:
                raise ValueError("normalize to RRNS needs mb=")
        else:
            mb = None
        reds = ((), (self.base.ma,), (self.base.ma, mb))[layout.n_redundant]
        x = self.x
        if not reds:
            return self._wrap(x, layout=layout, mb=None)
        from .convert import mrs_dot_mod

        xr = mrs_dot_mod(self.base, self.to_mrs(), reds)
        return self._wrap(
            jnp.concatenate([x, xr.astype(x.dtype)], axis=-1),
            layout=layout, mb=mb,
        )

    # ------------------------------------------------- scaling & division
    def halve(self) -> "RnsArray":
        """Exact floor(X/2) (paper's scaling primitive): parity via the
        mixed-radix digit sum, then multiply by 2^{-1} per channel.
        Unsigned only: floor-halving the embedding X = v mod M is NOT
        floor(v/2) for negative v."""
        if self.signed:
            raise ValueError("halve/scale_pow2 are defined on unsigned "
                             "ranges; strip signs first")
        from .division import _halve_impl

        return self._wrap(
            _halve_impl(self.base, self._cl(), self.redundant_moduli)
        )

    def scale_pow2(self, k: int) -> "RnsArray":
        """Exact floor(X / 2^k): k chained halvings."""
        out = self
        for _ in range(int(k)):
            out = out.halve()
        return out

    def divmod(self, other) -> tuple["RnsArray", "RnsArray"]:
        """(Q, R) with X = Q·D + R, 0 <= R < D, entirely in RNS — restoring
        division where every magnitude decision is one Algorithm-1
        comparison (2·nbits+1 of them).  Unsigned operands only."""
        self._need_ma("divmod")
        other = self._lift(other)
        if other is NotImplemented:
            raise TypeError("divmod needs an RnsArray (or int) divisor")
        if self.signed or other.signed:
            raise ValueError("divmod is defined on unsigned ranges; "
                             "strip signs first")
        from .division import _divmod_impl

        q, r = _divmod_impl(
            self.base, self._alg1_packed(), other._alg1_packed()
        )
        if self.layout is Layout.RRNS:
            # quotient/remainder carry fresh m_a channels; rebuild m_b
            lift = lambda p: RnsArray(
                p, self.base, layout=Layout.BASE_MA,
            ).normalize(Layout.RRNS, mb=self.mb).with_channel_axis(
                self.channel_axis
            )
        else:
            lift = lambda p: self._wrap(p)
        return lift(q), lift(r)

    # ------------------------------------------------------- constructors
    @classmethod
    def encode(cls, base: RNSBase, values, *,
               layout: Layout = Layout.BASE_MA,
               mb: int | None = None,
               channel_axis: int = -1) -> "RnsArray":
        """Unsigned integer tensor (values in [0, M), int64-ranged) ->
        residues + consistent redundant channels.

        >>> import jax.numpy as jnp
        >>> from repro.core import RnsArray, make_base, rns_to_int
        >>> base = make_base(4, bits=8)
        >>> a = RnsArray.encode(base, jnp.asarray([1234]))
        >>> int(a.xa[0]) == 1234 % base.ma
        True
        """
        from .convert import tensor_to_rns

        values = jnp.asarray(values)
        res = tensor_to_rns(base, values)
        reds = ((), (base.ma,), (base.ma, mb))[layout.n_redundant]
        if layout is Layout.RRNS and mb is None:
            raise ValueError("encode to RRNS needs mb=")
        cols = [res]
        for mr in reds:
            cols.append(
                jnp.mod(values.astype(jnp.int64), mr)[..., None]
                .astype(res.dtype)
            )
        return cls(
            jnp.concatenate(cols, axis=-1) if reds else res,
            base, layout=layout, signed=False, channel_axis=-1,
            mb=mb if layout is Layout.RRNS else None,
        ).with_channel_axis(channel_axis)

    @classmethod
    def encode_signed(cls, base: RNSBase, values, *,
                      channel_axis: int = -1) -> "RnsArray":
        """Signed integer tensor (|v| < M/2) -> signed embedding with a
        consistent m_a channel (DESIGN.md §4)."""
        from .signed import _encode_signed_impl

        packed = _encode_signed_impl(base, jnp.asarray(values))
        return cls(
            packed, base, layout=Layout.BASE_MA, signed=True,
            channel_axis=-1,
        ).with_channel_axis(channel_axis)

    @classmethod
    def from_packed(cls, base: RNSBase, packed, *, signed: bool = False,
                    mb: int | None = None,
                    channel_axis: int = -1) -> "RnsArray":
        """Lift a legacy buffer: ``(..., n)`` (BASE), ``(..., n+1)``
        (BASE_MA) or ``(..., n+2)`` (RRNS, needs ``mb=``) at
        ``channel_axis``.  The redundant channels are taken AS IS —
        no consistency check (that is ``GradCodec.verify_packed``'s job)."""
        packed = jnp.asarray(packed)
        extra = packed.shape[channel_axis] - base.n
        if not 0 <= extra <= 2:
            raise ValueError(
                f"buffer carries {packed.shape[channel_axis]} channels; an "
                f"n={base.n} base expects n, n+1 or n+2"
            )
        layout = (Layout.BASE, Layout.BASE_MA, Layout.RRNS)[extra]
        return cls(packed, base, layout=layout, signed=signed,
                   channel_axis=channel_axis,
                   mb=mb if layout is Layout.RRNS else None)

    @classmethod
    def from_parts(cls, base: RNSBase, x, xa=None) -> "RnsArray":
        """Lift the oldest convention: separate base residues ``x: (..., n)``
        and (optionally) redundant residue ``xa: (...,)``."""
        x = jnp.asarray(x)
        if xa is None:
            return cls(x, base, layout=Layout.BASE)
        xa = jnp.asarray(xa)
        return cls(
            jnp.concatenate([x, xa[..., None].astype(x.dtype)], axis=-1),
            base, layout=Layout.BASE_MA,
        )
