"""Divide-and-conquer Mixed-Radix Conversion — the paper's parallel claim.

The paper (§2.1.1, §3.3) notes MRC admits O(log n)-time parallel forms
(Huang 1983).  Huang's network needs O(n²) processors with cross-channel
lookup traffic that maps poorly to TPU lanes (DESIGN.md §3); this module
implements the closest TPU-idiomatic equivalent: a recursive split

    X = A + M1 · B,   A = X mod M1 (MRS digits on B1, recursively),
                      B = floor(X / M1) with residues on B2:
                          b_j = (x_j − A mod m_j) · M1^{-1} mod m_j,

where ``A mod m_j`` is a base extension of A's digits into B2 — a dot
product against precomputed partial products (Alg. 3 generalized), i.e.
log-depth.  Total: O(log² n) depth, O(n²) work — same work as Alg. 2 with
near-log depth, entirely out of einsums (MXU-friendly).

The recursion is built at TRACE time (static tree over the base split), so
the lowered HLO is a log²-depth DAG of dots — no sequential scan.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .base import RNSBase

__all__ = ["mrc_tree"]


@functools.lru_cache(maxsize=None)
def _tree_tables(moduli: tuple, bits: int):
    """Precompute, per tree node: betas of B1 into B2 and M1^{-1} mod B2."""
    if len(moduli) == 1:
        return None
    half = len(moduli) // 2
    b1, b2 = moduli[:half], moduli[half:]
    M1 = 1
    for m in b1:
        M1 *= m
    betas = np.zeros((len(b2), len(b1)), dtype=np.int64)
    for t, mt in enumerate(b2):
        acc = 1
        for i, mi in enumerate(b1):
            betas[t, i] = acc % mt
            acc = (acc * mi) % mt
    m1_inv = np.asarray([pow(M1 % mt, -1, mt) for mt in b2], dtype=np.int64)
    # NOTE: cache numpy only — caching jnp arrays would leak tracers across
    # jit traces via the lru_cache.
    return half, betas, m1_inv, np.asarray(b2, dtype=np.int64)


def _mrc_rec(moduli: tuple, bits: int, x):
    """x: (..., n) int64 residues on `moduli` -> (..., n) MRS digits."""
    n = len(moduli)
    if n == 1:
        return x
    half, betas_np, m1_inv_np, m2_np = _tree_tables(moduli, bits)
    betas = jnp.asarray(betas_np)
    m1_inv = jnp.asarray(m1_inv_np)
    m2 = jnp.asarray(m2_np)
    a_digits = _mrc_rec(moduli[:half], bits, x[..., :half])
    # extend A into B2: A mod m_t = sum_i a_i * beta[t, i]  (log-depth dot)
    terms = jnp.mod(a_digits[..., None, :] * betas, m2[:, None])
    a_mod = jnp.mod(jnp.sum(terms, axis=-1), m2)  # (..., n-half)
    b_res = jnp.mod((x[..., half:] - a_mod) * m1_inv, m2)
    b_digits = _mrc_rec(moduli[half:], bits, b_res)
    return jnp.concatenate([a_digits, b_digits], axis=-1)


def mrc_tree(base: RNSBase, x):
    """Log²-depth MRC; digits identical to repro.core.mrc (tests assert)."""
    digits = _mrc_rec(tuple(int(m) for m in base.moduli), base.bits,
                      x.astype(jnp.int64))
    return digits.astype(x.dtype)
