"""Conversions: Python ints <-> RNS, MRS -> residue mod m_a (Alg. 3),
and fixed-width integer tensors <-> RNS residue tensors.

``to_ma`` is Algorithm 3 of the paper: given the mixed-radix digits of X,
compute X mod m_a as a dot product against the precomputed partial products
``beta_i = prod_{j<i} m_j mod m_a``.  Cost: n modular mults + (n-1) adds —
the paper's count — and the reduction tree is O(log n) depth in parallel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .base import RNSBase

__all__ = [
    "to_ma",
    "mrs_dot_mod",
    "int_to_rns",
    "rns_to_int",
    "tensor_to_rns",
    "rns_to_tensor",
]


def to_ma(base: RNSBase, digits):
    """Alg. 3: X mod m_a from mixed-radix digits ``(..., n)`` -> ``(...,)``.

    Per-term reduction keeps the accumulator small: each term < m_a <= 2**15,
    so the sum over n <= 2**16 channels stays < 2**31 (int32-safe).
    """
    betas = jnp.asarray(base.betas_ma_np, dtype=digits.dtype)
    terms = jnp.mod(digits * betas, jnp.asarray(base.ma, dtype=digits.dtype))
    return jnp.mod(jnp.sum(terms, axis=-1), base.ma)


def mrs_dot_mod(base: RNSBase, digits, targets: tuple[int, ...]):
    """Multi-target Alg. 3: X mod m_t for each target, shape (..., T).

    This is the exact MRC-based base extension's backward half — a dot
    product per target modulus, log-depth in parallel.
    """
    betas = jnp.asarray(base.betas_for(targets), dtype=digits.dtype)  # (T, n)
    mt = jnp.asarray(np.asarray(targets), dtype=digits.dtype)  # (T,)
    terms = jnp.mod(digits[..., None, :] * betas, mt[:, None])
    return jnp.mod(jnp.sum(terms, axis=-1), mt)


# --------------------------------------------------------------------------
# Exact host-side conversions (tests, checkpoint fingerprints, crypto I/O)
# --------------------------------------------------------------------------


def int_to_rns(base: RNSBase, x: int) -> np.ndarray:
    """Residues of a Python int (negative x embeds as x mod M)."""
    return base.residues_of(x)


def rns_to_int(base: RNSBase, residues) -> int:
    """Exact value in [0, M) via CRT on Python ints (host-side oracle)."""
    x = 0
    for r, m in zip(np.asarray(residues).tolist(), base.moduli):
        Mi = base.M // m
        x = (x + (int(r) * pow(Mi, -1, m) % m) * Mi) % base.M
    return x


# --------------------------------------------------------------------------
# Tensor codecs (gradient aggregation path)
# --------------------------------------------------------------------------


def tensor_to_rns(base: RNSBase, x):
    """Integer tensor -> residue tensor ``(..., n)``.

    Works for signed x: since m_i | M, (x mod m_i) == ((x mod M) mod m_i) and
    ``jnp.mod`` already returns non-negative remainders.  |x| must be < M/2
    for the signed embedding to round-trip.
    """
    m = jnp.asarray(base.moduli_np)
    return jnp.mod(x[..., None].astype(jnp.int64), m.astype(jnp.int64)).astype(
        base.dtype
    )


def rns_to_tensor(base: RNSBase, digits_or_residues, *, from_digits=False):
    """Residue tensor -> int64 values in [0, M) via MRC + Horner.

    Requires M < 2**63 (true for the codec bases: n<=4, 15-bit moduli).
    Pass mixed-radix digits with ``from_digits=True`` to skip the MRC.
    """
    from .mrc import mrc_unrolled

    if base.M >= 1 << 62:
        raise ValueError("rns_to_tensor requires M < 2**62; use rns_to_int")
    d = digits_or_residues if from_digits else mrc_unrolled(base, digits_or_residues)
    d = d.astype(jnp.int64)
    acc = d[..., base.n - 1]
    for i in range(base.n - 2, -1, -1):
        acc = acc * int(base.moduli[i]) + d[..., i]
    return acc
