"""Base extension: convert residues in base B to residues in a target base.

Three methods, mirroring the paper's §2.1 taxonomy:

* ``extend_mrc``      — exact, via MRC + multi-target Alg. 3 dot.  The method
  the paper builds on (no bounds, no special moduli).
* ``extend_shenoy``   — exact CRT-form extension using a redundant residue
  (Shenoy–Kumaresan).  Requires x_r == X mod m_r to be TRUE — the paper's §2
  explains how that premise breaks for channel-wise differences, which is
  precisely why the comparison algorithm exists.
* ``extend_kawamura`` — approximate CRT (Cox–Rower).  k can be off by one
  near the top of the range; exposed so benchmarks can chart the error band.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .base import RNSBase
from .convert import mrs_dot_mod
from .mrc import mrc

__all__ = ["extend_mrc", "extend_shenoy", "extend_kawamura"]


def _extend_mrc_impl(base: RNSBase, x, targets: tuple[int, ...]):
    """MRC + multi-target Alg.-3 dot — the jnp route of
    ``RnsArray.extend`` (the pallas backend swaps in the kernel MRC)."""
    return mrs_dot_mod(base, mrc(base, x), targets)


def extend_mrc(base: RNSBase, x, targets: tuple[int, ...]):
    """Exact extension of ``x: (..., n)`` to residues mod each target, (..., T).

    This is also the reconstruction step of the RRNS single-fault repair
    (DESIGN.md §10): the corrected residue of a located channel is the
    surviving channels' value extended back to that channel's modulus.

    Legacy shim over ``RnsArray.extend``.

    >>> import jax.numpy as jnp
    >>> from repro.core.base import RNSBase
    >>> from repro.core.extend import extend_mrc
    >>> base = RNSBase(moduli=(3, 5, 7), ma=11, bits=15)
    >>> x = jnp.asarray([[52 % 3, 52 % 5, 52 % 7]])
    >>> extend_mrc(base, x, (11, 13)).tolist()       # 52 mod 11, 52 mod 13
    [[8, 0]]
    """
    from .array import RnsArray

    return RnsArray.from_parts(base, x).extend(tuple(targets))


def _xi(base: RNSBase, x):
    """CRT coefficients xi_i = |x_i * Mi^{-1}|_{m_i}."""
    mi_inv = jnp.asarray(base.Mi_inv_np, dtype=x.dtype)
    m = jnp.asarray(base.moduli_np, dtype=x.dtype)
    return jnp.mod(x * mi_inv, m)


def extend_shenoy(base: RNSBase, x, xr, mr: int, targets: tuple[int, ...]):
    """Shenoy–Kumaresan: exact, given the redundant residue xr = X mod m_r.

    Y = sum xi_i M_i = X + k M with 0 <= k < n, so k is recovered mod m_r
    (requires m_r > n) and subtracted off in each target channel.

    >>> import jax.numpy as jnp
    >>> from repro.core.base import RNSBase
    >>> from repro.core.extend import extend_shenoy
    >>> base = RNSBase(moduli=(3, 5, 7), ma=11, bits=15)
    >>> x = jnp.asarray([[52 % 3, 52 % 5, 52 % 7]])
    >>> xr = jnp.asarray([52 % 11])                  # TRUE redundant residue
    >>> extend_shenoy(base, x, xr, 11, (13,)).tolist()
    [[0]]
    """
    if mr <= base.n:
        raise ValueError("Shenoy extension needs m_r > n")
    xi = _xi(base, x)  # (..., n)
    dt = jnp.int64

    mi_mod_r = jnp.asarray(base.Mi_mod((mr,))[0], dtype=dt)  # (n,)
    y_mod_r = jnp.mod(jnp.sum(jnp.mod(xi.astype(dt) * mi_mod_r, mr), axis=-1), mr)
    m_inv_r = pow(base.M % mr, -1, mr)
    k = jnp.mod((y_mod_r - xr.astype(dt)) * m_inv_r, mr)  # exact k, < n

    mi_mod_t = jnp.asarray(base.Mi_mod(targets), dtype=dt)  # (T, n)
    m_mod_t = jnp.asarray(base.M_mod(targets), dtype=dt)  # (T,)
    mt = jnp.asarray(np.asarray(targets), dtype=dt)
    s = jnp.sum(jnp.mod(xi.astype(dt)[..., None, :] * mi_mod_t, mt[:, None]), axis=-1)
    out = jnp.mod(s - k[..., None] * m_mod_t, mt)
    return out.astype(x.dtype)


def extend_kawamura(
    base: RNSBase, x, targets: tuple[int, ...], *, alpha: float = 0.5, q: int = 8
):
    """Kawamura et al. (Cox–Rower) approximate extension.

    k ~= floor(sum_i xi_i / m_i + alpha) approximated with the top q bits of
    xi_i (moduli are ~2^bits so xi/m ~ xi >> (bits - q)).  Exact except when
    X falls within ~(1-alpha)·M of the range top (or alpha·M of 0 for the
    down-rounding direction) — the bound the paper cites as disqualifying
    for full-range comparison.
    """
    xi = _xi(base, x)
    dt = jnp.int64
    trunc = (xi.astype(dt) >> (base.bits - q)).astype(dt)
    k = (jnp.sum(trunc, axis=-1) + int(alpha * (1 << q))) >> q  # (...,)

    mi_mod_t = jnp.asarray(base.Mi_mod(targets), dtype=dt)
    m_mod_t = jnp.asarray(base.M_mod(targets), dtype=dt)
    mt = jnp.asarray(np.asarray(targets), dtype=dt)
    s = jnp.sum(jnp.mod(xi.astype(dt)[..., None, :] * mi_mod_t, mt[:, None]), axis=-1)
    out = jnp.mod(s - k[..., None] * m_mod_t, mt)
    return out.astype(x.dtype)
