"""RNS division and scaling built on the paper's comparison.

The paper's conclusion names division/scaling as the operations its
comparison unlocks.  We implement classical restoring division in pure RNS:
every magnitude decision is one Algorithm-1 comparison, and the only extra
machinery is doubling (add) and exact halving (parity via mixed-radix digit
sum — all moduli odd ⇒ beta_i ≡ 1 mod 2 ⇒ X mod 2 = sum a_i mod 2).

Operands travel as *packed* tensors (..., n+1) — base residues plus the
redundant m_a channel — so comparisons never need a fresh conversion.

Wrap discipline: doubling D inside the ring wraps mod M once D·2^j >= M.
A wrapped rung of the ladder would compare arbitrarily, so the up-phase
detects wraps with the comparison itself (2d >= d fails iff wrap: the
wrapped value 2d−M is < d < M) and the down-phase masks those rungs out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import arith
from .base import RNSBase
from .compare import compare_packed_ge
from .mrc import mrc

__all__ = ["pack", "unpack", "divmod_rns", "halve", "scale_pow2", "parity"]


def pack(base: RNSBase, x, xa):
    return jnp.concatenate([x, xa[..., None].astype(x.dtype)], axis=-1)


def unpack(packed):
    return packed[..., :-1], packed[..., -1]


def padd(base, p, q):
    x = arith.add(base, p[..., :-1], q[..., :-1])
    xa = jnp.mod(p[..., -1] + q[..., -1], base.ma)
    return pack(base, x, xa)


def psub(base, p, q):
    x = arith.sub(base, p[..., :-1], q[..., :-1])
    xa = jnp.mod(p[..., -1] - q[..., -1], base.ma)
    return pack(base, x, xa)


def parity(base: RNSBase, x):
    """X mod 2 from base residues (all moduli odd)."""
    return jnp.mod(jnp.sum(mrc(base, x), axis=-1), 2)


def halve(base: RNSBase, packed):
    """Exact floor(X/2): subtract the parity bit, multiply by 2^{-1}."""
    x, xa = unpack(packed)
    p = parity(base, x).astype(x.dtype)
    x = arith.sub(base, x, jnp.broadcast_to(p[..., None], x.shape))
    xa = jnp.mod(xa - p, base.ma)
    x = arith.mul_const(base, x, base.inv2_np)
    xa = jnp.mod(xa * base.inv2_ma, base.ma)
    return pack(base, x, xa)


def scale_pow2(base: RNSBase, packed, k: int):
    """floor(X / 2^k) — the paper's 'scaling' application, k exact halvings."""
    for _ in range(k):
        packed = halve(base, packed)
    return packed


def divmod_rns(base: RNSBase, xp, dp, *, iters: int | None = None):
    """(Q, R) with X = Q*D + R, 0 <= R < D, entirely in RNS.

    Restoring division.  Up-phase builds the ladder d·2^j (j = 0..nbits) with
    per-rung wrap flags; down-phase walks j = nbits..0, subtracting where the
    Algorithm-1 comparison allows, accumulating Q by Horner (Q = 2Q + bit_j).
    Total comparisons: 2·nbits+1, each one MRC.

    Inputs/outputs are packed (..., n+1).  D must be nonzero.
    """
    nbits = iters if iters is not None else base.M.bit_length()

    def up(carry, _):
        d, valid = carry
        d2 = padd(base, d, d)
        # 2d >= d holds iff no wrap (wrapped value is 2d - M < d).
        valid2 = valid & compare_packed_ge(base, d2, d)
        return (d2, valid2), (d2, valid2)

    valid0 = jnp.ones(xp.shape[:-1], dtype=bool)
    (_, _), (ladder, valids) = jax.lax.scan(up, (dp, valid0), None, length=nbits)
    # Prepend rung j=0 (d itself, always valid).
    ladder = jnp.concatenate([dp[None], ladder], axis=0)  # (nbits+1, ..., n+1)
    valids = jnp.concatenate([valid0[None], valids], axis=0)

    zero = jnp.zeros_like(xp)

    def down(carry, rung):
        q, r = carry
        d_j, valid_j = rung
        bit = compare_packed_ge(base, r, d_j) & valid_j
        bitx = bit[..., None]
        r = jnp.where(bitx, psub(base, r, d_j), r)
        # Q = 2Q + bit  (Horner over the quotient bits, in RNS).
        q2 = padd(base, q, q)
        q2p1 = padd(base, q2, _one_like(base, q))
        q = jnp.where(bitx, q2p1, q2)
        return (q, r), None

    (q, r), _ = jax.lax.scan(
        down, (zero, xp), (ladder[::-1], valids[::-1])
    )
    return q, r


def _one_like(base: RNSBase, packed):
    return jnp.ones_like(packed)  # residues of 1 are all 1 (moduli > 1)
