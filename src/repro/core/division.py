"""RNS division and scaling built on the paper's comparison.

The paper's conclusion names division/scaling as the operations its
comparison unlocks.  We implement classical restoring division in pure RNS:
every magnitude decision is one Algorithm-1 comparison, and the only extra
machinery is doubling (add) and exact halving (parity via mixed-radix digit
sum — all moduli odd ⇒ beta_i ≡ 1 mod 2 ⇒ X mod 2 = sum a_i mod 2).

Operands travel as *packed* tensors (..., n+1) — base residues plus the
redundant m_a channel — so comparisons never need a fresh conversion.
The typed frontend is ``RnsArray.divmod`` / ``.halve`` / ``.scale_pow2``
(core/array.py); the public functions here are legacy shims over it.

Wrap discipline: doubling D inside the ring wraps mod M once D·2^j >= M.
A wrapped rung of the ladder would compare arbitrarily, so the up-phase
detects wraps with the comparison itself (2d >= d fails iff wrap: the
wrapped value 2d−M is < d < M) and the down-phase masks those rungs out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import arith
from .base import RNSBase
from .compare import _compare_ge_impl
from .mrc import mrc

__all__ = ["pack", "unpack", "divmod_rns", "halve", "scale_pow2", "parity"]


def pack(base: RNSBase, x, xa):
    return jnp.concatenate([x, xa[..., None].astype(x.dtype)], axis=-1)


def unpack(packed):
    return packed[..., :-1], packed[..., -1]


def padd(base, p, q):
    x = arith.add(base, p[..., :-1], q[..., :-1])
    xa = jnp.mod(p[..., -1] + q[..., -1], base.ma)
    return pack(base, x, xa)


def psub(base, p, q):
    x = arith.sub(base, p[..., :-1], q[..., :-1])
    xa = jnp.mod(p[..., -1] - q[..., -1], base.ma)
    return pack(base, x, xa)


def _packed_ge(base, p, q):
    return _compare_ge_impl(
        base, p[..., :-1], p[..., -1], q[..., :-1], q[..., -1], unroll=True
    )


def parity(base: RNSBase, x):
    """X mod 2 from base residues (all moduli odd)."""
    return jnp.mod(jnp.sum(mrc(base, x), axis=-1), 2)


def _halve_impl(base: RNSBase, buf, red_moduli: tuple[int, ...]):
    """Exact floor(X/2) over a channels-last buffer ``(..., n + k)`` whose
    trailing k channels carry the ``red_moduli`` redundant residues
    (k = 0, 1 or 2): subtract the parity bit, multiply by 2^{-1} — per
    channel, each in its own modulus."""
    n = base.n
    x, extra = buf[..., :n], buf[..., n:]
    p = parity(base, x).astype(buf.dtype)
    x = arith.sub(base, x, jnp.broadcast_to(p[..., None], x.shape))
    x = arith.mul_const(base, x, base.inv2_np)
    cols = [x]
    for i, mr in enumerate(red_moduli):
        xr = jnp.mod(extra[..., i] - p, mr)
        cols.append(jnp.mod(xr * pow(2, -1, mr), mr)[..., None]
                    .astype(buf.dtype))
    return jnp.concatenate(cols, axis=-1) if red_moduli else x


def halve(base: RNSBase, packed):
    """Exact floor(X/2) on a packed (..., n+1) tensor.  Legacy shim over
    ``RnsArray.halve``."""
    from .array import RnsArray

    return RnsArray.from_packed(base, packed).halve().to_packed()


def scale_pow2(base: RNSBase, packed, k: int):
    """floor(X / 2^k) — the paper's 'scaling' application, k exact halvings.
    Legacy shim over ``RnsArray.scale_pow2``."""
    from .array import RnsArray

    return RnsArray.from_packed(base, packed).scale_pow2(k).to_packed()


def _divmod_impl(base: RNSBase, xp, dp, *, iters: int | None = None):
    """(Q, R) with X = Q*D + R, 0 <= R < D, entirely in RNS.

    Restoring division.  Up-phase builds the ladder d·2^j (j = 0..nbits) with
    per-rung wrap flags; down-phase walks j = nbits..0, subtracting where the
    Algorithm-1 comparison allows, accumulating Q by Horner (Q = 2Q + bit_j).
    Total comparisons: 2·nbits+1, each one MRC.

    Inputs/outputs are packed (..., n+1).  D must be nonzero.
    """
    nbits = iters if iters is not None else base.M.bit_length()

    def up(carry, _):
        d, valid = carry
        d2 = padd(base, d, d)
        # 2d >= d holds iff no wrap (wrapped value is 2d - M < d).
        valid2 = valid & _packed_ge(base, d2, d)
        return (d2, valid2), (d2, valid2)

    valid0 = jnp.ones(xp.shape[:-1], dtype=bool)
    (_, _), (ladder, valids) = jax.lax.scan(up, (dp, valid0), None, length=nbits)
    # Prepend rung j=0 (d itself, always valid).
    ladder = jnp.concatenate([dp[None], ladder], axis=0)  # (nbits+1, ..., n+1)
    valids = jnp.concatenate([valid0[None], valids], axis=0)

    zero = jnp.zeros_like(xp)

    def down(carry, rung):
        q, r = carry
        d_j, valid_j = rung
        bit = _packed_ge(base, r, d_j) & valid_j
        bitx = bit[..., None]
        r = jnp.where(bitx, psub(base, r, d_j), r)
        # Q = 2Q + bit  (Horner over the quotient bits, in RNS).
        q2 = padd(base, q, q)
        q2p1 = padd(base, q2, _one_like(base, q))
        q = jnp.where(bitx, q2p1, q2)
        return (q, r), None

    (q, r), _ = jax.lax.scan(
        down, (zero, xp), (ladder[::-1], valids[::-1])
    )
    return q, r


def divmod_rns(base: RNSBase, xp, dp, *, iters: int | None = None):
    """(Q, R) on packed (..., n+1) operands.  Legacy shim over
    ``RnsArray.divmod`` (which adds layout checks and the typed result)."""
    from .array import RnsArray

    if iters is not None:  # expert knob not exposed on the typed API
        return _divmod_impl(base, xp, dp, iters=iters)
    q, r = RnsArray.from_packed(base, xp).divmod(
        RnsArray.from_packed(base, dp)
    )
    return q.to_packed(), r.to_packed()


def _one_like(base: RNSBase, packed):
    return jnp.ones_like(packed)  # residues of 1 are all 1 (moduli > 1)
