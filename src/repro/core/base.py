"""RNS base definition and precomputed tables.

The paper (Didier, Glandus, El Mrabet, Robert — "RNS Comparison revisited, a
software perspective") assumes a base ``B = {m_1..m_n}`` of pairwise-coprime
moduli plus one *redundant* modulus ``m_a`` coprime to all of them.  This
module generates such bases deterministically and precomputes every constant
table the algorithms need:

* ``inv_tri[j, i] = m_j^{-1} mod m_i`` (j < i)       — Alg. 2 (MRC)
* ``betas_ma[i]   = prod_{j<i} m_j mod m_a``         — Alg. 3 (to_ma)
* ``Mi_inv[i]     = (M/m_i)^{-1} mod m_i``           — CRT-based extensions
* Shenoy–Kumaresan and Kawamura constants            — baseline extensions

TPU adaptation (see DESIGN.md §3): the default is 15-bit prime moduli stored
in int32 lanes, so every product of two residues stays below 2**30 and no
64-bit multiply is ever required.  31-bit moduli with int64 lanes are
available for CPU-hosted crypto contexts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

__all__ = ["RNSBase", "gen_coprime_moduli", "is_prime", "make_base"]


# --------------------------------------------------------------------------
# Prime / moduli generation (host-side, exact Python ints)
# --------------------------------------------------------------------------

_SMALL_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(x: int) -> bool:
    """Deterministic Miller–Rabin, valid for x < 3.3e24 with these bases."""
    if x < 2:
        return False
    for p in _SMALL_PRIMES:
        if x % p == 0:
            return x == p
    d, s = x - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        v = pow(a, d, x)
        if v in (1, x - 1):
            continue
        for _ in range(s - 1):
            v = v * v % x
            if v == x - 1:
                break
        else:
            return False
    return True


def gen_coprime_moduli(n: int, bits: int = 15, *, skip: int = 0) -> list[int]:
    """n largest primes strictly below 2**bits (optionally skipping some).

    Primes are pairwise coprime by construction; choosing them just below a
    power of two keeps Kawamura's ``m_i ~ 2^bits`` approximation tight and
    maximizes the dynamic range per lane bit.
    """
    out: list[int] = []
    x = (1 << bits) - 1
    skipped = 0
    while len(out) < n:
        if is_prime(x):
            if skipped < skip:
                skipped += 1
            else:
                out.append(x)
        x -= 2 if x % 2 else 1
        if x < 3:
            raise ValueError(f"not enough {bits}-bit primes for n={n}")
    return out


# --------------------------------------------------------------------------
# RNSBase
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RNSBase:
    """An RNS base ``{m_1..m_n}`` with redundant modulus ``m_a``.

    Instances are hashable (moduli tuples) so they can be closed over by
    ``jax.jit`` functions as static configuration; all table properties are
    cached numpy arrays that become embedded constants when traced.
    """

    moduli: tuple[int, ...]
    ma: int
    bits: int = 15

    def __post_init__(self):
        ms = self.moduli
        if len(set(ms)) != len(ms):
            raise ValueError("duplicate moduli")
        import math

        for i, mi in enumerate(ms):
            if math.gcd(mi, self.ma) != 1:
                raise ValueError(f"m_a={self.ma} not coprime to m_{i}={mi}")
            for mj in ms[i + 1 :]:
                if math.gcd(mi, mj) != 1:
                    raise ValueError(f"moduli {mi},{mj} not coprime")

    # -- sizes ------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.moduli)

    @functools.cached_property
    def M(self) -> int:
        """Dynamic range (Python int; may be thousands of bits)."""
        out = 1
        for m in self.moduli:
            out *= m
        return out

    @property
    def dtype(self):
        """Lane dtype: int32 iff residue products fit 31 bits."""
        return np.int32 if self.bits <= 15 else np.int64

    # -- tables (numpy; exact, computed once) ------------------------------
    @functools.cached_property
    def moduli_np(self) -> np.ndarray:
        return np.asarray(self.moduli, dtype=self.dtype)

    @functools.cached_property
    def inv_tri_np(self) -> np.ndarray:
        """inv_tri[j, i] = m_j^{-1} mod m_i for j < i, else 0.  (Alg. 2)"""
        n = self.n
        t = np.zeros((n, n), dtype=self.dtype)
        for j in range(n):
            for i in range(j + 1, n):
                t[j, i] = pow(self.moduli[j], -1, self.moduli[i])
        return t

    @functools.cached_property
    def betas_ma_np(self) -> np.ndarray:
        """betas[i] = prod_{j<i} m_j mod m_a  (beta_1 = 1).  (Alg. 3)"""
        return self.betas_for((self.ma,))[0]

    def betas_for(self, targets: Sequence[int]) -> np.ndarray:
        """(T, n) partial-product table: betas[t, i] = prod_{j<i} m_j mod m_t.

        Used by the MRC-based base extension (a multi-target Alg. 3): the
        extension is then a dot product — log-depth parallel, per the paper.
        """
        T, n = len(targets), self.n
        out = np.zeros((T, n), dtype=np.int64)
        for t, mt in enumerate(targets):
            acc = 1
            for i in range(n):
                out[t, i] = acc % mt
                acc = (acc * self.moduli[i]) % mt
        return out.astype(self.dtype)

    @functools.cached_property
    def M_mod_ma(self) -> int:
        return self.M % self.ma

    # -- CRT-form constants (Shenoy–Kumaresan / Kawamura baselines) --------
    @functools.cached_property
    def Mi_inv_np(self) -> np.ndarray:
        """|M_i^{-1}|_{m_i} with M_i = M/m_i."""
        return np.asarray(
            [pow(self.M // m, -1, m) for m in self.moduli], dtype=self.dtype
        )

    def Mi_mod(self, targets: Sequence[int]) -> np.ndarray:
        """(T, n): M_i mod m_t."""
        out = np.zeros((len(targets), self.n), dtype=np.int64)
        for t, mt in enumerate(targets):
            for i, m in enumerate(self.moduli):
                out[t, i] = (self.M // m) % mt
        return out.astype(self.dtype)

    def M_mod(self, targets: Sequence[int]) -> np.ndarray:
        return np.asarray([self.M % mt for mt in targets], dtype=self.dtype)

    @functools.cached_property
    def inv2_np(self) -> np.ndarray:
        """2^{-1} mod m_i (all moduli odd) — used by halving/scaling."""
        return np.asarray([pow(2, -1, m) for m in self.moduli], dtype=self.dtype)

    @functools.cached_property
    def inv2_ma(self) -> int:
        return pow(2, -1, self.ma)

    # -- signed embedding -------------------------------------------------
    @functools.cached_property
    def half_M_residues(self) -> np.ndarray:
        """Residues of T = ceil(M/2): X >= T  <=>  X encodes a negative value."""
        T = (self.M + 1) // 2
        return np.asarray([T % m for m in self.moduli], dtype=self.dtype)

    @functools.cached_property
    def half_M_ma(self) -> int:
        return ((self.M + 1) // 2) % self.ma

    # -- misc ---------------------------------------------------------------
    def residues_of(self, x: int) -> np.ndarray:
        """Exact residues of a Python int (negative ok: embeds x mod M)."""
        return np.asarray([x % m for m in self.moduli], dtype=self.dtype)

    def ma_residue_of(self, x: int) -> int:
        """Residue mod m_a of the value x mod M (NOT of x itself when x<0).

        For x < 0 the RNS channels store x + kM, so the matching redundant
        residue is (x mod M) mod m_a.
        """
        return (x % self.M) % self.ma

    def __hash__(self):
        return hash((self.moduli, self.ma, self.bits))


def make_base(n: int, bits: int = 15, *, ma_bits: int | None = None) -> RNSBase:
    """Standard constructor: n primes just below 2**bits, plus the next prime
    down as the redundant modulus (mirrors the paper's 'one modulus of the
    second base B'' usage)."""
    ms = gen_coprime_moduli(n + 1, bits if ma_bits is None else bits)
    return RNSBase(moduli=tuple(ms[:n]), ma=ms[n], bits=bits)
