"""RNS comparison — the paper's contribution (Algorithm 1) plus baselines.

``rns_compare_ge`` implements Algorithm 1 / Theorem 1:

    Delta' = (n_a^(1) - n_a^(2)) mod m_a
    z      = (N1 - N2) channel-wise in B            (= (N1-N2) mod M)
    Delta  = to_ma(MRC(z))                          (= ((N1-N2) mod M) mod m_a)
    N1 >= N2  <=>  Delta == Delta'

One MRC + one Alg.3 dot = (n(n-1)/2 + n) modular mults — half the classical
method's n(n-1).  Valid on the FULL range 0 <= N1,N2 < M with no moduli-form
or bound restrictions (the properties tests assert).

Baselines implemented for the paper's comparisons:
  * ``classic_compare_ge``  — two MRCs + lexicographic digit compare
    (Szabo–Tanaka / Flores; the paper's Table 1 opponent).
  * ``approx_crt_ge``       — Kawamura/Xiao-style fractional-CRT position
    comparison; fast but WRONG for operands closer than the rounding error,
    demonstrating why the paper rejects approximate methods for exactness.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import arith
from .base import RNSBase
from .convert import to_ma
from .mrc import mrc, mrc_unrolled, mrs_ge

__all__ = ["rns_compare_ge", "classic_compare_ge", "approx_crt_ge", "compare_packed_ge"]


def _compare_ge_impl(base: RNSBase, x1, xa1, x2, xa2, *, unroll: bool = False):
    """Algorithm 1, pure jnp — the implementation ``RnsArray.compare_ge``
    routes to on the jnp backend (the pallas backend takes the fused kernel
    in kernels/rns_compare.py instead)."""
    ma = base.ma
    delta_p = jnp.mod(xa1 - xa2, ma)                 # line 1
    z = arith.sub(base, x1, x2)                      # line 2
    digits = (mrc_unrolled if unroll else mrc)(base, z)  # line 3 (Alg. 2)
    delta = to_ma(base, digits)                      # line 4 (Alg. 3)
    return delta == delta_p                          # lines 5-9 (Thm. 1)


def rns_compare_ge(base: RNSBase, x1, xa1, x2, xa2, *, unroll: bool = False):
    """Algorithm 1.  All args batched: x*: (..., n), xa*: (...,).

    Returns a boolean tensor: True where N1 >= N2.

    Legacy shim: lifts the separate (x, xa) argument pairs into ``RnsArray``
    and compares there — prefer ``RnsArray.from_parts(base, x, xa)`` and the
    ``>=`` operator directly (core/array.py).
    """
    from .array import RnsArray

    a = RnsArray.from_parts(base, x1, xa1)
    b = RnsArray.from_parts(base, x2, xa2)
    return a.compare_ge(b, unroll=unroll)


def compare_packed_ge(base: RNSBase, p1, p2, *, unroll: bool = True):
    """Alg. 1 on 'packed' tensors (..., n+1) whose last channel is the
    redundant residue.  This is the layout the gradient codec carries so the
    redundant channel rides along through every ring op.

    Legacy shim over ``RnsArray.from_packed(...).compare_ge(...)``.
    """
    from .array import RnsArray

    a = RnsArray.from_packed(base, p1[..., : base.n + 1])
    b = RnsArray.from_packed(base, p2[..., : base.n + 1])
    return a.compare_ge(b, unroll=unroll)


def classic_compare_ge(base: RNSBase, x1, x2, *, unroll: bool = False):
    """Classical method: MRC both operands, compare digits lexicographically.

    Cost: n(n-1) modular mults + n digit compares (paper Table 1, row 2).
    Needs no redundant modulus — that is the trade the paper makes.
    """
    f = mrc_unrolled if unroll else mrc
    return mrs_ge(f(base, x1), f(base, x2))


def approx_crt_ge(base: RNSBase, x1, x2, *, frac_bits: int = 30):
    """Approximate-CRT comparison baseline (Kawamura-style fractions).

    Position of X in [0,1):  pos(X) ~= sum_i |x_i * Mi^{-1}|_{m_i} / m_i mod 1.
    Compare pos(N1) vs pos(N2) in fixed point.  Exact only when
    |N1 - N2| / M exceeds the accumulated rounding error (~ n * 2^-frac_bits
    + quantization); tests and benchmarks exhibit the failure band, which is
    the paper's argument for an exact method.
    """
    mi_inv = jnp.asarray(base.Mi_inv_np, dtype=x1.dtype)
    m = jnp.asarray(base.moduli_np, dtype=x1.dtype)

    def pos(x):
        xi = jnp.mod(x * mi_inv, m).astype(jnp.int64)  # |x_i Mi^{-1}|_{m_i}
        # fixed-point xi / m_i with frac_bits fractional bits
        fr = (xi << frac_bits) // m.astype(jnp.int64)
        return jnp.mod(jnp.sum(fr, axis=-1), jnp.int64(1) << frac_bits)

    return pos(x1) >= pos(x2)
