"""Dual-base RNS Montgomery modular multiplication (Bajard–Didier–Kornerup).

This is the paper's motivating context (§1, §3): cryptographic modular
multiplication keeps every operand in TWO RNS bases B and B', and one modulus
of B' doubles as the paper's redundant modulus m_a — which is why "the
redundant residue is readily available" and comparison costs only ONE
conversion.

Algorithm (MM(X, Y) = X·Y·M^{-1} mod N, operands in both bases):

    q   <- x·y·(-N^{-1})  in B            (q < M)
    q'  <- extend(q)      B  -> B'         (exact MRC extension)
    r'  <- (x'·y' + q'·N)·M^{-1}  in B'    (exact division by M)
    r   <- extend(r')     B' -> B
    result r == X·Y·M^{-1} (mod N),  r < 2N   (needs M > 4N, M' > 2N)

Both extensions here use the exact MRC path (extend_mrc); the Kawamura
variant is available for benchmarking the approximate trade-off.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import arith
from .base import RNSBase
from .extend import extend_kawamura, extend_mrc

__all__ = ["RNSMontgomery", "DualRep"]


@dataclasses.dataclass
class DualRep:
    """An operand held in both bases: xB (..., n), xBp (..., n')."""

    xB: jnp.ndarray
    xBp: jnp.ndarray


class RNSMontgomery:
    def __init__(self, baseB: RNSBase, baseBp: RNSBase, N: int):
        if not (baseB.M > 4 * N and baseBp.M > 2 * N):
            raise ValueError("need M > 4N and M' > 2N for bounded outputs")
        import math

        if math.gcd(baseB.M, baseBp.M) != 1:
            raise ValueError("bases must be coprime")
        self.B, self.Bp, self.N = baseB, baseBp, N
        # -N^{-1} mod m_i (channel constants in B)
        self.negNinv_B = np.asarray(
            [(-pow(N, -1, m)) % m for m in baseB.moduli], dtype=baseB.dtype
        )
        self.N_Bp = np.asarray([N % m for m in baseBp.moduli], dtype=baseBp.dtype)
        self.Minv_Bp = np.asarray(
            [pow(baseB.M % m, -1, m) for m in baseBp.moduli], dtype=baseBp.dtype
        )

    def to_dual(self, x: int) -> DualRep:
        return DualRep(
            jnp.asarray(self.B.residues_of(x)), jnp.asarray(self.Bp.residues_of(x))
        )

    def from_dual(self, d: DualRep) -> int:
        from .convert import rns_to_int

        return rns_to_int(self.B, np.asarray(d.xB))

    def mul(self, x: DualRep, y: DualRep, *, approx: bool = False) -> DualRep:
        """Montgomery product X·Y·M^{-1} mod N (result < 2N), batched."""
        B, Bp = self.B, self.Bp
        q = arith.mul_const(B, arith.mul(B, x.xB, y.xB), self.negNinv_B)
        if approx:
            qp = extend_kawamura(B, q, Bp.moduli)
        else:
            qp = extend_mrc(B, q, Bp.moduli)
        t = arith.add(
            Bp, arith.mul(Bp, x.xBp, y.xBp), arith.mul_const(Bp, qp, self.N_Bp)
        )
        rp = arith.mul_const(Bp, t, self.Minv_Bp)
        r = extend_mrc(Bp, rp, B.moduli)
        return DualRep(xB=r, xBp=rp)
