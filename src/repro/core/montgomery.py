"""Dual-base RNS Montgomery arithmetic over the typed ``RnsArray`` frontend.

This is the paper's motivating context (§1, §3): cryptographic modular
multiplication keeps every operand in TWO RNS bases B and B'.  The redundant
modulus m_a rides along as an extra ``RnsArray`` channel of the B-side value
(``Layout.BASE_MA``, or ``Layout.RRNS`` with a second redundant channel for
locate-and-correct wire codewords), which is why "the redundant residue is
readily available" and the final comparison costs only ONE conversion.

One Montgomery product MM(X, Y) = X·Y·M^{-1} mod N (operands in both bases):

    q   <- x·y·(-N^{-1})  in B             (q < M)
    q'  <- extend(q)      B  -> B'          (exact MRC extension, Alg. 2+3)
    t'  <- x'·y' + q'·N   in B'             (t = XY + qN ≡ 0 mod M)
    r'  <- t'·M^{-1}      in B'             (exact division by M)
    r   <- extend(r')     B' -> B           (plus the redundant channels)
    result r ≡ X·Y·M^{-1} (mod N),  r < 2N  (needs M > 4N, M' > 2N)

The B-side extension targets include the redundant channels, and those stay
EXACT through every product: r'_j·(M^{-1} mod m'_j) ≡ R mod m'_j holds
per-channel because R·M = T over the integers, so the extension's MRC digits
represent the true R < M' and any extra target channel (m_a, m_b) receives
the true residue of R.  The B'-side value needs no redundant channels (the
comparison and the wire codewords live on the B side), so ``DualRep.hi`` is
always ``Layout.BASE``.

Backend dispatch happens HERE (like ``RnsArray``'s methods): under the
``pallas`` backend with 15-bit bases, ``mont_mul``/``ladder_step`` route to
the fused Pallas kernels in ``repro.kernels.mont_ladder``; otherwise the
pure-jnp reference below runs.  Both paths are exact modular integer
arithmetic, hence bitwise-identical.

>>> from repro.core import RNSBase, gen_coprime_moduli
>>> from repro.core.montgomery import RNSMontgomery
>>> ms = gen_coprime_moduli(14, 15)
>>> B = RNSBase(moduli=tuple(ms[:6]), ma=ms[12], bits=15)
>>> Bp = RNSBase(moduli=tuple(ms[6:12]), ma=ms[13], bits=15)
>>> mont = RNSMontgomery(B, Bp, N=10**20 + 39)          # ~67-bit modulus
>>> mont.modmul(10**19 + 7, 10**18 + 9) == (10**19 + 7) * (10**18 + 9) % mont.N
True
>>> mont.modexp(123456789, 65537) == pow(123456789, 65537, mont.N)
True
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

import jax
import jax.numpy as jnp

from . import arith
from .array import Layout, RnsArray
from .base import RNSBase
from .convert import mrs_dot_mod, rns_to_int
from .dispatch import resolve_backend
from .extend import extend_kawamura, extend_mrc
from .mrc import mrc

__all__ = ["DualRep", "RNSMontgomery", "mont_mul", "ladder_step",
           "mont_consts", "minv_residues", "exp_bits_msb"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DualRep:
    """One big-integer value held in both Montgomery bases.

    ``lo`` is the B-side ``RnsArray`` (any layout — its redundant channels
    are maintained exactly through ``mont_mul``); ``hi`` is the B'-side
    value, always ``Layout.BASE``.  The legacy raw-array attributes ``xB``
    and ``xBp`` are kept as views for pre-RnsArray callers.
    """

    lo: RnsArray
    hi: RnsArray

    def __post_init__(self):
        if self.hi.layout is not Layout.BASE:
            raise ValueError("DualRep.hi carries no redundant channels "
                             "(Layout.BASE); the comparison lives on .lo")

    def tree_flatten(self):
        return (self.lo, self.hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def xB(self):
        """Legacy view: B-side base residue channels ``(..., n)``."""
        return self.lo.x

    @property
    def xBp(self):
        """Legacy view: B'-side residue channels ``(..., n')``."""
        return self.hi.x


# ------------------------------------------------------------- constants


def _channel_targets(base: RNSBase, layout: Layout,
                     mb: int | None) -> tuple[int, ...]:
    """Channel moduli of an RnsArray over ``base`` with ``layout``."""
    reds = ((), (base.ma,), (base.ma, mb))[layout.n_redundant]
    if layout is Layout.RRNS and mb is None:
        raise ValueError("RRNS layout needs the second redundant modulus mb=")
    return tuple(int(m) for m in base.moduli) + tuple(int(m) for m in reds)


@functools.lru_cache(maxsize=None)
def minv_residues(baseB: RNSBase, hi_targets: tuple[int, ...]) -> np.ndarray:
    """``M^{-1} mod m'_j`` per B'-side channel — N-independent, cached."""
    try:
        return np.asarray([pow(baseB.M % t, -1, t) for t in hi_targets],
                          dtype=baseB.dtype)
    except ValueError as e:
        raise ValueError(
            f"every B'-side channel modulus must be coprime to M: {e}"
        ) from None


def mont_consts(baseB: RNSBase, baseBp: RNSBase, N: int, *,
                layout: Layout = Layout.BASE_MA,
                mb: int | None = None) -> dict[str, np.ndarray]:
    """Host-computed per-``N`` channel constants (exact big-int residues).

    Keys: ``neg`` = -N^{-1} mod m_i over B's base channels (n,); ``n_lo`` /
    ``m2_lo`` / ``one_lo`` = residues of N, M² mod N, M mod N over ALL
    B-side channels of ``layout``; ``n_hi`` / ``m2_hi`` / ``one_hi`` = the
    same over B'-side base channels.  All are broadcast-ready rows for
    batched ``mont_mul`` — the serve engine stacks one row per slot.
    """
    if not (baseB.M > 4 * N and baseBp.M > 2 * N):
        raise ValueError("need M > 4N and M' > 2N for bounded outputs")
    if math.gcd(baseB.M, baseBp.M) != 1:
        raise ValueError("bases must be coprime")
    if math.gcd(N, baseB.M) != 1:
        raise ValueError("N must be coprime to M (it has N^{-1} mod m_i)")
    lo_t = _channel_targets(baseB, layout, mb)
    hi_t = tuple(int(m) for m in baseBp.moduli)
    m2 = (baseB.M * baseB.M) % N
    one = baseB.M % N
    enc = lambda v, ts: np.asarray([v % t for t in ts], dtype=baseB.dtype)
    return {
        "neg": np.asarray([(-pow(N, -1, m)) % m for m in baseB.moduli],
                          dtype=baseB.dtype),
        "n_lo": enc(N, lo_t), "n_hi": enc(N, hi_t),
        "m2_lo": enc(m2, lo_t), "m2_hi": enc(m2, hi_t),
        "one_lo": enc(one, lo_t), "one_hi": enc(one, hi_t),
    }


def exp_bits_msb(e: int, nbits: int) -> np.ndarray:
    """``(nbits,)`` int32 exponent bits, most-significant first.  Leading
    zeros are ladder no-ops (r0 stays 1̄), so a fixed-width ladder computes
    any exponent of ≤ ``nbits`` bits in constant time."""
    if e < 0 or e.bit_length() > nbits:
        raise ValueError(f"exponent needs {e.bit_length()} bits > {nbits}")
    return np.asarray([(e >> (nbits - 1 - i)) & 1 for i in range(nbits)],
                      dtype=np.int32)


# ------------------------------------------------------- the multiplication


def _mont_mul_jnp(x: DualRep, y: DualRep, neg, n_hi) -> DualRep:
    """Pure-jnp reference MM — calls the impl functions directly so the
    reference path stays reference even under the pallas backend."""
    bB, bBp = x.lo.base, x.hi.base
    lo_t = _channel_targets(bB, x.lo.layout, x.lo.mb)
    hi_t = tuple(int(m) for m in bBp.moduli)
    mh = jnp.asarray(bBp.moduli_np, dtype=x.hi.dtype)
    # q = x·y·(-N^{-1}) over B's base channels
    q = arith.mul(bB, arith.mul(bB, x.lo.x, y.lo.x),
                  jnp.asarray(neg, dtype=x.lo.dtype))
    qp = mrs_dot_mod(bB, mrc(bB, q), hi_t)                  # exact B -> B'
    t = arith.add(bBp, arith.mul(bBp, x.hi.x, y.hi.x),
                  jnp.mod(qp * jnp.asarray(n_hi, dtype=qp.dtype), mh))
    rp = jnp.mod(t * jnp.asarray(minv_residues(bB, hi_t), dtype=t.dtype), mh)
    r = mrs_dot_mod(bBp, mrc(bBp, rp), lo_t)                # exact B' -> B(+reds)
    return DualRep(x.lo._wrap(r.astype(x.lo.dtype), signed=False),
                   x.hi._wrap(rp.astype(x.hi.dtype), signed=False))


def _check_pair(x: DualRep, y: DualRep):
    if (x.lo.base is not y.lo.base and x.lo.base != y.lo.base) or \
            x.lo.layout is not y.lo.layout or x.lo.mb != y.lo.mb:
        raise ValueError("mont_mul operands need matching bases and layout")


def mont_mul(x: DualRep, y: DualRep, neg, n_hi) -> DualRep:
    """Batched Montgomery product MM(X, Y) = X·Y·M^{-1} mod N, result < 2N
    when inputs are < 2N.  ``neg``/``n_hi`` are per-``N`` channel rows from
    ``mont_consts`` (broadcastable against the batch, so one call can mix
    different moduli N across batch rows)."""
    _check_pair(x, y)
    if resolve_backend() == "pallas" and x.lo.base.bits <= 15 \
            and x.hi.base.bits <= 15:
        from repro.kernels.ops import mont_mul_op

        return mont_mul_op(x, y, neg, n_hi)
    return _mont_mul_jnp(x, y, neg, n_hi)


def _sel(keep0, a: DualRep, b: DualRep) -> DualRep:
    """where(keep0, a, b) element-wise over both bases (keep0: batch bools)."""
    k = keep0[..., None]
    return DualRep(
        a.lo._wrap(jnp.where(k, a.lo._cl(), b.lo._cl())),
        a.hi._wrap(jnp.where(k, a.hi._cl(), b.hi._cl())),
    )


def ladder_step(r0: DualRep, r1: DualRep, bit, neg, n_hi):
    """One branchless Montgomery-ladder bit (constant-time shape):

        t  = MM(r0, r1);  s = MM(r_bit, r_bit)
        bit=0:  (r0, r1) <- (s, t)        bit=1:  (r0, r1) <- (t, s)

    The select is a data-independent ``where`` — both multiplications run
    for every bit, so the ladder's cost and memory trace never depend on
    the exponent (the classic SPA countermeasure)."""
    if resolve_backend() == "pallas" and r0.lo.base.bits <= 15 \
            and r0.hi.base.bits <= 15:
        from repro.kernels.ops import mont_ladder_op

        return mont_ladder_op(r0, r1, bit, neg, n_hi)
    bit0 = jnp.asarray(bit) == 0
    t = _mont_mul_jnp(r0, r1, neg, n_hi)
    sq = _sel(bit0, r0, r1)
    s = _mont_mul_jnp(sq, sq, neg, n_hi)
    return _sel(bit0, s, t), _sel(bit0, t, s)


# ------------------------------------------------------------ the frontend


class RNSMontgomery:
    """Dual-base Montgomery context for a fixed modulus ``N``.

    ``layout`` picks the B-side redundant channels: ``BASE_MA`` (default —
    enough for the Alg.-1 canonicalization in ``modexp``/``modmul``),
    ``RRNS`` (adds m_b, so the value doubles as a locate-and-correct wire
    codeword), or ``BASE`` (bare legacy layout; ``mul`` works, the
    canonicalizing frontends refuse).
    """

    def __init__(self, baseB: RNSBase, baseBp: RNSBase, N: int, *,
                 layout: Layout = Layout.BASE_MA, mb: int | None = None):
        self.consts = mont_consts(baseB, baseBp, N, layout=layout, mb=mb)
        self.B, self.Bp, self.N = baseB, baseBp, int(N)
        self.layout, self.mb = layout, mb
        self._lo_t = _channel_targets(baseB, layout, mb)
        # legacy channel-constant attributes (pre-RnsArray callers)
        self.negNinv_B = self.consts["neg"]
        self.N_Bp = self.consts["n_hi"]
        self.Minv_Bp = minv_residues(baseB, tuple(int(m) for m in baseBp.moduli))
        self._fns: dict = {}

    # ------------------------------------------------------- conversions
    def _lo(self, packed) -> RnsArray:
        return RnsArray.from_packed(self.B, packed, mb=self.mb)

    def to_dual(self, x: int) -> DualRep:
        """Encode a host big int into both bases (+ redundant channels).
        Exact for ANY magnitude — residues are computed host-side."""
        lo = np.asarray([x % t for t in self._lo_t], dtype=self.B.dtype)
        return DualRep(self._lo(jnp.asarray(lo)),
                       RnsArray.from_packed(self.Bp,
                                            jnp.asarray(self.Bp.residues_of(x))))

    def from_dual(self, d: DualRep) -> int:
        return rns_to_int(self.B, np.asarray(d.xB))

    # ------------------------------------------------------------ algebra
    def mul(self, x: DualRep, y: DualRep, *, approx: bool = False) -> DualRep:
        """Montgomery product X·Y·M^{-1} mod N (result < 2N), batched.

        ``approx=True`` benchmarks the Kawamura floating extension instead
        of exact MRC; its result drops the redundant channels (an
        approximate extension cannot maintain them exactly)."""
        if approx:
            B, Bp = self.B, self.Bp
            q = arith.mul_const(B, arith.mul(B, x.xB, y.xB), self.consts["neg"])
            qp = extend_kawamura(B, q, Bp.moduli)
            t = arith.add(Bp, arith.mul(Bp, x.xBp, y.xBp),
                          arith.mul_const(Bp, qp, self.consts["n_hi"]))
            rp = arith.mul_const(Bp, t, self.Minv_Bp)
            r = extend_mrc(Bp, rp, B.moduli)
            return DualRep(RnsArray.from_packed(B, r),
                           RnsArray.from_packed(Bp, rp))
        return mont_mul(x, y, self.consts["neg"], self.consts["n_hi"])

    def _canonicalize(self, lo: RnsArray):
        """Reduce a ``< 2N`` B-side value to ``< N``: one full-range Alg.-1
        comparison against N, then a channel-wise conditional subtract
        (exact in the redundant channels too, since R - N >= 0)."""
        if self.layout is Layout.BASE:
            raise ValueError("canonicalization needs the m_a channel: build "
                             "RNSMontgomery with layout=BASE_MA or RRNS")
        n_arr = self._lo(jnp.asarray(self.consts["n_lo"]))
        ge = lo.compare_ge(n_arr)
        m = jnp.asarray(self._lo_t, dtype=lo.dtype)
        d = lo._cl() - jnp.asarray(self.consts["n_lo"], dtype=lo.dtype)
        d = jnp.where(d < 0, d + m, d)
        return jnp.where(jnp.asarray(ge)[..., None], d, lo._cl())

    def _fn(self, key, build):
        if key not in self._fns:
            self._fns[key] = build()
        return self._fns[key]

    def _m2(self) -> DualRep:
        return DualRep(self._lo(jnp.asarray(self.consts["m2_lo"])),
                       RnsArray.from_packed(self.Bp,
                                            jnp.asarray(self.consts["m2_hi"])))

    def modmul(self, a: int, b: int) -> int:
        """``a·b mod N`` via two Montgomery products (enter domain, exit)."""

        def build():
            def run(a_lo, a_hi, b_lo, b_hi):
                neg, n_hi = self.consts["neg"], self.consts["n_hi"]
                abar = mont_mul(DualRep(self._lo(a_lo),
                                        RnsArray.from_packed(self.Bp, a_hi)),
                                self._m2(), neg, n_hi)
                r = mont_mul(abar,
                             DualRep(self._lo(b_lo),
                                     RnsArray.from_packed(self.Bp, b_hi)),
                             neg, n_hi)
                return self._canonicalize(r.lo)
            return jax.jit(run)

        da, db = self.to_dual(a % self.N), self.to_dual(b % self.N)
        out = self._fn("modmul", build)(da.lo.to_packed(), da.hi.to_packed(),
                                        db.lo.to_packed(), db.hi.to_packed())
        return rns_to_int(self.B, np.asarray(out)[..., : self.B.n])

    def modexp(self, a: int, e: int) -> int:
        """``a^e mod N`` by a constant-time Montgomery ladder — bitwise
        equal to ``pow(a, e, N)``.  The jitted ladder scan is cached per
        exponent WIDTH, so same-width exponents share one compilation."""
        nbits = max(1, int(e).bit_length())

        def build():
            def run(a_lo, a_hi, bits):
                neg, n_hi = self.consts["neg"], self.consts["n_hi"]
                abar = mont_mul(DualRep(self._lo(a_lo),
                                        RnsArray.from_packed(self.Bp, a_hi)),
                                self._m2(), neg, n_hi)
                one = DualRep(self._lo(jnp.asarray(self.consts["one_lo"])),
                              RnsArray.from_packed(
                                  self.Bp, jnp.asarray(self.consts["one_hi"])))

                def body(carry, b):
                    r0, r1 = carry
                    return ladder_step(r0, r1, b, neg, n_hi), None

                (r0, _), _ = jax.lax.scan(body, (one, abar), bits)
                # leave the domain: MM(r0, 1) — literal all-ones residues
                ones = DualRep(
                    self._lo(jnp.ones(len(self._lo_t), self.B.dtype)),
                    RnsArray.from_packed(self.Bp,
                                         jnp.ones(self.Bp.n, self.Bp.dtype)))
                return self._canonicalize(
                    mont_mul(r0, ones, neg, n_hi).lo)
            return jax.jit(run)

        da = self.to_dual(a % self.N)
        out = self._fn(("modexp", nbits), build)(
            da.lo.to_packed(), da.hi.to_packed(),
            jnp.asarray(exp_bits_msb(int(e), nbits)))
        return rns_to_int(self.B, np.asarray(out)[..., : self.B.n])
