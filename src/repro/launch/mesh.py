"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state; dryrun.py sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "required_devices"]


def required_devices(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (data, model) or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (data=1, model=1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
