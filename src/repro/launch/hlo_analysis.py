"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

collective_bytes parses the partitioned module text (per-device view) and
sums effective per-device wire bytes for every collective op:

    all-reduce        2 * size   (ring = reduce-scatter + all-gather)
    all-gather        output size (data received per device, ~out*(n-1)/n)
    reduce-scatter    input size
    all-to-all        size       (each device sends/receives ~size)
    collective-permute size

Async pairs (-start/-done) are counted once via the -start line.

Roofline terms (seconds, per chip) for TPU v5e:
    compute    = HLO flops / 197e12 (bf16 peak)
    memory     = HLO bytes accessed / 819e9
    collective = per-device collective bytes / 50e9 (ICI per link)
"""
from __future__ import annotations

import re

__all__ = ["collective_bytes", "roofline", "HW"]

HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(?:pred|[suf]\d+|bf16|c64|c128)\[.*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_DONE_RE = re.compile(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)-done\(")


def _shapes_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device wire bytes by collective kind from partitioned HLO."""
    out = {
        "all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
        "all-to-all": 0, "collective-permute": 0, "ops": 0,
    }
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        eq = line.index("=")
        par = line.index(m.group(1))
        out_bytes = _shapes_bytes(line[eq:par])
        in_bytes = _shapes_bytes(line[par:])
        if kind == "all-reduce":
            eff = 2 * out_bytes
        elif kind == "all-gather":
            eff = out_bytes
        elif kind == "reduce-scatter":
            eff = in_bytes
        else:
            eff = max(out_bytes, in_bytes)
        out[kind] += eff
        out["ops"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def roofline(cost: dict, coll: dict) -> dict:
    """Three roofline terms (seconds) from per-device cost/collective data."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll["total"])
    terms = {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": byts / HW["hbm_bw"],
        "collective_s": cb / HW["ici_bw"],
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collective_bytes_per_device": cb,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = (
        terms["compute_s"] / bound if bound > 0 else 0.0
    )
    return terms
