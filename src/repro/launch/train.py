"""Training driver: data pipeline -> jitted train step -> checkpoints.

Runs REAL steps on whatever devices exist (CPU smoke configs by default;
the same code path pjit-shards on a TPU mesh).  Demonstrates the
fault-tolerance loop: resume from the newest repairable checkpoint (RRNS
repair-on-restore, DESIGN.md §14), policy-driven async saves on a single
background writer, and a step-time watchdog (straggler hook).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 30 --ckpt-dir /tmp/ck --ckpt-policy 2@10,5,60s \
        --ckpt-keep 3 [--rns-allreduce]

    # RRNS locate-and-correct transport with an injected wire corruption
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --smoke \
        --steps 4 --rns-correct --inject-corrupt-step 2

    # corrupt one RRNS channel of the newest checkpoint, then watch the
    # restore repair it in stride (2 channels: refuse + fall back)
    PYTHONPATH=src python -m repro.launch.train --smoke --steps 10 \
        --ckpt-dir /tmp/ck --inject-ckpt-corrupt 1
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401  (x64)
from repro.configs import get_config
from repro.models import init_params
from repro.train import checkpointer as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def _corrupt_wire(codec):
    """Transport hook that flips one residue of the local wire buffer —
    element 0's channel-0 residue moves by +1 mod m_1, a guaranteed-real,
    still-canonical corruption (the injection half of the --rns-correct
    smoke demo; the repair half must undo it exactly)."""
    m0 = int(codec.base.moduli[0])

    def hook(buf):
        # raw channel-major (n_channels, B) residues of the RnsArray wire
        # buffer (train_step unwraps/rewraps the type around the hook)
        return buf.at[0, 0].set(jnp.mod(buf[0, 0] + 1, m0))

    return hook


def make_rns_dp_step(cfg, opt_cfg, codec, *, repair=False, inject=False):
    """Data-parallel step with the paper's RNS-exact gradient all-reduce,
    bucketed: per-device grads encode (fused Pallas kernel when the codec
    qualifies) into ONE contiguous (n_channels, B_total) int32 buffer, the
    whole pytree moves in a single per-channel psum, and the fused decode
    runs at the optimizer boundary inside ``adamw_update``
    (dist/grad_codec.py, DESIGN.md §9).  Runs under shard_map over the
    'data' axis.

    repair=True adds the RRNS locate-and-correct pass on the wire buffer
    (needs a ``correct=True`` codec, DESIGN.md §10); inject=True corrupts
    one residue first, so the returned step demonstrates in-flight repair.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    ndev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    step = make_train_step(
        cfg, opt_cfg, rns_codec=codec, rns_axis="data", rns_repair=repair,
        transport_hook=_corrupt_wire(codec) if inject else None,
    )
    fn = shard_map(
        step, mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn), ndev


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--ckpt-policy", default="",
                    help="save-policy grammar 'N | N@M | Ns | Nm, ...' "
                         "(e.g. '2@10,5,60s'); overrides --save-every")
    ap.add_argument("--ckpt-keep", type=int, default=0,
                    help="retention GC: keep only the newest K committed "
                         "steps (0 = keep everything)")
    ap.add_argument("--inject-ckpt-corrupt", type=int, default=0,
                    metavar="K",
                    help="corrupt K RRNS channels of the newest saved "
                         "checkpoint before restoring: 1 demonstrates "
                         "locate-and-correct, 2 the refuse-and-fall-back "
                         "path (needs --ckpt-dir)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rns-allreduce", action="store_true",
                    help="use the paper's RNS gradient aggregation (DP demo)")
    ap.add_argument("--rns-correct", action="store_true",
                    help="RNS aggregation with the second redundant modulus "
                         "and in-flight RRNS repair of corrupted wire "
                         "buffers (implies --rns-allreduce)")
    ap.add_argument("--inject-corrupt-step", type=int, default=-1,
                    metavar="N",
                    help="with --rns-correct: corrupt one wire residue at "
                         "step N to demonstrate the in-place repair")
    ap.add_argument("--unfused-codec", action="store_true",
                    help="force the jnp encode/decode path for the RNS "
                         "codec (A/B against the fused Pallas kernels)")
    ap.add_argument("--watchdog-x", type=float, default=3.0,
                    help="warn when a step exceeds x * median step time")
    ap.add_argument("--profile-start-step", type=int, default=-1,
                    metavar="N",
                    help="train step at which to start a JAX profiler "
                         "trace (-1 disables; levanter Performance-Guide "
                         "pattern: start step + step count)")
    ap.add_argument("--profile-steps", type=int, default=0, metavar="N",
                    help="train steps to capture in the profiler window")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="profiler artifact directory (default: "
                         "--ckpt-dir when set, else '.')")
    args = ap.parse_args(argv)
    if args.inject_corrupt_step >= 0 and not args.rns_correct:
        ap.error("--inject-corrupt-step needs --rns-correct (there is no "
                 "repair path to demonstrate without it)")
    if args.inject_ckpt_corrupt and not args.ckpt_dir:
        ap.error("--inject-ckpt-corrupt needs --ckpt-dir (there is no "
                 "checkpoint to corrupt without one)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg.validate()
    opt_cfg = AdamWConfig(warmup=5, decay_steps=max(args.steps, 10))

    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    start_step = 0

    if args.ckpt_dir:
        if args.inject_ckpt_corrupt:
            latest = ckpt.discover_latest(args.ckpt_dir)
            if latest is None:
                ap.error("--inject-ckpt-corrupt: nothing saved under "
                         f"{args.ckpt_dir} yet")
            ckpt.inject_channel_corruption(
                os.path.join(args.ckpt_dir, f"step_{latest}"),
                leaf=0, channels=tuple(range(args.inject_ckpt_corrupt)),
            )
            print(f"[inject] corrupted {args.inject_ckpt_corrupt} RRNS "
                  f"channel(s) of step {latest}, leaf 0, element 0")
        abs_tree = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt_state},
        )
        try:
            # restore directly (one scan+read+hash of the checkpoint);
            # probing latest first would read and decode it all twice
            tree, start_step, extra, rep = ckpt.restore(
                args.ckpt_dir, abs_tree)
        except FileNotFoundError:
            pass  # fresh run: nothing restorable yet
        else:
            params, opt_state = tree["params"], tree["opt"]
            print(f"[resume] restored step {start_step}: "
                  f"{rep['leaves']} leaves, "
                  f"repaired_leaves={rep['repaired_leaves']} "
                  f"repaired_elements={rep['repaired_elements']} "
                  f"steps_skipped={rep['steps_skipped']}")
            opt_step = int(np.asarray(opt_state["step"]))
            if opt_step != start_step:
                print(f"[resume] WARNING: optimizer step {opt_step} != "
                      f"checkpoint step {start_step}")

    inject_fn = None
    if args.rns_allreduce or args.rns_correct:
        from repro.dist.grad_codec import GradCodec

        codec = GradCodec.make(world=max(len(jax.devices()), 2),
                               fused=not args.unfused_codec,
                               correct=args.rns_correct)
        step_fn, ndev = make_rns_dp_step(cfg, opt_cfg, codec,
                                         repair=args.rns_correct)
        if args.rns_correct and args.inject_corrupt_step >= 0:
            inject_fn, _ = make_rns_dp_step(cfg, opt_cfg, codec,
                                            repair=True, inject=True)
        assert args.batch % ndev == 0, "batch must divide device count"
        reds = "+".join(str(r) for r in codec.redundant)
        print(f"[rns] RNS gradient all-reduce over {ndev} device(s), "
              f"base n={codec.base.n} moduli, redundant {reds}, "
              f"bucketed single-psum transport, "
              f"{'fused Pallas' if codec.use_fused else 'jnp'} codec"
              + (", RRNS locate-and-correct armed" if args.rns_correct
                 else ""))
    else:
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches)
        )

    loader = SyntheticLM(cfg, seq=args.seq, batch=args.batch)
    prefetch = Prefetcher(loader, start_step=start_step)
    saver = None
    if args.ckpt_dir:
        policy = args.ckpt_policy or str(args.save_every)
        saver = ckpt.Checkpointer(args.ckpt_dir, policy,
                                  keep=args.ckpt_keep or None)
        print(f"[ckpt] policy {policy!r}, "
              f"keep {'all' if not args.ckpt_keep else args.ckpt_keep}, "
              f"async RRNS-coded saves under {args.ckpt_dir}")
    from repro.launch.profiling import ProfilerWindow

    window = ProfilerWindow(
        args.profile_start_step, args.profile_steps,
        args.profile_dir or args.ckpt_dir or ".", label="train",
    )
    times = []
    try:
        for _ in range(start_step, args.steps):
            window.step()
            step, batch = prefetch.next()
            t0 = time.time()
            fn = (inject_fn if inject_fn is not None
                  and step == args.inject_corrupt_step else step_fn)
            params, opt_state, metrics = fn(
                params, opt_state,
                jax.tree_util.tree_map(jnp.asarray, batch),
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            times.append(dt)
            med = sorted(times)[len(times) // 2]
            if len(times) > 3 and dt > args.watchdog_x * med:
                print(f"[watchdog] step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s) — straggler suspected")
            if metrics.get("repaired", 0) > 0:
                print(f"[rns-correct] repaired "
                      f"{int(metrics['repaired'])} corrupted wire "
                      f"value(s) in place at step {step} — no rollback")
            if metrics.get("unrepairable", 0) > 0:
                print(f"[rns-correct] step {step}: "
                      f"{int(metrics['unrepairable'])} element(s) beyond "
                      f"single-channel repair — checkpoint rollback advised")
            print(f"step {step:4d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['gnorm']:.3f} {dt*1e3:.0f}ms")
            if saver is not None:
                saver.maybe_save(step + 1,
                                 {"params": params, "opt": opt_state},
                                 extra={"opt_step": int(metrics["opt_step"])})
    finally:
        window.close()
        prefetch.close()
        if saver is not None:
            saver.close()  # drain the queue; re-raise any failed save
    if window.enabled and window.artifact:
        print(f"[profile] captured {window.captured} step(s) under "
              f"{window.artifact}")
    print("done")
    return params


if __name__ == "__main__":
    main()
