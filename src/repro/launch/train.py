"""Training driver: data pipeline -> jitted train step -> checkpoints.

Runs REAL steps on whatever devices exist (CPU smoke configs by default;
the same code path pjit-shards on a TPU mesh).  Demonstrates the
fault-tolerance loop: resume from the newest fingerprint-valid checkpoint,
async atomic saves, and a step-time watchdog (straggler hook).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 30 --ckpt-dir /tmp/ck --save-every 10 [--rns-allreduce]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401  (x64)
from repro.configs import get_config
from repro.models import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def make_rns_dp_step(cfg, opt_cfg, codec):
    """Data-parallel step with the paper's RNS-exact gradient all-reduce,
    bucketed: per-device grads encode (fused Pallas kernel when the codec
    qualifies) into ONE contiguous (n+1, B_total) int32 buffer, the whole
    pytree moves in a single per-channel psum, and the fused decode runs at
    the optimizer boundary inside ``adamw_update`` (dist/grad_codec.py,
    DESIGN.md §9).  Runs under shard_map over the 'data' axis."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    ndev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()), ("data",))
    step = make_train_step(cfg, opt_cfg, rns_codec=codec, rns_axis="data")
    fn = shard_map(
        step, mesh,
        in_specs=(P(), P(), P("data")),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn), ndev


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--rns-allreduce", action="store_true",
                    help="use the paper's RNS gradient aggregation (DP demo)")
    ap.add_argument("--unfused-codec", action="store_true",
                    help="force the jnp encode/decode path for the RNS "
                         "codec (A/B against the fused Pallas kernels)")
    ap.add_argument("--watchdog-x", type=float, default=3.0,
                    help="warn when a step exceeds x * median step time")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg.validate()
    opt_cfg = AdamWConfig(warmup=5, decay_steps=max(args.steps, 10))

    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    start_step = 0

    if args.ckpt_dir:
        abs_tree = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt_state},
        )
        try:
            # restore directly (one scan+read+hash of the checkpoint);
            # probing latest_step first would read and hash it all twice
            tree, start_step, _ = ckpt.restore(args.ckpt_dir, abs_tree)
        except FileNotFoundError:
            pass  # fresh run: nothing restorable yet
        else:
            params, opt_state = tree["params"], tree["opt"]
            print(f"[resume] restored fingerprint-valid step {start_step}")

    if args.rns_allreduce:
        from repro.dist.grad_codec import GradCodec

        codec = GradCodec.make(world=max(len(jax.devices()), 2),
                               fused=not args.unfused_codec)
        step_fn, ndev = make_rns_dp_step(cfg, opt_cfg, codec)
        assert args.batch % ndev == 0, "batch must divide device count"
        print(f"[rns] RNS gradient all-reduce over {ndev} device(s), "
              f"base n={codec.base.n} moduli, m_a={codec.base.ma}, "
              f"bucketed single-psum transport, "
              f"{'fused Pallas' if codec.use_fused else 'jnp'} codec")
    else:
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches)
        )

    loader = SyntheticLM(cfg, seq=args.seq, batch=args.batch)
    prefetch = Prefetcher(loader, start_step=start_step)
    pending_save = None
    times = []
    try:
        for _ in range(start_step, args.steps):
            step, batch = prefetch.next()
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state,
                jax.tree_util.tree_map(jnp.asarray, batch),
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            times.append(dt)
            med = sorted(times)[len(times) // 2]
            if len(times) > 3 and dt > args.watchdog_x * med:
                print(f"[watchdog] step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s) — straggler suspected")
            print(f"step {step:4d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['gnorm']:.3f} {dt*1e3:.0f}ms")
            if args.ckpt_dir and (step + 1) % args.save_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save_async(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                )
    finally:
        prefetch.close()
        if pending_save is not None:
            pending_save.join()
    print("done")
    return params


if __name__ == "__main__":
    main()
