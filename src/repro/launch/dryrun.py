"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective analysis for the roofline report.

MUST set XLA_FLAGS before ANY jax import (device count locks on first init):
the two lines below are therefore the first statements of the module.

Usage:
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro  # noqa: E402,F401  (enables x64)
from repro.configs import SHAPES, ALIASES, get_config, shape_cells  # noqa: E402
from repro.dist.act_sharding import use_mesh  # noqa: E402
from repro.dist.sharding import (  # noqa: E402
    batch_specs,
    cache_specs,
    named_shardings,
    opt_state_specs,
    param_specs,
)
from repro.launch.hlo_analysis import roofline  # noqa: E402
from repro.launch.hlo_costs import analyze_module  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import abstract_params  # noqa: E402
from repro.serve.serve_step import (  # noqa: E402
    cache_abstract,
    make_decode_step,
    make_prefill,
    prompt_abstract,
)
from repro.train.optimizer import AdamWConfig, adamw_init  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

HBM_PER_CHIP = 16 * 1024**3  # v5e


# ----------------------------------------------------------------- helpers
def count_params(cfg, params_abs):
    """(total, active) parameter counts; MoE experts scale by top_k/E."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        keys = [str(getattr(k, "key", k)) for k in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "moe" in keys and keys[-1] in ("wi", "wo"):
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return total, int(active)


def model_flops(cfg, params_abs, kind: str, batch: int, seq: int) -> float:
    """6·N_active·D (train) or 2·N_active·D (serve), global."""
    _, active = count_params(cfg, params_abs)
    tokens = batch * (1 if kind == "decode" else seq)
    return (6.0 if kind == "train" else 2.0) * active * tokens


def train_batch_abstract(cfg, batch: int, seq: int):
    spec = {"tokens": jax.ShapeDtypeStruct((batch, seq + 1), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.float32
        )
    return spec


def input_specs(cfg, shape_name: str, params_abs):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cell = SHAPES[shape_name]
    kind, seq, batch = cell["kind"], cell["seq"], cell["batch"]
    if kind == "train":
        return {"batch": train_batch_abstract(cfg, batch, seq)}
    if kind == "prefill":
        return {"batch": prompt_abstract(cfg, batch, seq)}
    cache = cache_abstract(cfg, params_abs, batch, seq)
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ----------------------------------------------------------------- lowering
def lower_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               kv_quant: bool = False, seq_parallel: bool = False):
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    kind, seq, batch = cell["kind"], cell["seq"], cell["batch"]
    if (kv_quant and kind != "train" and not cfg.window
            and cfg.family in ("dense", "vlm", "moe")):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if seq_parallel and cfg.family in ("dense", "vlm", "moe"):
        # Korthikanti-style sequence parallelism: residual/norm activations
        # shard (batch x seq); shrinks the live (b, S, d) temps that
        # dominate long prefill (the ROADMAP seq-parallel item)
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    # bf16 weights everywhere; training keeps f32 masters INSIDE the
    # (ZeRO-sharded) optimizer state (mixed-precision production layout).
    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")

    params_abs = abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, n_experts=cfg.n_experts)
    psh = named_shardings(pspecs, mesh)
    ins = input_specs(cfg, shape_name, params_abs)

    with mesh, use_mesh(mesh):
        if kind == "train":
            opt_abs = jax.eval_shape(
                lambda p: adamw_init(p, master=True), params_abs
            )
            zspec = opt_state_specs(params_abs, pspecs, mesh, zero1=cfg.zero1)
            ospecs = {"m": zspec, "v": zspec, "master": zspec, "step": P()}
            osh = named_shardings(ospecs, mesh)
            bsh = named_shardings(batch_specs(ins["batch"], mesh), mesh)
            step = make_train_step(
                cfg, AdamWConfig(), microbatches=microbatches,
                grad_shardings=None if os.environ.get("RNS_NO_GRAD_PIN") else psh,
            )
            msh = named_shardings(
                {k: P() for k in ("loss", "ce", "aux", "gnorm", "opt_step")},
                mesh
            )
            jitted = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, msh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, ins["batch"])
        elif kind == "prefill":
            cache_len = seq + (cfg.n_patches if cfg.family == "vlm" else 0)
            fn = make_prefill(cfg, cache_len)
            bsh = named_shardings(batch_specs(ins["batch"], mesh), mesh)
            cache_abs = jax.eval_shape(fn, params_abs, ins["batch"])[1]
            csh = named_shardings(cache_specs(cache_abs, mesh), mesh)
            lsh = named_shardings(
                batch_specs(
                    jax.ShapeDtypeStruct((batch, cfg.vocab), jnp.float32), mesh
                ),
                mesh,
            )
            jitted = jax.jit(
                fn, in_shardings=(psh, bsh), out_shardings=(lsh, csh)
            )
            lowered = jitted.lower(params_abs, ins["batch"])
        else:  # decode
            fn = make_decode_step(cfg)
            csh = named_shardings(cache_specs(ins["cache"], mesh), mesh)
            tsh = named_shardings(batch_specs(ins["tokens"], mesh), mesh)
            lsh = named_shardings(
                batch_specs(
                    jax.ShapeDtypeStruct((batch, cfg.vocab), jnp.float32), mesh
                ),
                mesh,
            )
            jitted = jax.jit(
                fn,
                in_shardings=(psh, csh, tsh, named_shardings(P(), mesh)),
                out_shardings=(lsh, csh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_abs, ins["cache"], ins["tokens"], ins["pos"]
            )
    return cfg, params_abs, lowered, (kind, seq, batch)


def run_cell(arch: str, shape_name: str, mesh_name: str, *, microbatches=1,
             kv_quant=False, seq_parallel=False):
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    ndev = mesh.size
    t0 = time.time()
    cfg, params_abs, lowered, (kind, seq, batch) = lower_cell(
        arch, shape_name, mesh, microbatches=microbatches, kv_quant=kv_quant,
        seq_parallel=seq_parallel,
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (per-device static memory)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    # Trip-count-aware accounting (XLA's cost_analysis counts scan bodies
    # once — useless for scanned-layer models; see launch/hlo_costs.py).
    mc = analyze_module(compiled.as_text())
    cost = {"flops": mc.flops, "bytes accessed": mc.bytes}
    print({"flops": mc.flops, "bytes": mc.bytes,
           "xla_flops_once": xla_cost.get("flops")})
    coll = mc.collectives
    for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute", "ops"):
        coll.setdefault(k, 0.0)
    terms = roofline(cost, coll)
    terms["dynamic_loops"] = mc.dynamic_loops
    terms["while_loops"] = mc.while_loops

    mf = model_flops(cfg, params_abs, kind, batch, seq)
    per_dev_bytes = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    # The CPU backend promotes every bf16 dot to f32 (no native bf16 GEMM),
    # so fat temporaries are f32 copies of bf16 tensors — roughly 2x what the
    # TPU compilation holds.  Report both raw and adjusted (see EXPERIMENTS
    # §Dry-run methodology).
    per_dev_adj = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes // 2
        - mem.alias_size_in_bytes
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": ndev,
        "kind": kind,
        "seq": seq,
        "global_batch": batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "per_device_bytes_tpu_adjusted": per_dev_adj,
            "fits_hbm_raw_cpu": bool(per_dev_bytes < HBM_PER_CHIP),
            "fits_hbm": bool(per_dev_adj < HBM_PER_CHIP),
        },
        "collectives": coll,
        "xla_cost_analysis_once": {
            k: xla_cost.get(k) for k in ("flops", "bytes accessed")
        },
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": mf / ndev,
        "useful_flops_ratio": (
            (mf / ndev) / terms["hlo_flops_per_device"]
            if terms["hlo_flops_per_device"]
            else 0.0
        ),
        "knobs": {"microbatches": microbatches, "remat": cfg.remat,
                  "zero1": cfg.zero1, "window_cache": cfg.window_cache,
                  "kv_quant": cfg.kv_quant, "seq_parallel": cfg.seq_parallel},
    }
    return rec


def run_cell_autofit(arch, shape, mesh_name, *, microbatches=1,
                     kv_quant=False):
    """Escalate memory knobs until the cell fits HBM: train cells climb the
    grad-accumulation ladder (microbatches 1 -> 4 -> 8 -> 16), serve cells
    turn on the int8 KV cache, then sequence parallelism.  Explicit
    ``--microbatches`` / ``--kv-quant`` flags set the ladder FLOOR (never
    escaped downward).  Records the FIRST fitting configuration (knobs are
    in the artifact), or the last attempt if none fits — the artifact
    guard test then reports the cell honestly as over-HBM."""
    kind = SHAPES[shape]["kind"]
    if kind == "train":
        mbs = [mb for mb in (1, 4, 8, 16) if mb >= microbatches]
        ladder = [{"microbatches": mb} for mb in (mbs or [microbatches])]
    else:
        # only offer the rungs lower_cell will actually apply to this
        # config — re-lowering an unchanged cell buys nothing
        cfg = get_config(arch)
        quantizable = not cfg.window and cfg.family in ("dense", "vlm", "moe")
        ladder = [] if kv_quant and quantizable else [{}]
        if quantizable:
            ladder.append({"kv_quant": True})
            ladder.append({"kv_quant": True, "seq_parallel": True})
        elif cfg.family in ("dense", "vlm", "moe"):
            ladder.append({"seq_parallel": True})
    rec = None
    for knobs in ladder:
        rec = run_cell(arch, shape, mesh_name, **knobs)
        if rec["memory"]["fits_hbm"]:
            return rec
        print(f"[autofit] {arch}/{shape}/{mesh_name} over HBM at {knobs}; "
              f"escalating", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--auto-fit", action="store_true",
                    help="escalate microbatches (train) / int8 KV cache "
                         "(serve) until the cell fits HBM")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [
            (a, s) for a in ALIASES for s in shape_cells(get_config(a))
        ]
    else:
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mesh_name in meshes:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                if args.auto_fit:
                    rec = run_cell_autofit(
                        arch, shape, mesh_name,
                        microbatches=args.microbatches,
                        kv_quant=args.kv_quant,
                    )
                else:
                    rec = run_cell(
                        arch, shape, mesh_name,
                        microbatches=args.microbatches,
                        kv_quant=args.kv_quant,
                    )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(
                    f"[ok]   {tag}: compile={rec['compile_s']}s "
                    f"bottleneck={r['bottleneck']} "
                    f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                    f"coll={r['collective_s']:.4f}s "
                    f"fits={rec['memory']['fits_hbm']}"
                    f" (raw={rec['memory']['fits_hbm_raw_cpu']})",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
