# Copyright 2026 the repro authors
#
# JAX profiler capture windows for the drivers (levanter's
# Performance-Guide pattern: a start step + a step count on the command
# line, one trace artifact per run).  Shared by ``launch/train.py``
# (``--profile-start-step/--profile-steps``) and ``launch/serve.py``
# (same flags; a "step" is one driver tick / offline loop iteration).

from __future__ import annotations

import os

import jax

__all__ = ["ProfilerWindow"]


class ProfilerWindow:
    """Capture steps ``[start, start + n)`` of a driver loop.

    Call ``step()`` once at the top of every driver iteration; the
    window starts/stops ``jax.profiler`` around the configured slice and
    ``close()`` (always call it — a crashed run must not leave the
    profiler armed) stops a still-open trace.  Disabled entirely when
    ``start < 0`` or ``n < 1``, so drivers can construct one
    unconditionally.  The artifact lands under
    ``<outdir>/profile_<label>/`` (TensorBoard's XPlane layout).
    """

    def __init__(self, start: int, n: int, outdir: str, label: str = "run"):
        self.enabled = start >= 0 and n >= 1
        self.start, self.n = int(start), int(n)
        self.logdir = os.path.join(outdir, f"profile_{label}")
        self.artifact: str | None = None
        self.captured = 0
        self._step = 0
        self._active = False
        self._done = False

    def step(self) -> None:
        if not self.enabled or self._done:
            return
        if not self._active and self._step == self.start:
            os.makedirs(self.logdir, exist_ok=True)
            jax.profiler.start_trace(self.logdir)
            self._active = True
            self.artifact = self.logdir
        elif self._active:
            self.captured += 1
            if self.captured >= self.n:
                jax.profiler.stop_trace()
                self._active = False
                self._done = True
        self._step += 1

    def close(self) -> None:
        """Stop a still-open capture (loop ended inside the window)."""
        if self._active:
            jax.profiler.stop_trace()
            self._active = False
            self._done = True
