"""Roofline report generator: dry-run JSONs -> markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline_report \
        --dryrun experiments/dryrun --out experiments/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ALIASES, SHAPES, get_config, shape_cells


def load_records(dryrun_dir):
    recs = {}
    for path in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_row(r):
    t = r["roofline"]
    mem = r["memory"]
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
        f"| {t['collective_s']*1e3:.2f} | **{t['bottleneck']}** "
        f"| {r['model_flops_per_device']/1e12:.2f} "
        f"| {t['hlo_flops_per_device']/1e12:.2f} "
        f"| {r['useful_flops_ratio']:.2f} "
        f"| {mem['per_device_bytes_tpu_adjusted']/2**30:.1f} "
        f"| {'Y' if mem['fits_hbm'] else 'N'} |"
    )


HEADER = (
    "| arch | shape | mesh | compute ms | memory ms | collective ms | "
    "bottleneck | model TF/dev | HLO TF/dev | useful | GiB/dev | fits |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|---|"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--mesh", default="single",
                    help="mesh for the main table (single|multi|both)")
    args = ap.parse_args()
    recs = load_records(args.dryrun)

    lines = [HEADER]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    skipped = []
    for arch in ALIASES:
        cfg = get_config(arch)
        cells = shape_cells(cfg)
        for shape in SHAPES:
            if shape not in cells:
                skipped.append((arch, shape))
                continue
            for mesh in meshes:
                r = recs.get((arch, shape, mesh))
                lines.append(
                    fmt_row(r) if r else
                    f"| {arch} | {shape} | {mesh} | — | — | — | MISSING "
                    f"| — | — | — | — | — |"
                )
    lines.append("")
    lines.append("Skipped cells (full-attention archs at 500k decode, "
                 "DESIGN.md §6):")
    for arch, shape in skipped:
        lines.append(f"- {arch} × {shape}: SKIP")
    out = "\n".join(lines)
    with open(args.out, "w") as f:
        f.write(out + "\n")
    print(out)


if __name__ == "__main__":
    main()
