"""Continuous-batching serving driver (DESIGN.md §12).

Drives ``repro.serve.ContinuousBatcher`` over a request workload — either
synthetic (``--requests N`` with Poisson arrivals) or replayed from a
workload file (``--trace FILE``) — and reports latency/throughput.  The
simulation clock is DECODE-STEP TICKS (one persistent batched decode step
per tick), so every latency number is deterministic for a given seed;
wall-clock throughput is reported separately.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 8 --slots 4 --arrival-rate 0.5 --report serve_report.json

Workload file format (JSON lines, one request per line)::

    {"rid": 0, "prompt": [3, 1, 4], "max_new": 16, "eos": 7, "arrival": 0.0}
    {"rid": 1, "family": "crypto", "op": "modexp",
     "a": "0x1234", "b": 65537, "n": "0x10001", "arrival": 2.0}

``prompt`` may be replaced by ``"prompt_len": N`` to synthesize N random
token ids from ``--seed``.  Crypto-family lines (DESIGN.md §15) carry big
integers as JSON ints or hex strings (anything ``int(s, 0)`` accepts) and
need ``--crypto-slots`` to be accepted by the engine; rids are checked for
uniqueness ACROSS families (the engine keys verify state on rid in one
shared log).  ``--families llm,crypto`` filters a replay to a subset.
``--save-trace`` writes the (possibly synthetic) workload back out in this
format (big ints as hex) so a run is replayable.

Smoke flags: ``--smoke`` (the DEFAULT: shrink the arch to the CPU-sized
config) and ``--no-smoke`` (run the full published config) are an explicit
pair over one setting — exactly one applies, and the help text of each
names the default.

``--page-size N`` switches the engine onto the paged, prefix-sharing pool
layout (DESIGN.md §13; ``--pages`` sizes the pool, ``--no-prefix-share``
disables admission dedup) and adds a ``paging`` block to the report:
pages in use / shared (dedup hits) / CoW copies, and the per-page
fingerprint verify/repair counters under ``--rns-verify``.

``--rns-verify`` arms the engine's RnsArray cache-integrity fingerprints
(verified at every retirement); ``--inject-wire-corrupt`` additionally
corrupts one stored wire buffer after the run and demonstrates the
detect -> ``repair_packed`` -> re-verify loop in the report.

Families the batcher gates out (ssm/hybrid/encdec/vlm) fall back to a
single-shot sequential loop (``report["engine"] == "single-shot"``) so
every arch in the zoo stays servable; ``--rns-verify`` requires the slot
engine and raises for them.

``--mode`` selects the measurement layer (DESIGN.md §16):

* ``sim`` (default) — the deterministic tick-clock replay above.
* ``offline`` — the MLPerf-offline-style saturation harness
  (``serve/offline.py``): every request available at t=0, length-
  bucketed single-call prefill (``--buckets``), a background completion
  pump overlapping host work with device decode (``--no-overlap``
  measures the synchronous baseline), ``--replicas`` data-parallel
  engines behind one shared admission queue, and wall-clock TTFT /
  latency / tok/s / tok/s-per-chip stats with a steady-state
  zero-retrace assertion.
* ``loadgen`` — the closed-loop QPS search (``serve/loadgen.py``):
  binary-searches the max sustainable offered QPS whose measured phase
  meets the TTFT/latency SLO (``--slo-ttft-ms/--slo-p99-ms``), between
  ``--qps-lo`` and ``--qps-hi``; the report carries every phase plus an
  SLO-pass attestation of the best passing phase.

``--profile-start-step/--profile-steps`` capture a JAX profiler trace
of that window of driver steps (decode ticks in ``sim``, loop
iterations in ``offline``/``loadgen``) into the report directory.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from collections import Counter

import numpy as np

import jax

import repro  # noqa: F401
from repro.configs import get_config
from repro.launch.profiling import ProfilerWindow
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.crypto import CryptoRequest
from repro.serve.offline import (
    OfflineInference, pow2_buckets, sample_stats,
)
from repro.serve.scheduler import Request

FAMILIES = ("llm", "crypto")


def _bigint(v) -> int:
    """JSON big ints arrive as ints or as strings ("0x..", "0o..", "123")
    — ``int(s, 0)`` accepts all of them; floats are refused (lossy)."""
    if isinstance(v, bool) or isinstance(v, float):
        raise ValueError(f"big-int field must be an int or string, "
                         f"got {v!r}")
    return int(v, 0) if isinstance(v, str) else int(v)


def load_trace(path: str, rng, vocab: int) -> list:
    """Parse a JSONL workload file into Request/CryptoRequest objects
    (see module docstring).  Rid uniqueness is enforced ACROSS families:
    the engine's verify log is one rid-keyed dict shared by both lanes."""
    reqs = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            family = d.get("family", "llm")
            if family == "crypto":
                reqs.append(CryptoRequest(
                    rid=int(d.get("rid", i)), op=str(d["op"]),
                    a=_bigint(d["a"]), b=_bigint(d["b"]),
                    n=_bigint(d["n"]) if d.get("n") is not None else None,
                    arrival=float(d.get("arrival", 0.0)),
                ))
                continue
            if family != "llm":
                raise ValueError(
                    f"workload file {path} line {i + 1}: unknown family "
                    f"{family!r}; expected one of {FAMILIES}")
            prompt = d.get("prompt")
            if prompt is None:
                plen = int(d["prompt_len"])
                prompt = [int(t) for t in rng.integers(1, vocab, plen)]
            reqs.append(Request(
                rid=int(d.get("rid", i)), prompt=[int(t) for t in prompt],
                max_new=int(d["max_new"]), eos=d.get("eos"),
                arrival=float(d.get("arrival", 0.0)),
            ))
    if not reqs:
        raise ValueError(f"workload file {path} holds no requests")
    counts = Counter(r.rid for r in reqs)
    dups = sorted(r for r, n in counts.items() if n > 1)
    if dups:
        # the engine keys per-request verify state on rid, shared across
        # families — a crypto and an LLM request may NOT share a rid
        raise ValueError(f"workload file {path}: duplicate rids {dups} "
                         f"(rids are unique across families)")
    return reqs


def synth_requests(n: int, rng, vocab: int, *, prompt_mean: int,
                   max_new: int, arrival_rate: float) -> list:
    """Synthetic workload: geometric-ish prompt lengths around
    ``prompt_mean`` and Poisson arrivals at ``arrival_rate`` requests per
    decode-step tick (rate 0 = everything arrives at t=0)."""
    t = 0.0
    reqs = []
    for i in range(n):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        plen = max(1, int(rng.poisson(prompt_mean)))
        reqs.append(Request(
            rid=i, prompt=[int(x) for x in rng.integers(1, vocab, plen)],
            max_new=max_new, arrival=t,
        ))
    return reqs


def synth_crypto_requests(n: int, rng, ctx, *, arrival_rate: float,
                          rid0: int) -> list:
    """Synthetic crypto workload over ``ctx``'s bases: modexp / modmul /
    divmod round-robin, operands drawn uniformly below the relevant bound
    (random odd moduli coprime to both base products — no special forms),
    Poisson arrivals like ``synth_requests``."""
    MMp = ctx.baseB.M * ctx.baseBp.M

    def below(lim: int) -> int:
        # rng.integers tops out at int64; big ints come from raw bytes
        nb = (int(lim).bit_length() + 7) // 8 + 1
        while True:
            v = int.from_bytes(rng.bytes(nb), "little")
            if v < lim:
                return v

    def modulus() -> int:
        while True:
            N = below(ctx.n_max) | 1
            if N > 4 and math.gcd(N, MMp) == 1:
                return N

    t, reqs = 0.0, []
    for i in range(n):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        op = ("modexp", "modmul", "divmod")[i % 3]
        if op == "divmod":
            a, b, N = below(ctx.baseB.M), 1 + below(ctx.baseB.M - 1), None
        else:
            N = modulus()
            a = below(N)
            b = below(1 << ctx.exp_bits) if op == "modexp" else below(N)
        reqs.append(CryptoRequest(rid=rid0 + i, op=op, a=a, b=b, n=N,
                                  arrival=t))
    return reqs


def save_trace(path: str, reqs: list) -> None:
    with open(path, "w") as f:
        for r in reqs:
            if getattr(r, "family", "llm") == "crypto":
                d = {"rid": r.rid, "family": "crypto", "op": r.op,
                     "a": hex(r.a), "b": hex(r.b), "arrival": r.arrival}
                if r.n is not None:
                    d["n"] = hex(r.n)
            else:
                d = {"rid": r.rid, "prompt": r.prompt,
                     "max_new": r.max_new, "eos": r.eos,
                     "arrival": r.arrival}
            f.write(json.dumps(d) + "\n")


def _stats(xs: list) -> dict:
    """Latency summary; an empty sample (a family filter can leave zero
    completions) returns the explicit ``n: 0`` record instead of
    crashing percentile on ``[]``."""
    return sample_stats(xs)


def simulate_single_shot(cfg, params, reqs: list, rng) -> tuple:
    """Sequential one-request-at-a-time serving for the families the
    continuous batcher gates out (ssm/hybrid/encdec/vlm) — the legacy
    prefill + scalar-position decode loop, kept so every family in the
    zoo stays servable.  One prefill trace per distinct prompt length
    (no chunking); the tick clock counts one tick per generated token.
    Returns (completed requests, counters) like ``simulate``."""
    import jax.numpy as jnp

    from repro.models import decode_step, prefill

    prefill_fn = jax.jit(
        lambda p, b, L: prefill(cfg, p, b, L), static_argnums=2
    )
    decode_fn = jax.jit(
        lambda p, c, tok, pos: decode_step(cfg, p, c, tok, pos)
    )
    t, steps = 0.0, 0
    for r in sorted(reqs, key=lambda q: q.arrival):
        t = max(t, r.arrival)
        r.t_admit = t
        # vlm prefill prepends n_patches patch embeddings to the sequence,
        # so the cache must hold them on top of prompt + generated tokens
        cache_len = len(r.prompt) + r.max_new + (
            cfg.n_patches if cfg.family == "vlm" else 0
        )
        batch = {"tokens": jnp.asarray([r.prompt], jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (1, cfg.n_patches, cfg.d_model)), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (1, cfg.enc_frames, cfg.d_model)), jnp.float32)
        logits, cache = prefill_fn(params, batch, cache_len)
        tok = int(jnp.argmax(logits[0]))
        t += 1.0
        steps += 1
        r.out.append(tok)
        r.t_first = t
        base = len(r.prompt) + (cfg.n_patches if cfg.family == "vlm" else 0)
        i = 0
        while len(r.out) < r.max_new and not (
            r.eos is not None and tok == r.eos
        ):
            lg, cache = decode_fn(
                params, cache, jnp.asarray([[tok]], jnp.int32),
                jnp.int32(base + i),
            )
            tok = int(jnp.argmax(lg[0]))
            r.out.append(tok)
            t += 1.0
            steps += 1
            i += 1
        r.t_done = t
    return sorted(reqs, key=lambda q: q.rid), \
        {"steps": steps, "max_concurrency": 1}


def simulate(engine: ContinuousBatcher, reqs: list,
             on_step=None) -> dict:
    """Run the arrival/admission/decode loop to completion; returns the
    tick-clock counters (requests stamp their own t_* fields).
    ``on_step`` fires once per decode tick (profiler hook)."""
    reqs = sorted(reqs, key=lambda r: r.arrival)
    t, i, steps, max_conc = 0.0, 0, 0, 0
    while i < len(reqs) or engine.busy:
        while i < len(reqs) and reqs[i].arrival <= t:
            engine.submit(reqs[i])
            i += 1
        engine.try_admit(now=t)
        decoding = engine.sched.decoding_slots()
        laddering = (engine.crypto.running_slots()
                     if engine.crypto is not None else [])
        if decoding or laddering:
            max_conc = max(max_conc, len(decoding) + len(laddering))
            if on_step is not None:
                on_step()
            engine.step(now=t)
            t += 1.0
            steps += 1
        elif i < len(reqs):
            t = math.ceil(reqs[i].arrival)  # idle: fast-forward the clock
    return {"steps": steps, "max_concurrency": max_conc}


def _crypto_report(crypto_done: list, ctx, *, clock_key: str) -> dict:
    """Crypto block of the report: every result is differentially
    checkable against Python's big ints, so the oracle check runs
    inline; ``clock_key`` names the timebase (ticks in sim mode, wall
    seconds in offline mode)."""
    ok = 0
    for r in crypto_done:
        want = (divmod(r.a, r.b) if r.op == "divmod"
                else pow(r.a % r.n, r.b, r.n) if r.op == "modexp"
                else (r.a * r.b) % r.n)
        ok += int(r.result == want)
    return {
        "requests": len(crypto_done),
        "ops": dict(Counter(r.op for r in crypto_done)),
        "range_bits": ctx.baseB.M.bit_length(),
        "exp_bits": ctx.exp_bits,
        "oracle_ok": ok,
        "oracle_failed": len(crypto_done) - ok,
        clock_key: _stats([r.t_done - r.arrival for r in crypto_done]),
    }


def _parse_buckets(spec: str, cache_len: int, ap) -> tuple | None:
    if spec == "none":
        return None
    if spec == "pow2":
        return pow2_buckets(cache_len)
    try:
        buckets = tuple(int(b) for b in spec.split(","))
    except ValueError:
        ap.error(f"--buckets takes 'pow2', 'none', or a comma list of "
                 f"ints; got {spec!r}")
    return buckets


def _offline_main(args, ap, cfg, params, reqs, crypto_ctx, rng,
                  window) -> dict:
    """``--mode offline|loadgen``: the wall-clock saturation harness
    (DESIGN.md §16) instead of the tick-clock replay."""
    buckets = _parse_buckets(args.buckets, args.cache_len, ap)
    try:
        harness = OfflineInference(
            cfg, params, n_slots=args.slots, cache_len=args.cache_len,
            prefill_chunk=args.prefill_chunk, buckets=buckets,
            replicas=args.replicas, overlap=args.overlap,
            queue_size=args.queue_size, rns_verify=args.rns_verify,
            page_size=args.page_size, n_pages=args.pages,
            prefix_share=args.prefix_share,
            crypto_slots=args.crypto_slots, crypto_ctx=crypto_ctx,
            crypto_chunk=args.crypto_chunk,
        )
    except NotImplementedError as err:
        ap.error(f"--mode {args.mode} needs the continuous-batching "
                 f"engine for {cfg.name}: {err}")
    warm = harness.warmup()
    print(f"# warmup: {len(warm['warmed_plens'])} prefill width(s) x "
          f"{warm['replicas']} replica(s) compiled: {warm['jit_traces']}")
    harness.on_step = window.step
    report = {
        "arch": cfg.name,
        "mode": args.mode,
        "engine": "offline-harness",
        "n_slots": args.slots,
        "cache_len": args.cache_len,
        "warmup": warm,
    }
    try:
        if args.mode == "offline":
            for r in reqs:
                r.arrival = 0.0  # offline scenario: all available at t=0
            report.update(harness.run(reqs))
            harness.require_steady_state()
            crypto_done = [r for r, _ in harness.completions
                           if getattr(r, "family", "llm") == "crypto"]
            if crypto_done:
                report["crypto"] = _crypto_report(
                    crypto_done, harness.engines[0].crypto_ctx,
                    clock_key="latency_s")
            if args.rns_verify:
                report["rns"] = {
                    "slots_verified": harness.replica_set.verify_ok,
                    "slots_failed": harness.replica_set.verify_failed,
                }
        else:
            from repro.serve.loadgen import (
                SLO, poisson_requests, search_max_qps,
            )

            slo = SLO(ttft_p99_s=args.slo_ttft_ms / 1e3,
                      latency_p99_s=args.slo_p99_ms / 1e3)
            rid_counter = [0]

            def make_requests(n, qps):
                rid0 = rid_counter[0]
                rid_counter[0] += n
                return poisson_requests(
                    n, qps, rng, vocab=cfg.vocab,
                    prompt_mean=args.prompt_mean, max_new=args.max_new,
                    cache_len=args.cache_len, rid0=rid0,
                )

            out = search_max_qps(
                harness, make_requests, slo, qps_lo=args.qps_lo,
                qps_hi=args.qps_hi, iters=args.qps_iters,
                phase_requests=args.phase_requests,
            )
            harness.require_steady_state()
            report.update(out)
            print(f"# loadgen: {out['note']}")
    finally:
        window.close()
    if window.enabled:
        report["profile"] = {"artifact": window.artifact,
                             "captured_steps": window.captured}
    print(json.dumps(report, indent=1))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote report to {args.report}")
    return report


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="continuous-batching serve driver (DESIGN.md §12)")
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", dest="smoke", action="store_true",
                    help="shrink the arch to the CPU smoke config "
                         "(the default; see --no-smoke)")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false",
                    help="run the full published config instead of the "
                         "smoke shrink")
    ap.set_defaults(smoke=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent request capacity (batched cache rows)")
    ap.add_argument("--cache-len", type=int, default=128,
                    help="per-slot KV capacity (prompt + generated)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=None,
                    help="switch to the paged pool layout with pages of "
                         "this many tokens (DESIGN.md §13)")
    ap.add_argument("--pages", type=int, default=None,
                    help="physical pages in the pool (default: full "
                         "backing for every slot plus the parking page)")
    ap.add_argument("--no-prefix-share", dest="prefix_share",
                    action="store_false",
                    help="disable admission-time prompt-prefix dedup "
                         "(paged mode; measures pure paging)")
    ap.set_defaults(prefix_share=True)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic workload size (ignored with --trace)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="replay a JSONL workload file instead")
    ap.add_argument("--families", default=None, metavar="F1,F2",
                    help="replay filter: keep only these request families "
                         f"(subset of {','.join(FAMILIES)})")
    ap.add_argument("--crypto-slots", type=int, default=0,
                    help="slots of the big-integer crypto lane "
                         "(DESIGN.md §15); 0 disables the family")
    ap.add_argument("--crypto-requests", type=int, default=0,
                    help="synthetic crypto requests appended to the "
                         "workload (needs --crypto-slots; ignored with "
                         "--trace)")
    ap.add_argument("--crypto-limbs", type=int, default=8,
                    help="15-bit channels per Montgomery base")
    ap.add_argument("--crypto-exp-bits", type=int, default=32,
                    help="fixed ladder width (max exponent bits)")
    ap.add_argument("--crypto-chunk", type=int, default=8,
                    help="ladder bits per engine tick (divides exp bits)")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="Poisson arrivals per decode-step tick (synthetic)")
    ap.add_argument("--prompt-mean", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rns-verify", action="store_true",
                    help="RnsArray cache-integrity fingerprints per slot")
    ap.add_argument("--inject-wire-corrupt", action="store_true",
                    help="with --rns-verify: corrupt one stored wire "
                         "buffer post-run and show detect/repair/re-verify")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the report dict as JSON")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the workload as a replayable JSONL trace")
    ap.add_argument("--warm-restart", default=None, metavar="DIR",
                    help="warm-restart state dir (needs --page-size and "
                         "--rns-verify): restore + revalidate the previous "
                         "run's retained prefix pages before serving, and "
                         "persist this run's pool state there afterwards "
                         "(DESIGN.md §14)")
    ap.add_argument("--mode", choices=("sim", "offline", "loadgen"),
                    default="sim",
                    help="sim: deterministic tick-clock replay (default); "
                         "offline: wall-clock saturation harness; loadgen: "
                         "closed-loop max-QPS search (DESIGN.md §16)")
    ap.add_argument("--buckets", default="pow2", metavar="SPEC",
                    help="offline prefill buckets: 'pow2' (power-of-two "
                         "ladder up to cache-len, the default), 'none' "
                         "(chunked prefill), or a comma list like "
                         "'32,64,128'; composes with --page-size (padded "
                         "write barrier through the page table)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind one shared "
                         "admission queue (offline/loadgen)")
    ap.add_argument("--queue-size", type=int, default=64,
                    help="bound of the completion pump's queue "
                         "(backpressure depth)")
    ap.add_argument("--no-overlap", dest="overlap", action="store_false",
                    help="run completion callbacks inline on the driver "
                         "thread — the synchronous baseline the overlap "
                         "ratio is measured against")
    ap.set_defaults(overlap=True)
    ap.add_argument("--qps-lo", type=float, default=0.5,
                    help="loadgen search floor (offered QPS)")
    ap.add_argument("--qps-hi", type=float, default=64.0,
                    help="loadgen search ceiling (offered QPS)")
    ap.add_argument("--qps-iters", type=int, default=4,
                    help="loadgen bisections after the bracket probes")
    ap.add_argument("--phase-requests", type=int, default=16,
                    help="requests per measured loadgen phase")
    ap.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="SLO: TTFT p99 bound (milliseconds)")
    ap.add_argument("--slo-p99-ms", type=float, default=10000.0,
                    help="SLO: end-to-end latency p99 bound (ms)")
    ap.add_argument("--profile-start-step", type=int, default=-1,
                    metavar="N",
                    help="driver step at which to start a JAX profiler "
                         "trace (-1 disables; a step is a decode tick in "
                         "sim, a loop iteration in offline/loadgen)")
    ap.add_argument("--profile-steps", type=int, default=0, metavar="N",
                    help="driver steps to capture in the profiler window")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="profiler artifact directory (default: the "
                         "--report directory, else '.')")
    args = ap.parse_args(argv)
    if args.warm_restart and (args.page_size is None or not args.rns_verify
                              or not args.prefix_share):
        ap.error("--warm-restart needs --page-size, --rns-verify, and "
                 "prefix sharing (the persisted state IS the retained "
                 "pages plus their RRNS fingerprints)")
    if args.mode != "sim":
        # --page-size composes with --buckets here (the padded write
        # barrier, DESIGN.md §13); only the sim-flavored extras stay out
        bad = [f for f, v in (
            ("--warm-restart", bool(args.warm_restart)),
            ("--inject-wire-corrupt", args.inject_wire_corrupt),
        ) if v]
        if bad:
            ap.error(f"--mode {args.mode} drives the wall-clock harness; "
                     f"drop {', '.join(bad)}")
    if args.mode == "loadgen" and (args.trace or args.crypto_requests
                                   or args.crypto_slots):
        ap.error("--mode loadgen synthesizes its own Poisson LLM phases; "
                 "drop --trace / --crypto-*")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg.validate()
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.key(args.seed))
    crypto_ctx = None
    if args.crypto_slots:
        from repro.serve.crypto import CryptoContext

        crypto_ctx = CryptoContext(n_limbs=args.crypto_limbs,
                                   exp_bits=args.crypto_exp_bits)
    if args.trace:
        reqs = load_trace(args.trace, rng, cfg.vocab)
    else:
        reqs = synth_requests(
            args.requests, rng, cfg.vocab, prompt_mean=args.prompt_mean,
            max_new=args.max_new, arrival_rate=args.arrival_rate,
        )
        if args.crypto_requests:
            if crypto_ctx is None:
                ap.error("--crypto-requests needs --crypto-slots >= 1")
            rid0 = 1 + max((r.rid for r in reqs), default=-1)
            reqs += synth_crypto_requests(
                args.crypto_requests, rng, crypto_ctx,
                arrival_rate=args.arrival_rate, rid0=rid0,
            )
    if args.families is not None:
        keep = {f.strip() for f in args.families.split(",") if f.strip()}
        unknown = keep - set(FAMILIES)
        if unknown or not keep:
            ap.error(f"--families takes a non-empty subset of "
                     f"{','.join(FAMILIES)}; got {args.families!r}")
        reqs = [r for r in reqs if getattr(r, "family", "llm") in keep]
        if not reqs:
            ap.error(f"--families {args.families} filtered out every "
                     f"request in the workload")
    if args.save_trace:
        save_trace(args.save_trace, reqs)
    if any(getattr(r, "family", "llm") == "crypto" for r in reqs) \
            and crypto_ctx is None:
        ap.error("the workload holds crypto-family requests; pass "
                 "--crypto-slots >= 1 to arm the crypto lane (or filter "
                 "them out with --families llm)")

    profdir = args.profile_dir or (
        os.path.dirname(os.path.abspath(args.report)) if args.report
        else "."
    )
    window = ProfilerWindow(args.profile_start_step, args.profile_steps,
                            profdir, label=f"serve_{args.mode}")
    if args.mode != "sim":
        return _offline_main(args, ap, cfg, params, reqs, crypto_ctx,
                             rng, window)

    try:
        engine = ContinuousBatcher(
            cfg, params, n_slots=args.slots, cache_len=args.cache_len,
            prefill_chunk=args.prefill_chunk, rns_verify=args.rns_verify,
            page_size=args.page_size, n_pages=args.pages,
            prefix_share=args.prefix_share,
            crypto_slots=args.crypto_slots, crypto_ctx=crypto_ctx,
            crypto_chunk=args.crypto_chunk,
        )
    except NotImplementedError as err:
        if args.rns_verify:
            raise  # the integrity path needs the slot engine
        if crypto_ctx is not None:
            raise  # so does the crypto lane (no single-shot crypto path)
        print(f"# {cfg.name}: {err}")
        print("# falling back to single-shot sequential serving")
        engine = None
    warm = None
    if args.warm_restart and engine is not None:
        try:
            warm = dict(engine.load_warm_state(args.warm_restart),
                        restored=True)
            print(f"# warm restart: adopted {warm['adopted']} of "
                  f"{warm['pages_saved']} persisted page(s), "
                  f"repaired {warm['repaired_pages']}, "
                  f"dropped {warm['dropped']}")
        except FileNotFoundError:
            warm = {"restored": False}  # first run: nothing saved yet
            print(f"# warm restart: no state under {args.warm_restart} "
                  f"yet (cold start)")
    t0 = time.time()
    crypto_done = []
    try:
        if engine is not None:
            counters = simulate(engine, reqs, on_step=window.step)
            done = engine.sched.completed
            if engine.crypto is not None:
                crypto_done = engine.crypto.completed
        else:
            done, counters = simulate_single_shot(cfg, params, reqs, rng)
    finally:
        window.close()
    wall = time.time() - t0

    toks = sum(len(r.out) for r in done)
    report = {
        "arch": cfg.name,
        "engine": "continuous" if engine is not None else "single-shot",
        "n_slots": args.slots if engine is not None else 1,
        "cache_len": args.cache_len,
        "requests": len(done) + len(crypto_done),
        "tokens_out": toks,
        "steps": counters["steps"],
        "max_concurrency": counters["max_concurrency"],
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else 0.0,
        "ttft_ticks": _stats([r.t_first - r.arrival for r in done]),
        "latency_ticks": _stats([r.t_done - r.arrival for r in done]),
    }
    if engine is not None:
        report["jit_traces"] = engine.jit_cache_sizes()
        if engine.paged:
            report["paging"] = engine.page_stats()
    if crypto_done:
        report["crypto"] = _crypto_report(
            crypto_done, engine.crypto_ctx, clock_key="latency_ticks")
    if window.enabled:
        report["profile"] = {"artifact": window.artifact,
                             "captured_steps": window.captured}
    if args.rns_verify:
        # wire keys: rids on the monolithic path (one per retired request,
        # still stored), page ids on the paged path (only RETAINED shared
        # pages outlive their readers — freed pages verified at release);
        # crypto modexps add ("crypto", rid) keys (one-shots publish none)
        keys = (sorted(k for k in engine.wire.keys()
                       if not isinstance(k, tuple)) if engine.paged
                else [r.rid for r in done])
        keys = keys + [("crypto", r.rid) for r in crypto_done
                       if ("crypto", r.rid) in engine.wire]
        rns = {
            "slots_verified": sum(engine.verify_log.values()),
            "slots_failed": sum(not v for v in engine.verify_log.values()),
            "wire_ok": sum(engine.wire_ok(k) for k in keys),
        }
        if args.inject_wire_corrupt and keys:
            key = keys[0]
            engine.corrupt_wire(key, channel=1, delta=3)
            rns["injected_detected"] = not engine.wire_ok(key)
            rns["injected_repair"] = engine.repair_wire(key)
            rns["injected_reverified"] = engine.wire_ok(key)
        report["rns"] = rns

    if args.warm_restart and engine is not None:
        engine.drain_completed()  # idle the engine before snapshotting
        saved = engine.save_warm_state(args.warm_restart)
        report["warm_restart"] = dict(warm or {}, **saved)
        print(f"# warm restart: persisted {saved['pages_saved']} retained "
              f"page(s) to {args.warm_restart}")

    print(json.dumps(report, indent=1))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote report to {args.report}")
    return report


if __name__ == "__main__":
    main()
