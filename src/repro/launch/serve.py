"""Serving driver: prefill a batched prompt, decode tokens, report rates.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --prompt 32 --decode 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg.validate()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt), dtype=np.int32))}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_frames, cfg.d_model)),
            jnp.float32)

    cache_len = args.prompt + args.decode + (
        cfg.n_patches if cfg.family == "vlm" else 0)
    prefill_fn = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len))
    decode_fn = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))

    t0 = time.time()
    logits, cache = prefill_fn(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt} in {t_prefill*1e3:.0f}ms "
          f"({args.batch*args.prompt/t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    base_pos = args.prompt + (cfg.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    for i in range(args.decode):
        logits, cache = decode_fn(params, cache, tok, jnp.int32(base_pos + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decode: {args.decode} steps in {t_dec*1e3:.0f}ms "
          f"({args.batch*args.decode/t_dec:.1f} tok/s)")
    print("sampled token ids (greedy):", toks[0][:12], "...")
    return toks


if __name__ == "__main__":
    main()
