"""Continuous-batching serving driver (DESIGN.md §12).

Drives ``repro.serve.ContinuousBatcher`` over a request workload — either
synthetic (``--requests N`` with Poisson arrivals) or replayed from a
workload file (``--trace FILE``) — and reports latency/throughput.  The
simulation clock is DECODE-STEP TICKS (one persistent batched decode step
per tick), so every latency number is deterministic for a given seed;
wall-clock throughput is reported separately.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 8 --slots 4 --arrival-rate 0.5 --report serve_report.json

Workload file format (JSON lines, one request per line)::

    {"rid": 0, "prompt": [3, 1, 4], "max_new": 16, "eos": 7, "arrival": 0.0}

``prompt`` may be replaced by ``"prompt_len": N`` to synthesize N random
token ids from ``--seed``.  ``--save-trace`` writes the (possibly
synthetic) workload back out in this format so a run is replayable.

Smoke flags: ``--smoke`` (the DEFAULT: shrink the arch to the CPU-sized
config) and ``--no-smoke`` (run the full published config) are an explicit
pair over one setting — exactly one applies, and the help text of each
names the default.

``--page-size N`` switches the engine onto the paged, prefix-sharing pool
layout (DESIGN.md §13; ``--pages`` sizes the pool, ``--no-prefix-share``
disables admission dedup) and adds a ``paging`` block to the report:
pages in use / shared (dedup hits) / CoW copies, and the per-page
fingerprint verify/repair counters under ``--rns-verify``.

``--rns-verify`` arms the engine's RnsArray cache-integrity fingerprints
(verified at every retirement); ``--inject-wire-corrupt`` additionally
corrupts one stored wire buffer after the run and demonstrates the
detect -> ``repair_packed`` -> re-verify loop in the report.

Families the batcher gates out (ssm/hybrid/encdec/vlm) fall back to a
single-shot sequential loop (``report["engine"] == "single-shot"``) so
every arch in the zoo stays servable; ``--rns-verify`` requires the slot
engine and raises for them.
"""
from __future__ import annotations

import argparse
import json
import math
import time
from collections import Counter

import numpy as np

import jax

import repro  # noqa: F401
from repro.configs import get_config
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.scheduler import Request


def load_trace(path: str, rng, vocab: int) -> list:
    """Parse a JSONL workload file into Requests (see module docstring)."""
    reqs = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            prompt = d.get("prompt")
            if prompt is None:
                plen = int(d["prompt_len"])
                prompt = [int(t) for t in rng.integers(1, vocab, plen)]
            reqs.append(Request(
                rid=int(d.get("rid", i)), prompt=[int(t) for t in prompt],
                max_new=int(d["max_new"]), eos=d.get("eos"),
                arrival=float(d.get("arrival", 0.0)),
            ))
    if not reqs:
        raise ValueError(f"workload file {path} holds no requests")
    counts = Counter(r.rid for r in reqs)
    dups = sorted(r for r, n in counts.items() if n > 1)
    if dups:
        # the engine keys per-request verify state on rid
        raise ValueError(f"workload file {path}: duplicate rids {dups}")
    return reqs


def synth_requests(n: int, rng, vocab: int, *, prompt_mean: int,
                   max_new: int, arrival_rate: float) -> list:
    """Synthetic workload: geometric-ish prompt lengths around
    ``prompt_mean`` and Poisson arrivals at ``arrival_rate`` requests per
    decode-step tick (rate 0 = everything arrives at t=0)."""
    t = 0.0
    reqs = []
    for i in range(n):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        plen = max(1, int(rng.poisson(prompt_mean)))
        reqs.append(Request(
            rid=i, prompt=[int(x) for x in rng.integers(1, vocab, plen)],
            max_new=max_new, arrival=t,
        ))
    return reqs


def save_trace(path: str, reqs: list) -> None:
    with open(path, "w") as f:
        for r in reqs:
            f.write(json.dumps({
                "rid": r.rid, "prompt": r.prompt, "max_new": r.max_new,
                "eos": r.eos, "arrival": r.arrival,
            }) + "\n")


def _stats(xs: list) -> dict:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95))}


def simulate_single_shot(cfg, params, reqs: list, rng) -> tuple:
    """Sequential one-request-at-a-time serving for the families the
    continuous batcher gates out (ssm/hybrid/encdec/vlm) — the legacy
    prefill + scalar-position decode loop, kept so every family in the
    zoo stays servable.  One prefill trace per distinct prompt length
    (no chunking); the tick clock counts one tick per generated token.
    Returns (completed requests, counters) like ``simulate``."""
    import jax.numpy as jnp

    from repro.models import decode_step, prefill

    prefill_fn = jax.jit(
        lambda p, b, L: prefill(cfg, p, b, L), static_argnums=2
    )
    decode_fn = jax.jit(
        lambda p, c, tok, pos: decode_step(cfg, p, c, tok, pos)
    )
    t, steps = 0.0, 0
    for r in sorted(reqs, key=lambda q: q.arrival):
        t = max(t, r.arrival)
        r.t_admit = t
        # vlm prefill prepends n_patches patch embeddings to the sequence,
        # so the cache must hold them on top of prompt + generated tokens
        cache_len = len(r.prompt) + r.max_new + (
            cfg.n_patches if cfg.family == "vlm" else 0
        )
        batch = {"tokens": jnp.asarray([r.prompt], jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (1, cfg.n_patches, cfg.d_model)), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (1, cfg.enc_frames, cfg.d_model)), jnp.float32)
        logits, cache = prefill_fn(params, batch, cache_len)
        tok = int(jnp.argmax(logits[0]))
        t += 1.0
        steps += 1
        r.out.append(tok)
        r.t_first = t
        base = len(r.prompt) + (cfg.n_patches if cfg.family == "vlm" else 0)
        i = 0
        while len(r.out) < r.max_new and not (
            r.eos is not None and tok == r.eos
        ):
            lg, cache = decode_fn(
                params, cache, jnp.asarray([[tok]], jnp.int32),
                jnp.int32(base + i),
            )
            tok = int(jnp.argmax(lg[0]))
            r.out.append(tok)
            t += 1.0
            steps += 1
            i += 1
        r.t_done = t
    return sorted(reqs, key=lambda q: q.rid), \
        {"steps": steps, "max_concurrency": 1}


def simulate(engine: ContinuousBatcher, reqs: list) -> dict:
    """Run the arrival/admission/decode loop to completion; returns the
    tick-clock counters (requests stamp their own t_* fields)."""
    reqs = sorted(reqs, key=lambda r: r.arrival)
    t, i, steps, max_conc = 0.0, 0, 0, 0
    while i < len(reqs) or engine.sched.busy:
        while i < len(reqs) and reqs[i].arrival <= t:
            engine.submit(reqs[i])
            i += 1
        engine.try_admit(now=t)
        decoding = engine.sched.decoding_slots()
        if decoding:
            max_conc = max(max_conc, len(decoding))
            engine.step(now=t)
            t += 1.0
            steps += 1
        elif i < len(reqs):
            t = math.ceil(reqs[i].arrival)  # idle: fast-forward the clock
    return {"steps": steps, "max_concurrency": max_conc}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="continuous-batching serve driver (DESIGN.md §12)")
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", dest="smoke", action="store_true",
                    help="shrink the arch to the CPU smoke config "
                         "(the default; see --no-smoke)")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false",
                    help="run the full published config instead of the "
                         "smoke shrink")
    ap.set_defaults(smoke=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent request capacity (batched cache rows)")
    ap.add_argument("--cache-len", type=int, default=128,
                    help="per-slot KV capacity (prompt + generated)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=None,
                    help="switch to the paged pool layout with pages of "
                         "this many tokens (DESIGN.md §13)")
    ap.add_argument("--pages", type=int, default=None,
                    help="physical pages in the pool (default: full "
                         "backing for every slot plus the parking page)")
    ap.add_argument("--no-prefix-share", dest="prefix_share",
                    action="store_false",
                    help="disable admission-time prompt-prefix dedup "
                         "(paged mode; measures pure paging)")
    ap.set_defaults(prefix_share=True)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic workload size (ignored with --trace)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="replay a JSONL workload file instead")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="Poisson arrivals per decode-step tick (synthetic)")
    ap.add_argument("--prompt-mean", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rns-verify", action="store_true",
                    help="RnsArray cache-integrity fingerprints per slot")
    ap.add_argument("--inject-wire-corrupt", action="store_true",
                    help="with --rns-verify: corrupt one stored wire "
                         "buffer post-run and show detect/repair/re-verify")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the report dict as JSON")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the workload as a replayable JSONL trace")
    ap.add_argument("--warm-restart", default=None, metavar="DIR",
                    help="warm-restart state dir (needs --page-size and "
                         "--rns-verify): restore + revalidate the previous "
                         "run's retained prefix pages before serving, and "
                         "persist this run's pool state there afterwards "
                         "(DESIGN.md §14)")
    args = ap.parse_args(argv)
    if args.warm_restart and (args.page_size is None or not args.rns_verify
                              or not args.prefix_share):
        ap.error("--warm-restart needs --page-size, --rns-verify, and "
                 "prefix sharing (the persisted state IS the retained "
                 "pages plus their RRNS fingerprints)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg.validate()
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.key(args.seed))
    if args.trace:
        reqs = load_trace(args.trace, rng, cfg.vocab)
    else:
        reqs = synth_requests(
            args.requests, rng, cfg.vocab, prompt_mean=args.prompt_mean,
            max_new=args.max_new, arrival_rate=args.arrival_rate,
        )
    if args.save_trace:
        save_trace(args.save_trace, reqs)

    try:
        engine = ContinuousBatcher(
            cfg, params, n_slots=args.slots, cache_len=args.cache_len,
            prefill_chunk=args.prefill_chunk, rns_verify=args.rns_verify,
            page_size=args.page_size, n_pages=args.pages,
            prefix_share=args.prefix_share,
        )
    except NotImplementedError as err:
        if args.rns_verify:
            raise  # the integrity path needs the slot engine
        print(f"# {cfg.name}: {err}")
        print("# falling back to single-shot sequential serving")
        engine = None
    warm = None
    if args.warm_restart and engine is not None:
        try:
            warm = dict(engine.load_warm_state(args.warm_restart),
                        restored=True)
            print(f"# warm restart: adopted {warm['adopted']} of "
                  f"{warm['pages_saved']} persisted page(s), "
                  f"repaired {warm['repaired_pages']}, "
                  f"dropped {warm['dropped']}")
        except FileNotFoundError:
            warm = {"restored": False}  # first run: nothing saved yet
            print(f"# warm restart: no state under {args.warm_restart} "
                  f"yet (cold start)")
    t0 = time.time()
    if engine is not None:
        counters = simulate(engine, reqs)
        done = engine.sched.completed
    else:
        done, counters = simulate_single_shot(cfg, params, reqs, rng)
    wall = time.time() - t0

    toks = sum(len(r.out) for r in done)
    report = {
        "arch": cfg.name,
        "engine": "continuous" if engine is not None else "single-shot",
        "n_slots": args.slots if engine is not None else 1,
        "cache_len": args.cache_len,
        "requests": len(done),
        "tokens_out": toks,
        "steps": counters["steps"],
        "max_concurrency": counters["max_concurrency"],
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1) if wall > 0 else 0.0,
        "ttft_ticks": _stats([r.t_first - r.arrival for r in done]),
        "latency_ticks": _stats([r.t_done - r.arrival for r in done]),
    }
    if engine is not None:
        report["jit_traces"] = engine.jit_cache_sizes()
        if engine.paged:
            report["paging"] = engine.page_stats()
    if args.rns_verify:
        # wire keys: rids on the monolithic path (one per retired request,
        # still stored), page ids on the paged path (only RETAINED shared
        # pages outlive their readers — freed pages verified at release)
        keys = (sorted(engine.wire.keys()) if engine.paged
                else [r.rid for r in done])
        rns = {
            "slots_verified": sum(engine.verify_log.values()),
            "slots_failed": sum(not v for v in engine.verify_log.values()),
            "wire_ok": sum(engine.wire_ok(k) for k in keys),
        }
        if args.inject_wire_corrupt and keys:
            key = keys[0]
            engine.corrupt_wire(key, channel=1, delta=3)
            rns["injected_detected"] = not engine.wire_ok(key)
            rns["injected_repair"] = engine.repair_wire(key)
            rns["injected_reverified"] = engine.wire_ok(key)
        report["rns"] = rns

    if args.warm_restart and engine is not None:
        engine.drain_completed()  # idle the engine before snapshotting
        saved = engine.save_warm_state(args.warm_restart)
        report["warm_restart"] = dict(warm or {}, **saved)
        print(f"# warm restart: persisted {saved['pages_saved']} retained "
              f"page(s) to {args.warm_restart}")

    print(json.dumps(report, indent=1))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote report to {args.report}")
    return report


if __name__ == "__main__":
    main()
