"""Trip-count-aware cost analysis of compiled (post-SPMD, post-fusion) HLO.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers model (all of ours) under-reports FLOPs / bytes /
collectives by ~the layer count.  This module parses the compiled module
text, builds the computation call graph, extracts while-loop trip counts
(scan loops compare the induction variable against a constant), and
aggregates:

  * flops             — dot ops: 2 * |out| * prod(contracting dims)
  * bytes             — per TOP-LEVEL op: operands + outputs (post-fusion,
                        fusion boundaries ARE the HBM traffic; fusion
                        interiors are traversed for flops only)
  * collective bytes  — per-kind effective wire bytes (see hlo_analysis)

Multipliers: while bodies x trip count, fusion/call bodies x call sites.
Dynamic-bound loops (no comparable constant) fall back to multiplier 1 and
are reported in ``dynamic_loops`` so the caveat is visible per cell.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_module", "ModuleCosts"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# First `word(` token after '=' is the opcode: dtypes are followed by '[',
# layout/comment segments (`{3,2,1,0}`, `/*index=5*/`) contain no `word(`.
_OPCODE_RE = re.compile(r"\b([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_COMP_RE = re.compile(r"(calls|to_apply|body|condition)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(segment: str):
    """First shape's dims in a segment."""
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_segment: str        # text between '=' and opcode (result shapes)
    rest: str               # text from opcode onward (operands + attrs)
    operands: list
    comps: dict             # attr -> computation name


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict            # op name -> shape segment (for operand lookup)
    params: dict            # param name -> shape segment


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header:  %name (p: type[...], ...) -> ... {
        if (stripped.startswith("%") or stripped.startswith("ENTRY")) and stripped.endswith("{"):
            header = stripped
            name = header.split()[1] if header.startswith("ENTRY") else header.split()[0]
            name = name.lstrip("%").split("(")[0].rstrip()
            if header.startswith("ENTRY"):
                name = "ENTRY"
            cur = Computation(name=name, ops=[], shapes={}, params={})
            # parse params from header
            inner = header[header.find("(") + 1 : header.rfind("->")]
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))", inner):
                cur.params[pm.group(1)] = pm.group(2)
                cur.shapes[pm.group(1)] = pm.group(2)
            comps[name] = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        opname, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        out_seg, opcode = rhs[: om.start(1)], om.group(1)
        rest = rhs[om.end(1):]
        if opcode == "parameter":
            cur.shapes[opname] = out_seg
            continue
        operands = _OPERANDS_RE.findall(rest.split(")", 1)[0] + ")")
        attrs = dict()
        for am in _ATTR_COMP_RE.finditer(rest):
            attrs[am.group(1)] = am.group(2)
        cur.shapes[opname] = out_seg
        cur.ops.append(Op(opname, opcode, out_seg, rest, operands, attrs))
    return comps


def _trip_count(cond: Computation) -> int | None:
    """Scan-style loops: max integer constant in the condition computation."""
    consts = []
    for op in cond.ops:
        consts += [int(v) for v in _CONST_RE.findall(op.rest)]
    # also constants folded into compare lines directly
    return max(consts) if consts else None


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    bytes: float
    collectives: dict
    dynamic_loops: int
    while_loops: int


def _dot_flops(op: Op, shapes: dict) -> float:
    out_elems = 1
    dims = _shape_dims(op.out_segment)
    if dims is None:
        return 0.0
    for d in dims:
        out_elems *= d
    # contracting dims from lhs
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    lhs = op.operands[0] if op.operands else None
    k = 1
    if cm and lhs and lhs in shapes:
        lhs_dims = _shape_dims(shapes[lhs])
        if lhs_dims:
            for idx in cm.group(1).split(","):
                if idx:
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _coll_bytes(op: Op) -> tuple[str, float] | None:
    for kind in COLLECTIVES:
        if op.opcode == kind or op.opcode == kind + "-start":
            out_b = _shape_bytes(op.out_segment)
            in_b = _shape_bytes(op.rest.split(")", 1)[0])
            if kind == "all-reduce":
                eff = 2 * out_b
            elif kind == "all-gather":
                eff = out_b
            elif kind == "reduce-scatter":
                eff = in_b
            else:
                eff = max(out_b, in_b)
            return kind, eff
    return None


_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def analyze_module(text: str) -> ModuleCosts:
    comps = parse_module(text)
    memo: dict[tuple, tuple] = {}
    stats = {"dynamic": 0, "whiles": 0}

    def visit(name: str, count_bytes: bool):
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None:
            return 0.0, 0.0, {}
        fl, by, coll = 0.0, 0.0, {}
        for op in comp.ops:
            if op.opcode == "dot":
                fl += _dot_flops(op, comp.shapes)
            cb = _coll_bytes(op)
            if cb:
                coll[cb[0]] = coll.get(cb[0], 0.0) + cb[1]
                coll["ops"] = coll.get("ops", 0.0) + 1
            if count_bytes and op.opcode not in _SKIP_BYTES and not op.opcode.endswith("-done"):
                out_b = _shape_bytes(op.out_segment)
                if op.opcode == "dynamic-update-slice":
                    # in-place update: traffic = update region (read + write)
                    upd = comp.shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
                    by += 2 * _shape_bytes(upd)
                elif op.opcode == "dynamic-slice":
                    by += 2 * out_b  # read region + write result
                else:
                    in_b = sum(
                        _shape_bytes(comp.shapes.get(o, "")) for o in op.operands
                    )
                    by += out_b + in_b
            # recurse
            if op.opcode == "while":
                stats["whiles"] += 1
                body = op.comps.get("body")
                cond = op.comps.get("condition")
                trip = None
                ktc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                if ktc:
                    trip = int(ktc.group(1))
                elif cond and cond in comps:
                    trip = _trip_count(comps[cond])
                if trip is None:
                    stats["dynamic"] += 1
                    trip = 1
                for sub, cb2 in ((body, count_bytes), (cond, False)):
                    if sub:
                        f2, b2, c2 = visit(sub, cb2)
                        fl += trip * f2
                        by += trip * b2
                        for k, v in c2.items():
                            coll[k] = coll.get(k, 0.0) + trip * v
            elif op.opcode == "fusion":
                callee = op.comps.get("calls")
                if callee:
                    f2, b2, c2 = visit(callee, False)  # flops only inside fusion
                    fl += f2
                    for k, v in c2.items():
                        coll[k] = coll.get(k, 0.0) + v
            elif op.opcode in ("call", "conditional", "async-start"):
                for attr in ("to_apply", "calls"):
                    callee = op.comps.get(attr)
                    if callee:
                        f2, b2, c2 = visit(callee, count_bytes)
                        fl += f2
                        by += b2
                        for k, v in c2.items():
                            coll[k] = coll.get(k, 0.0) + v
        memo[key] = (fl, by, coll)
        return memo[key]

    fl, by, coll = visit("ENTRY", True)
    coll["total"] = sum(v for k, v in coll.items() if k in COLLECTIVES)
    return ModuleCosts(
        flops=fl, bytes=by, collectives=coll,
        dynamic_loops=stats["dynamic"], while_loops=stats["whiles"],
    )
