"""Bench regression gate for CI (DESIGN.md §13/§14 tooling).

Compares a freshly produced bench JSON against the committed baseline and
FAILS (exit 1) when the gated ratio drops more than ``--tolerance``
(default 20%) below the baseline's.  Gated rows hold RATIOS of two
wall-time numbers measured on the same host in the same process — each
the best of several timed passes (``benchmarks/run.py`` ``SERVE_PASSES``),
so one descheduled pass on a loaded shared runner cannot sink them —
which makes them the only bench metrics comparable between the CI runner
and whatever machine committed the baseline; absolute ``us_per_call``
rows are trend data only and are never gated.

    # default: the paged-vs-monolithic serve throughput ratio
    python benchmarks/check_regression.py BASELINE.json FRESH.json

    # the async-checkpointer gate: machine-independent ABSOLUTE floor
    python benchmarks/check_regression.py BENCH_ckpt.json FRESH.json \\
        --row ckpt_async_ratio --key overlap_ratio --floor 1.0

``--row``/``--key`` select which row's ``derived`` field carries the
ratio; ``--floor`` swaps the relative-to-baseline check for an absolute
one (the fresh value itself must clear the floor — right for ratios whose
meaningful bound is a constant, like overlap >= 1.0).  A baseline without
the gated row passes with a note, so each gate arms itself on the first
commit that carries its row.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

RATIO_ROW = "serve_paged_ratio"
RATIO_KEY = "throughput_ratio"


def load_ratio(path: str, row: str, key: str) -> float | None:
    """The ``key=<float>`` value in ``row``'s derived field, else None."""
    with open(path) as f:
        rows = json.load(f)
    entry = rows.get(row)
    if entry is None:
        return None
    m = re.search(rf"{re.escape(key)}=([0-9.]+)", entry.get("derived", ""))
    return float(m.group(1)) if m else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when a gated bench ratio regresses vs the "
                    "committed baseline (or an absolute --floor)")
    ap.add_argument("baseline", help="committed bench JSON")
    ap.add_argument("fresh", help="bench JSON from this run")
    ap.add_argument("--row", default=RATIO_ROW,
                    help=f"gated row name (default {RATIO_ROW})")
    ap.add_argument("--key", default=RATIO_KEY,
                    help=f"ratio key inside the row's derived field "
                         f"(default {RATIO_KEY})")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional drop vs baseline (default 0.20)")
    ap.add_argument("--floor", type=float, default=None,
                    help="absolute floor for the FRESH value instead of the "
                         "relative-to-baseline check (machine-independent "
                         "ratios only)")
    args = ap.parse_args(argv)

    base = load_ratio(args.baseline, args.row, args.key)
    fresh = load_ratio(args.fresh, args.row, args.key)
    if base is None:
        print(f"# {args.baseline} has no {args.row} row (pre-{args.key} "
              f"baseline); gate passes vacuously")
        return 0
    if fresh is None:
        print(f"FAIL: {args.fresh} lost its {args.row} row — the gated "
              f"bench did not run")
        return 1
    if args.floor is not None:
        verdict = "OK" if fresh >= args.floor else "FAIL"
        print(f"{verdict}: {args.row} {args.key} {fresh:.3f} vs absolute "
              f"floor {args.floor:.3f} (baseline carried {base:.3f})")
        return 0 if fresh >= args.floor else 1
    floor = base * (1.0 - args.tolerance)
    verdict = "OK" if fresh >= floor else "FAIL"
    print(f"{verdict}: {args.row} {args.key} {fresh:.3f} vs "
          f"baseline {base:.3f} (floor {floor:.3f} at "
          f"{args.tolerance:.0%} tolerance)")
    return 0 if fresh >= floor else 1


if __name__ == "__main__":
    sys.exit(main())
