"""Serve-bench regression gate for CI (DESIGN.md §13 tooling).

Compares a freshly produced BENCH_serve.json against the committed
baseline and FAILS (exit 1) when the paged-vs-monolithic throughput ratio
of ``serve_paged_ratio`` drops more than ``--tolerance`` (default 20%)
below the baseline's.  The ratio divides two tok/s numbers measured on the
same host in the same process — each the best of several timed passes
(``benchmarks/run.py`` ``SERVE_PASSES``), so one descheduled pass on a
loaded shared runner cannot sink it — which makes it the one serve metric
comparable between the CI runner and whatever machine committed the
baseline; absolute ``us_per_call`` rows are trend data only and are never
gated.

    python benchmarks/check_regression.py BASELINE.json FRESH.json

A baseline without the ratio row (pre-paging trajectory) passes with a
note, so the gate arms itself on the first commit that carries one.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

RATIO_ROW = "serve_paged_ratio"


def load_ratio(path: str) -> float | None:
    """The throughput_ratio value of RATIO_ROW in ``path``, else None."""
    with open(path) as f:
        rows = json.load(f)
    row = rows.get(RATIO_ROW)
    if row is None:
        return None
    m = re.search(r"throughput_ratio=([0-9.]+)", row.get("derived", ""))
    return float(m.group(1)) if m else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when the paged/monolithic serve throughput "
                    "ratio regresses vs the committed baseline")
    ap.add_argument("baseline", help="committed BENCH_serve.json")
    ap.add_argument("fresh", help="BENCH_serve.json from this run")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional drop vs baseline (default 0.20)")
    args = ap.parse_args(argv)

    base = load_ratio(args.baseline)
    fresh = load_ratio(args.fresh)
    if base is None:
        print(f"# {args.baseline} has no {RATIO_ROW} row (pre-paging "
              f"baseline); gate passes vacuously")
        return 0
    if fresh is None:
        print(f"FAIL: {args.fresh} lost its {RATIO_ROW} row — the paged "
              f"serve bench did not run")
        return 1
    floor = base * (1.0 - args.tolerance)
    verdict = "OK" if fresh >= floor else "FAIL"
    print(f"{verdict}: paged/monolithic throughput ratio {fresh:.3f} vs "
          f"baseline {base:.3f} (floor {floor:.3f} at "
          f"{args.tolerance:.0%} tolerance)")
    return 0 if fresh >= floor else 1


if __name__ == "__main__":
    sys.exit(main())
