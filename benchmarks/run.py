"""Benchmark harness — one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is wall time per
logical operation on THIS host's CPU — correctness/trend data, not TPU
numbers; the TPU story lives in the dry-run roofline).

  table1_opcount       paper Table 1: modular-mult counts, ours vs classic
  compare_latency      Alg.1 vs classic 2-MRC vs approx-CRT, batched, vs n
  compare_kernel       fused Pallas Alg.1 (interpret) vs unfused reference
  extension_methods    exactness + timing of MRC / Shenoy / Kawamura
  grad_codec           wire bytes + encode/allreduce/decode cost vs fp32
  division_scaling     comparison-driven divmod / scaling costs
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (x64)
from repro.core import (
    approx_crt_ge,
    mrc,
    mrc_tree,
    classic_compare_ge,
    divmod_rns,
    extend_kawamura,
    extend_mrc,
    extend_shenoy,
    halve,
    make_base,
    pack,
    rns_compare_ge,
    rns_to_int,
)
from repro.dist.grad_codec import GradCodec
from repro.kernels import compare_op

NS = (4, 8, 16, 32, 64)
BATCH = 2048


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _rand_operands(base, batch, rng):
    m = np.asarray(base.moduli_np)
    x1 = rng.integers(0, m, size=(batch, base.n)).astype(base.dtype)
    x2 = rng.integers(0, m, size=(batch, base.n)).astype(base.dtype)
    a1 = np.asarray([rns_to_int(base, r) % base.ma for r in x1], base.dtype)
    a2 = np.asarray([rns_to_int(base, r) % base.ma for r in x2], base.dtype)
    return (jnp.asarray(x1), jnp.asarray(a1), jnp.asarray(x2), jnp.asarray(a2))


# ---------------------------------------------------------------- Table 1
def _count_mults_ours(n):
    # MRC: n(n-1)/2, Alg.3 dot: n  (paper Table 1, row 1)
    return n * (n - 1) // 2 + n


def _count_mults_classic(n):
    return n * (n - 1)  # two MRCs (row 2)


def _instrumented_compare(base, N1, N2):
    """Pure-python Alg. 1 that counts modular multiplications."""
    n = base.n
    mults = 0
    z = [(a - b) % m for a, b, m in
         zip(base.residues_of(N1).tolist(), base.residues_of(N2).tolist(),
             base.moduli)]
    a = list(z)
    for i in range(1, n):
        for j in range(i):
            a[i] = (a[i] - a[j]) * int(base.inv_tri_np[j, i]) % base.moduli[i]
            mults += 1
    delta = 0
    for i in range(n):
        delta = (delta + a[i] * int(base.betas_ma_np[i])) % base.ma
        mults += 1
    dprime = (N1 % base.ma - N2 % base.ma) % base.ma
    assert (delta == dprime) == (N1 >= N2)
    return mults


def table1_opcount():
    rng = np.random.default_rng(0)
    for n in NS:
        base = make_base(n, bits=15)
        N1 = int(rng.integers(0, 1 << 60)) % base.M
        N2 = int(rng.integers(0, 1 << 60)) % base.M
        measured = _instrumented_compare(base, N1, N2)
        assert measured == _count_mults_ours(n), (measured, n)
        print(f"table1_ours_n{n},0,{measured}")
        print(f"table1_classic_n{n},0,{_count_mults_classic(n)}")
        print(f"table1_ratio_n{n},0,{_count_mults_classic(n)/measured:.3f}")


# ---------------------------------------------------------- compare latency
def compare_latency():
    rng = np.random.default_rng(1)
    for n in NS:
        base = make_base(n, bits=15)
        ops = _rand_operands(base, BATCH, rng)

        ours = jax.jit(lambda a, b, c, d: rns_compare_ge(base, a, b, c, d))
        classic = jax.jit(lambda a, c: classic_compare_ge(base, a, c))
        approx = jax.jit(lambda a, c: approx_crt_ge(base, a, c))

        t_ours = _time(ours, *ops)
        t_classic = _time(classic, ops[0], ops[2])
        t_approx = _time(approx, ops[0], ops[2])
        print(f"compare_ours_n{n},{t_ours:.1f},{t_ours/BATCH*1e3:.2f}ns_elt")
        print(f"compare_classic_n{n},{t_classic:.1f},"
              f"speedup={t_classic/t_ours:.2f}")
        print(f"compare_approx_n{n},{t_approx:.1f},exact=False")


def compare_kernel():
    rng = np.random.default_rng(2)
    for n in (4, 8, 16):
        base = make_base(n, bits=15)
        ops = _rand_operands(base, 512, rng)
        fused = lambda a, b, c, d: compare_op(base, a, b, c, d, interpret=True)
        ref = jax.jit(lambda a, b, c, d: rns_compare_ge(base, a, b, c, d))
        t_f = _time(fused, *ops, iters=5)
        t_r = _time(ref, *ops, iters=5)
        ok = bool(jnp.all(fused(*ops) == ref(*ops)))
        print(f"kernel_fused_interp_n{n},{t_f:.1f},match={ok}")
        print(f"kernel_ref_jit_n{n},{t_r:.1f},note=interpret-mode-not-perf")


def mrc_parallel_depth():
    """Sequential Alg. 2 vs divide-and-conquer MRC (the paper's §3.3
    parallel-time claim).  derived = dependency depth (levels of sequential
    modular ops on a machine with enough lanes)."""
    import math

    rng = np.random.default_rng(6)
    for n in (16, 64, 128):
        base = make_base(n, bits=15)
        m = np.asarray(base.moduli_np)
        xs = jnp.asarray(rng.integers(0, m, size=(256, n)).astype(np.int32))
        f_seq = jax.jit(lambda x: mrc(base, x))
        f_tree = jax.jit(lambda x: mrc_tree(base, x))
        assert bool(jnp.all(f_seq(xs) == f_tree(xs)))
        d_seq = n - 1
        d_tree = int(math.ceil(math.log2(n))) ** 2
        print(f"mrc_seq_n{n},{_time(f_seq, xs, iters=5):.1f},depth={d_seq}")
        print(f"mrc_tree_n{n},{_time(f_tree, xs, iters=5):.1f},"
              f"depth~log2(n)^2={d_tree}")


# ------------------------------------------------------- extension methods
def extension_methods():
    rng = np.random.default_rng(3)
    n = 16
    base = make_base(n, bits=15)
    targets = (32603, 32587)
    trials = 512
    Ns = [int(rng.integers(0, 1 << 62)) % base.M for _ in range(trials - 4)]
    Ns += [0, 1, base.M - 1, base.M - 2]  # adversarial edges
    xs = jnp.asarray(np.stack([base.residues_of(N) for N in Ns]))
    xr = jnp.asarray(np.asarray([N % base.ma for N in Ns], base.dtype))
    want = np.stack([[N % t for t in targets] for N in Ns])

    f_mrc = jax.jit(lambda x: extend_mrc(base, x, targets))
    f_sh = jax.jit(lambda x, r: extend_shenoy(base, x, r, base.ma, targets))
    f_kw = jax.jit(lambda x: extend_kawamura(base, x, targets))

    acc_mrc = float(np.mean(np.all(np.asarray(f_mrc(xs)) == want, -1)))
    acc_sh = float(np.mean(np.all(np.asarray(f_sh(xs, xr)) == want, -1)))
    acc_kw = float(np.mean(np.all(np.asarray(f_kw(xs)) == want, -1)))
    print(f"extend_mrc,{_time(f_mrc, xs):.1f},exact={acc_mrc:.4f}")
    print(f"extend_shenoy,{_time(f_sh, xs, xr):.1f},exact={acc_sh:.4f}")
    print(f"extend_kawamura,{_time(f_kw, xs):.1f},exact={acc_kw:.4f}")
    assert acc_mrc == 1.0 and acc_sh == 1.0  # exact methods must be exact


# --------------------------------------------------------------- grad codec
def grad_codec():
    codec = GradCodec.make(world=512)
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal((1 << 16,)).astype(np.float32))
    enc = jax.jit(codec.encode)
    dec = jax.jit(lambda p: codec.decode(codec.fold(p)))
    packed = enc(g)
    wire_bits = packed.shape[-1] * 16  # residues fit int16 lanes on the wire
    # Fair baseline: the codec provides EXACT integer summation over 512
    # replicas, whose scalar equivalent is int64 (int32 overflows, fp32 is
    # lossy/non-deterministic).  vs fp32 the wire costs 2x — recorded
    # honestly; the win is exactness + per-channel independence (paper §1).
    print(f"codec_encode,{_time(enc, g):.1f},wire_bits_per_elt={wire_bits}")
    print(f"codec_decode,{_time(dec, packed):.1f},"
          f"vs_exact_int64_ratio={wire_bits/64:.2f},vs_fp32_ratio="
          f"{wire_bits/32:.2f}")
    err = float(jnp.max(jnp.abs(dec(packed) - g)))
    print(f"codec_roundtrip,0,max_err={err:.2e}(<2^-{codec.frac_bits})")


def grad_codec_allreduce():
    """End-to-end distributed path: rns_psum (encode -> per-channel psum ->
    fold -> decode) vs a raw fp32 psum, under shard_map over this host's
    'data' axis.  The delta is the codec overhead a future fused-kernel PR
    must beat; the fused Pallas decode (interpret off-TPU) is timed alongside."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.dist.grad_codec import rns_psum
    from repro.kernels import codec_decode_op

    ndev = len(jax.devices())
    codec = GradCodec.make(world=max(ndev, 2))
    mesh = Mesh(np.array(jax.devices()), ("data",))
    rng = np.random.default_rng(7)
    for size in (1 << 14, 1 << 18):
        g = jnp.asarray(rng.standard_normal(size).astype(np.float32))
        sm = lambda f: jax.jit(shard_map(
            f, mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False
        ))
        f_rns = sm(lambda x: rns_psum(codec, x, "data"))
        f_fp = sm(lambda x: jax.lax.psum(x, "data") / ndev)
        t_rns = _time(f_rns, g, iters=10)
        t_fp = _time(f_fp, g, iters=10)
        err = float(jnp.max(jnp.abs(f_rns(g) - f_fp(g))))
        print(f"allreduce_rns_{size},{t_rns:.1f},"
              f"elts_per_s={size/t_rns*1e6:.2e}")
        print(f"allreduce_fp32_{size},{t_fp:.1f},"
              f"rns_overhead_x={t_rns/t_fp:.2f},max_dev={err:.1e}")
        summed = jax.jit(codec.encode)(g)
        f_fused = jax.jit(lambda p: codec_decode_op(codec, p, interpret=True))
        t_fused = _time(f_fused, summed, iters=5)
        print(f"allreduce_fused_decode_{size},{t_fused:.1f},"
              f"note=interpret-mode-not-perf")


# --------------------------------------------------------- division/scaling
def division_scaling():
    base = make_base(4, bits=8)
    rng = np.random.default_rng(5)
    X = int(rng.integers(1, base.M))
    D = int(rng.integers(1, X))
    xp = pack(base, jnp.asarray(base.residues_of(X)), jnp.asarray(X % base.ma))
    dp = pack(base, jnp.asarray(base.residues_of(D)), jnp.asarray(D % base.ma))
    f_div = jax.jit(lambda a, b: divmod_rns(base, a, b))
    q, r = f_div(xp, dp)
    ok = (rns_to_int(base, np.asarray(q[..., :-1])),
          rns_to_int(base, np.asarray(r[..., :-1]))) == divmod(X, D)
    ncmp = 2 * base.M.bit_length() + 1
    print(f"divmod_rns,{_time(f_div, xp, dp, iters=5):.1f},"
          f"comparisons={ncmp},correct={ok}")
    f_h = jax.jit(lambda a: halve(base, a))
    print(f"scale_halve,{_time(f_h, xp):.1f},exact=True")


TABLES = [
    table1_opcount,
    compare_latency,
    compare_kernel,
    mrc_parallel_depth,
    extension_methods,
    grad_codec,
    grad_codec_allreduce,
    division_scaling,
]


def main() -> None:
    print("name,us_per_call,derived")
    for fn in TABLES:
        fn()


if __name__ == "__main__":
    main()
