"""Benchmark harness — one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is wall time per
logical operation on THIS host's CPU — correctness/trend data, not TPU
numbers; the TPU story lives in the dry-run roofline).  ``--json PATH``
additionally writes the same rows as machine-readable JSON (default
``BENCH_codec.json``) so the perf trajectory is trackable across PRs;
``--small`` shrinks every sweep for CI smoke runs.

  table1_opcount       paper Table 1: modular-mult counts, ours vs classic
  compare_latency      Alg.1 vs classic 2-MRC vs approx-CRT, batched, vs n
  compare_kernel       fused Pallas Alg.1 (interpret) vs unfused reference
  extension_methods    exactness + timing of MRC / Shenoy / Kawamura
  grad_codec           wire bytes + encode/allreduce/decode cost vs fp32
  codec_correct        RRNS detect vs locate-and-correct cost + wire tax
  rns_array_api        typed RnsArray frontend vs legacy dispatch (~0 cost)
  division_scaling     comparison-driven divmod / scaling costs
  serve_batching       continuous batching vs one-at-a-time serving
  serve_paged          paged prefix-sharing pool vs the monolithic cache
  serve_offline        saturation harness vs the synchronous tick driver
  ckpt_async           async RRNS checkpointer stall vs blocking saves
  crypto_modexp        batched crypto lane vs solo ladders, Pallas vs jnp

``--json`` also splits the ``rns_array_*`` rows into BENCH_api.json, the
``serve_*`` rows into BENCH_serve.json, the ``ckpt_*`` rows into
BENCH_ckpt.json, and the ``crypto_*`` rows into BENCH_crypto.json so the
typed-API overhead, the serving latency/throughput trajectory, the
checkpoint overlap, and the crypto-lane batching win each have their own
tracked artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (x64)
from repro.core import (
    approx_crt_ge,
    mrc,
    mrc_tree,
    classic_compare_ge,
    divmod_rns,
    extend_kawamura,
    extend_mrc,
    extend_shenoy,
    halve,
    make_base,
    pack,
    rns_compare_ge,
    rns_to_int,
)
from repro.dist.grad_codec import GradCodec
from repro.kernels import compare_op

NS = (4, 8, 16, 32, 64)
KERNEL_NS = (4, 8, 16)
MRC_NS = (16, 64, 128)
BATCH = 2048
ALLREDUCE_SIZES = (1 << 14, 1 << 18)
EXT_TRIALS = 512

RESULTS: dict[str, dict] = {}


def emit(name: str, us: float, derived) -> None:
    """One benchmark row: CSV to stdout, and into the --json record."""
    RESULTS[name] = {"us_per_call": float(us), "derived": str(derived)}
    print(f"{name},{us:.1f},{derived}")


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _rand_operands(base, batch, rng):
    m = np.asarray(base.moduli_np)
    x1 = rng.integers(0, m, size=(batch, base.n)).astype(base.dtype)
    x2 = rng.integers(0, m, size=(batch, base.n)).astype(base.dtype)
    a1 = np.asarray([rns_to_int(base, r) % base.ma for r in x1], base.dtype)
    a2 = np.asarray([rns_to_int(base, r) % base.ma for r in x2], base.dtype)
    return (jnp.asarray(x1), jnp.asarray(a1), jnp.asarray(x2), jnp.asarray(a2))


# ---------------------------------------------------------------- Table 1
def _count_mults_ours(n):
    # MRC: n(n-1)/2, Alg.3 dot: n  (paper Table 1, row 1)
    return n * (n - 1) // 2 + n


def _count_mults_classic(n):
    return n * (n - 1)  # two MRCs (row 2)


def _instrumented_compare(base, N1, N2):
    """Pure-python Alg. 1 that counts modular multiplications."""
    n = base.n
    mults = 0
    z = [(a - b) % m for a, b, m in
         zip(base.residues_of(N1).tolist(), base.residues_of(N2).tolist(),
             base.moduli)]
    a = list(z)
    for i in range(1, n):
        for j in range(i):
            a[i] = (a[i] - a[j]) * int(base.inv_tri_np[j, i]) % base.moduli[i]
            mults += 1
    delta = 0
    for i in range(n):
        delta = (delta + a[i] * int(base.betas_ma_np[i])) % base.ma
        mults += 1
    dprime = (N1 % base.ma - N2 % base.ma) % base.ma
    assert (delta == dprime) == (N1 >= N2)
    return mults


def table1_opcount():
    rng = np.random.default_rng(0)
    for n in NS:
        base = make_base(n, bits=15)
        N1 = int(rng.integers(0, 1 << 60)) % base.M
        N2 = int(rng.integers(0, 1 << 60)) % base.M
        measured = _instrumented_compare(base, N1, N2)
        assert measured == _count_mults_ours(n), (measured, n)
        emit(f"table1_ours_n{n}", 0, measured)
        emit(f"table1_classic_n{n}", 0, _count_mults_classic(n))
        emit(f"table1_ratio_n{n}", 0,
             f"{_count_mults_classic(n) / measured:.3f}")


# ---------------------------------------------------------- compare latency
def compare_latency():
    rng = np.random.default_rng(1)
    for n in NS:
        base = make_base(n, bits=15)
        ops = _rand_operands(base, BATCH, rng)

        ours = jax.jit(lambda a, b, c, d: rns_compare_ge(base, a, b, c, d))
        classic = jax.jit(lambda a, c: classic_compare_ge(base, a, c))
        approx = jax.jit(lambda a, c: approx_crt_ge(base, a, c))

        t_ours = _time(ours, *ops)
        t_classic = _time(classic, ops[0], ops[2])
        t_approx = _time(approx, ops[0], ops[2])
        emit(f"compare_ours_n{n}", t_ours, f"{t_ours/BATCH*1e3:.2f}ns_elt")
        emit(f"compare_classic_n{n}", t_classic,
             f"speedup={t_classic/t_ours:.2f}")
        emit(f"compare_approx_n{n}", t_approx, "exact=False")


def compare_kernel():
    rng = np.random.default_rng(2)
    for n in KERNEL_NS:
        base = make_base(n, bits=15)
        ops = _rand_operands(base, 512, rng)
        fused = lambda a, b, c, d: compare_op(base, a, b, c, d, interpret=True)
        ref = jax.jit(lambda a, b, c, d: rns_compare_ge(base, a, b, c, d))
        t_f = _time(fused, *ops, iters=5)
        t_r = _time(ref, *ops, iters=5)
        ok = bool(jnp.all(fused(*ops) == ref(*ops)))
        emit(f"kernel_fused_interp_n{n}", t_f, f"match={ok}")
        emit(f"kernel_ref_jit_n{n}", t_r, "note=interpret-mode-not-perf")


def mrc_parallel_depth():
    """Sequential Alg. 2 vs divide-and-conquer MRC (the paper's §3.3
    parallel-time claim).  derived = dependency depth (levels of sequential
    modular ops on a machine with enough lanes)."""
    import math

    rng = np.random.default_rng(6)
    for n in MRC_NS:
        base = make_base(n, bits=15)
        m = np.asarray(base.moduli_np)
        xs = jnp.asarray(rng.integers(0, m, size=(256, n)).astype(np.int32))
        f_seq = jax.jit(lambda x: mrc(base, x))
        f_tree = jax.jit(lambda x: mrc_tree(base, x))
        assert bool(jnp.all(f_seq(xs) == f_tree(xs)))
        d_seq = n - 1
        d_tree = int(math.ceil(math.log2(n))) ** 2
        emit(f"mrc_seq_n{n}", _time(f_seq, xs, iters=5), f"depth={d_seq}")
        emit(f"mrc_tree_n{n}", _time(f_tree, xs, iters=5),
             f"depth~log2(n)^2={d_tree}")


# ------------------------------------------------------- extension methods
def extension_methods():
    rng = np.random.default_rng(3)
    n = 16
    base = make_base(n, bits=15)
    targets = (32603, 32587)
    trials = EXT_TRIALS
    Ns = [int(rng.integers(0, 1 << 62)) % base.M for _ in range(trials - 4)]
    Ns += [0, 1, base.M - 1, base.M - 2]  # adversarial edges
    xs = jnp.asarray(np.stack([base.residues_of(N) for N in Ns]))
    xr = jnp.asarray(np.asarray([N % base.ma for N in Ns], base.dtype))
    want = np.stack([[N % t for t in targets] for N in Ns])

    f_mrc = jax.jit(lambda x: extend_mrc(base, x, targets))
    f_sh = jax.jit(lambda x, r: extend_shenoy(base, x, r, base.ma, targets))
    f_kw = jax.jit(lambda x: extend_kawamura(base, x, targets))

    acc_mrc = float(np.mean(np.all(np.asarray(f_mrc(xs)) == want, -1)))
    acc_sh = float(np.mean(np.all(np.asarray(f_sh(xs, xr)) == want, -1)))
    acc_kw = float(np.mean(np.all(np.asarray(f_kw(xs)) == want, -1)))
    emit("extend_mrc", _time(f_mrc, xs), f"exact={acc_mrc:.4f}")
    emit("extend_shenoy", _time(f_sh, xs, xr), f"exact={acc_sh:.4f}")
    emit("extend_kawamura", _time(f_kw, xs), f"exact={acc_kw:.4f}")
    assert acc_mrc == 1.0 and acc_sh == 1.0  # exact methods must be exact


# --------------------------------------------------------------- grad codec
def grad_codec():
    from repro.kernels import codec_encode_op

    codec = GradCodec.make(world=512)
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal((1 << 16,)).astype(np.float32))
    enc = jax.jit(codec.encode)
    enc_fused = jax.jit(lambda x: codec_encode_op(codec, x))
    dec = jax.jit(lambda p: codec.decode(codec.fold(p)))
    packed = enc(g)
    wire_bits = packed.shape[-1] * 16  # residues fit int16 lanes on the wire
    # Fair baseline: the codec provides EXACT integer summation over 512
    # replicas, whose scalar equivalent is int64 (int32 overflows, fp32 is
    # lossy/non-deterministic).  vs fp32 the wire costs 2x — recorded
    # honestly; the win is exactness + per-channel independence (paper §1).
    emit("codec_encode", _time(enc, g), f"wire_bits_per_elt={wire_bits}")
    bitwise = bool(jnp.all(enc_fused(g) == packed))
    emit("codec_encode_fused", _time(enc_fused, g), f"bitwise={bitwise}")
    emit("codec_decode", _time(dec, packed),
         f"vs_exact_int64_ratio={wire_bits/64:.2f},vs_fp32_ratio="
         f"{wire_bits/32:.2f}")
    err = float(jnp.max(jnp.abs(dec(packed) - g)))
    emit("codec_roundtrip", 0, f"max_err={err:.2e}(<2^-{codec.frac_bits})")


def grad_codec_allreduce():
    """End-to-end distributed path under shard_map over this host's 'data'
    axis, recorded at three granularities:

      allreduce_rns_*          per-tensor rns_psum, jnp codec (historical)
      allreduce_rns_fused_*    per-tensor rns_psum, fused Pallas codec
      allreduce_fp32_*         raw fp32 psum baseline
      allreduce_{fused,jnp}_decode_*  decode alone, fed the REAL post-psum
                               summed channels (not fresh encodings)
      allreduce_rns_per_leaf_* / allreduce_rns_tree_* / _tree_unfused_*
                               an 8-leaf pytree: one collective per leaf vs
                               the single-buffer bucketed psum
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.dist.grad_codec import rns_psum, rns_psum_tree
    from repro.kernels import codec_decode_op

    ndev = len(jax.devices())
    world = max(ndev, 2)
    codec = GradCodec.make(world=world)                  # fused transport
    codec_jnp = GradCodec.make(world=world, fused=False)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    rng = np.random.default_rng(7)
    for size in ALLREDUCE_SIZES:
        g = jnp.asarray(rng.standard_normal(size).astype(np.float32))
        sm = lambda f: jax.jit(shard_map(
            f, mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False
        ))
        f_rns = sm(lambda x: rns_psum(codec_jnp, x, "data"))
        f_rns_fused = sm(lambda x: rns_psum(codec, x, "data"))
        f_fp = sm(lambda x: jax.lax.psum(x, "data") / ndev)
        t_rns = _time(f_rns, g, iters=10)
        t_rns_fused = _time(f_rns_fused, g, iters=10)
        t_fp = _time(f_fp, g, iters=10)
        err = float(jnp.max(jnp.abs(f_rns(g) - f_fp(g))))
        bitwise = bool(jnp.all(f_rns(g) == f_rns_fused(g)))
        emit(f"allreduce_rns_{size}", t_rns,
             f"elts_per_s={size/t_rns*1e6:.2e}")
        emit(f"allreduce_rns_fused_{size}", t_rns_fused,
             f"speedup_vs_jnp={t_rns/t_rns_fused:.2f},bitwise={bitwise}")
        emit(f"allreduce_fp32_{size}", t_fp,
             f"rns_overhead_x={t_rns_fused/t_fp:.2f},max_dev={err:.1e}")

        # decode alone, on the REAL post-psum summed channels (what the
        # optimizer-side decode actually sees — not fresh encodings)
        summed = sm(lambda x: jax.lax.psum(codec_jnp.encode(x), "data"))(g)
        f_fused_dec = jax.jit(lambda p: codec_decode_op(codec, p))
        f_jnp_dec = jax.jit(lambda p: codec_jnp.decode(codec_jnp.fold(p)))
        t_fused_dec = _time(f_fused_dec, summed, iters=10)
        t_jnp_dec = _time(f_jnp_dec, summed, iters=10)
        emit(f"allreduce_fused_decode_{size}", t_fused_dec,
             f"speedup_vs_jnp={t_jnp_dec/t_fused_dec:.2f}")
        emit(f"allreduce_jnp_decode_{size}", t_jnp_dec, "post-psum-input")

        # bucketing: an 8-leaf pytree as one collective per leaf vs ONE
        # single-buffer per-channel psum (tree_pack), fused and unfused
        tree = {
            f"leaf{i}": jnp.asarray(
                rng.standard_normal(size // 8).astype(np.float32)
            )
            for i in range(8)
        }
        smt = lambda f: jax.jit(shard_map(
            f, mesh, in_specs=(P(),), out_specs=P(), check_rep=False
        ))
        f_leaf = smt(lambda t: jax.tree_util.tree_map(
            lambda x: rns_psum(codec_jnp, x, "data"), t))
        f_tree = smt(lambda t: rns_psum_tree(codec, t, "data"))
        f_tree_u = smt(lambda t: rns_psum_tree(codec_jnp, t, "data"))
        t_leaf = _time(f_leaf, tree, iters=10)
        t_tree = _time(f_tree, tree, iters=10)
        t_tree_u = _time(f_tree_u, tree, iters=10)
        emit(f"allreduce_rns_per_leaf_{size}", t_leaf, "collectives=8")
        emit(f"allreduce_rns_tree_{size}", t_tree,
             f"collectives=1,speedup_vs_per_leaf={t_leaf/t_tree:.2f}")
        emit(f"allreduce_rns_tree_unfused_{size}", t_tree_u,
             f"collectives=1,fused_speedup={t_tree_u/t_tree:.2f}")


# ------------------------------------------------------------ codec correct
def codec_correct():
    """RRNS error handling on the wire buffer (DESIGN.md §10): the detect
    check (verify_packed, one MRC) vs the full locate-and-correct scan
    (n_channels survivor MRCs), and the wire tax of the second redundant
    channel.  Corruption is injected in ~1/1024 elements — repair must fix
    exactly those and leave the rest bitwise untouched."""
    codec = GradCodec.make(world=8, correct=True)
    rng = np.random.default_rng(8)
    B = min(ALLREDUCE_SIZES[-1], 1 << 14)
    g = jnp.asarray(rng.standard_normal(B).astype(np.float32))
    buf = codec.encode(g).astype(jnp.int32)
    m0 = int(codec.base.moduli[0])
    hits = rng.random(B) < 1.0 / 1024
    bad = jnp.where(
        jnp.asarray(hits)[:, None]
        & (jnp.arange(codec.n_channels) == 0),
        jnp.mod(buf + 7, m0), buf,
    )
    f_verify = jax.jit(lambda p: codec.verify_packed(p))
    f_correct = jax.jit(lambda p: codec.correct_packed(p))
    fixed, fault = f_correct(bad)
    n_fix = int(jnp.sum(fault >= 0))
    ok = bool(jnp.all(fixed == buf)) and n_fix == int(hits.sum())
    t_v = _time(f_verify, bad, iters=10)
    t_c = _time(f_correct, bad, iters=10)
    wire = codec.n_channels * 16  # int16-lane residues on the wire
    base_wire = (codec.base.n + 1) * 16
    emit("codec_verify_detect", t_v, f"elts={B}")
    emit("codec_locate_correct", t_c,
         f"vs_detect_x={t_c/t_v:.2f},repaired={n_fix},exact={ok}")
    emit("codec_correct_wire_bits", 0,
         f"per_elt={wire},vs_detect_only={wire/base_wire:.2f}x")
    assert ok, "RRNS repair must restore the corrupted buffer bitwise"


# ----------------------------------------------------------- typed frontend
def rns_array_api():
    """Dispatch overhead of the typed ``RnsArray`` frontend vs the legacy
    call signatures.  Under jit both routes trace to the same computation
    (the legacy functions ARE shims over the type), so steady-state time
    per call must be ~identical — this table guards that the API redesign
    stays free.  Rows land in BENCH_api.json for trend tracking."""
    from repro.core import RnsArray

    rng = np.random.default_rng(9)
    base = make_base(8, bits=15)
    ops = _rand_operands(base, BATCH, rng)
    a = RnsArray.from_parts(base, ops[0], ops[1])
    b = RnsArray.from_parts(base, ops[2], ops[3])
    legacy = jax.jit(lambda x1, a1, x2, a2: rns_compare_ge(base, x1, a1, x2, a2))
    typed = jax.jit(lambda u, v: u >= v)
    t_leg = _time(legacy, *ops)
    t_typ = _time(typed, a, b)
    bitwise = bool(jnp.all(typed(a, b) == legacy(*ops)))
    emit("rns_array_compare", t_typ,
         f"overhead_vs_legacy={t_typ/t_leg:.3f}x,bitwise={bitwise}")
    emit("rns_array_compare_legacy", t_leg, f"batch={BATCH}")

    base8 = make_base(4, bits=8)
    X = [int(rng.integers(1, base8.M)) for _ in range(8)]
    D = [int(rng.integers(1, x)) for x in X]
    xp = jnp.asarray(np.stack([np.concatenate(
        [base8.residues_of(v), [v % base8.ma]]).astype(np.int32) for v in X]))
    dp = jnp.asarray(np.stack([np.concatenate(
        [base8.residues_of(v), [v % base8.ma]]).astype(np.int32) for v in D]))
    ax = RnsArray.from_packed(base8, xp)
    ad = RnsArray.from_packed(base8, dp)
    f_leg = jax.jit(lambda p, q: divmod_rns(base8, p, q))
    f_typ = jax.jit(lambda u, v: u.divmod(v))
    t_leg = _time(f_leg, xp, dp, iters=5)
    t_typ = _time(f_typ, ax, ad, iters=5)
    ql, rl = f_leg(xp, dp)
    qt, rt = f_typ(ax, ad)
    bitwise = bool(jnp.all(ql == qt.to_packed()) and
                   jnp.all(rl == rt.to_packed()))
    emit("rns_array_divmod", t_typ,
         f"overhead_vs_legacy={t_typ/t_leg:.3f}x,bitwise={bitwise}")
    emit("rns_array_divmod_legacy", t_leg, "batch=8")


# --------------------------------------------------------------- serving
SERVE_REQS = 8
SERVE_PASSES = 3  # timed passes per engine; the gated ratio uses the best


def serve_batching():
    """Continuous batching (DESIGN.md §12) vs one-at-a-time serving on the
    smoke config: same workload (Poisson arrivals at tick rate 0.5), one
    engine with 4 slots vs a single-slot engine that can never overlap
    requests.  Rows land in BENCH_serve.json for trend tracking; tick
    latencies are deterministic, tok/s is this host's CPU."""
    from repro.configs import get_config
    from repro.launch.serve import simulate, synth_requests
    from repro.models import init_params
    from repro.serve.batcher import ContinuousBatcher

    cfg = get_config("gemma-2b").smoke()
    params = init_params(cfg, jax.random.key(0))

    def workload():
        rng = np.random.default_rng(12)
        return synth_requests(SERVE_REQS, rng, cfg.vocab, prompt_mean=8,
                              max_new=8, arrival_rate=0.5)

    def run(n_slots):
        eng = ContinuousBatcher(cfg, params, n_slots=n_slots, cache_len=32,
                                prefill_chunk=8)
        simulate(eng, workload())        # warmup: compile + one full pass
        n_warm = len(eng.sched.completed)
        t0 = time.perf_counter()
        counters = simulate(eng, workload())
        wall = time.perf_counter() - t0
        done = eng.sched.completed[n_warm:]  # only the timed pass counts
        toks = sum(len(r.out) for r in done)
        lat = float(np.mean([r.t_done - r.arrival for r in done]))
        return toks / wall, lat, counters["max_concurrency"]

    tokps_b, lat_b, conc = run(4)
    tokps_s, lat_s, _ = run(1)
    emit("serve_batched_tokps", 1e6 / tokps_b,
         f"tok_per_s={tokps_b:.1f},max_concurrency={conc}")
    emit("serve_solo_tokps", 1e6 / tokps_s, f"tok_per_s={tokps_s:.1f}")
    emit("serve_batching_speedup", 0,
         f"throughput_x={tokps_b/tokps_s:.2f},"
         f"latency_ticks_batched={lat_b:.1f},solo={lat_s:.1f}")


def serve_paged():
    """Paged prefix-sharing pool (DESIGN.md §13) vs the monolithic slot
    cache on the same workload: SERVE_REQS requests whose prompts share a
    75%-length common prefix (the system-prompt serving shape).  The
    committed gate metric is ``throughput_ratio`` — paged over monolithic
    tok/s on the SAME host, each the BEST of ``SERVE_PASSES`` timed passes
    (one noisy pass on a loaded CI runner must not fail the gate), so it
    tracks paging overhead machine-independently; ``pages_peak`` shows the
    dedup HBM win (shared prefix pages counted once, vs full rows for
    every slot)."""
    from repro.configs import get_config
    from repro.launch.serve import simulate
    from repro.models import init_params
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.scheduler import Request

    cfg = get_config("gemma-2b").smoke()
    params = init_params(cfg, jax.random.key(0))
    cache_len, page, chunk, plen, max_new = 32, 8, 8, 16, 8
    shared = plen * 3 // 4  # 75%-length common prefix

    def workload():
        rng = np.random.default_rng(21)
        prefix = [int(t) for t in rng.integers(1, cfg.vocab, shared)]
        return [
            Request(
                rid=i,
                prompt=prefix + [int(t) for t in
                                 rng.integers(1, cfg.vocab, plen - shared)],
                max_new=max_new, arrival=0.0,
            )
            for i in range(SERVE_REQS)
        ]

    def run(page_size):
        eng = ContinuousBatcher(
            cfg, params, n_slots=4, cache_len=cache_len,
            prefill_chunk=chunk, page_size=page_size,
        )
        simulate(eng, workload())        # warmup: compile + one full pass
        best = 0.0
        for _ in range(SERVE_PASSES):    # best-of-N rides out runner noise
            n_warm = len(eng.sched.completed)
            t0 = time.perf_counter()
            simulate(eng, workload())
            wall = time.perf_counter() - t0
            done = eng.sched.completed[n_warm:]
            toks = sum(len(r.out) for r in done)
            best = max(best, toks / wall)
        return best, eng

    tokps_p, eng_p = run(page)
    tokps_m, _ = run(None)
    st = eng_p.page_stats()
    emit("serve_paged_tokps", 1e6 / tokps_p,
         f"tok_per_s={tokps_p:.1f},pages_peak={st['pages_in_use_peak']},"
         f"dedup_hits={st['dedup_hits']},cow_copies={st['cow_copies']}")
    emit("serve_monolithic_tokps", 1e6 / tokps_m,
         f"tok_per_s={tokps_m:.1f}")
    emit("serve_paged_ratio", 0,
         f"throughput_ratio={tokps_p/tokps_m:.3f},"
         f"pages_peak={st['pages_in_use_peak']},"
         f"pages_monolithic_equiv={4 * (cache_len // page)}")


def serve_offline():
    """Saturation harness (DESIGN.md §16) vs the synchronous tick-clock
    driver on the same offline trace.  The harness pipeline = length-
    bucketed single-call prefill (ONE extend dispatch per prompt vs the
    baseline's ceil(plen/chunk) chunk loop) + a background completion
    pump running the detokenize callback (a sha256 over a 256 KiB
    payload per completion — releases the GIL like a real tokenizer's
    native code) off the driver thread; the baseline replays the
    identical trace through ``simulate()`` and runs the identical
    callback inline, serialized behind device work.  The gated metric is
    ``overlap_ratio`` — harness tok/s over baseline tok/s, each the
    best of SERVE_PASSES passes.  The floor holds machine-independently
    because the dispatch-count advantage alone clears it even on a
    single-core host (where threads cannot physically overlap); on
    multi-core runners the pump's overlap adds margin on top.  A second
    pass commits the same comparison over the PAGED, prefix-sharing
    pool (``offline_paged_*`` rows): bucketed prefill routes its pads
    through the §13 padded write barrier, and the harness must clear
    the same absolute 1.0 floor against the paged tick driver."""
    import hashlib

    from repro.configs import get_config
    from repro.launch.serve import simulate
    from repro.models import init_params
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.offline import OfflineInference
    from repro.serve.scheduler import Request

    cfg = get_config("gemma-2b").smoke()
    params = init_params(cfg, jax.random.key(0))
    cache_len, chunk, max_new = 64, 8, 8
    n = max(SERVE_REQS, 8)  # enough completions for the pump to matter
    payload = np.random.default_rng(3).bytes(256 << 10)

    def callback(req):
        return hashlib.sha256(payload).hexdigest()

    def workload(rid0):
        # prompts of 8..48 tokens: 1..6 chunk-loop dispatches baseline,
        # always exactly one bucketed dispatch on the harness
        rng = np.random.default_rng(17)
        return [
            Request(
                rid=rid0 + i,
                prompt=[int(t) for t in
                        rng.integers(1, cfg.vocab,
                                     8 + int(rng.integers(0, 41)))],
                max_new=max_new, arrival=0.0,
            )
            for i in range(n)
        ]

    harness = OfflineInference(
        cfg, params, n_slots=4, cache_len=cache_len, prefill_chunk=chunk,
        buckets=(16, 32, 64), overlap=True, queue_size=16,
        callback=callback,
    )
    harness.warmup()
    best_h, rep = 0.0, None
    for p in range(SERVE_PASSES):       # best-of-N rides out runner noise
        r = harness.run(workload(1000 * (p + 1)))
        if r["tok_per_s"] > best_h:
            best_h, rep = r["tok_per_s"], r
    harness.require_steady_state()

    eng = ContinuousBatcher(cfg, params, n_slots=4, cache_len=cache_len,
                            prefill_chunk=chunk)
    simulate(eng, workload(0))           # warmup: compile + one full pass
    [callback(r) for r in eng.sched.completed]
    best_s = 0.0
    for p in range(SERVE_PASSES):
        n_warm = len(eng.sched.completed)
        t0 = time.perf_counter()
        simulate(eng, workload(1000 * (p + 1)))
        done = eng.sched.completed[n_warm:]
        for r in done:                   # host work serialized, not overlapped
            callback(r)
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        best_s = max(best_s, toks / wall)

    bk = rep["buckets"]
    emit("offline_tokps", 1e6 / best_h,
         f"tok_per_s={best_h:.1f},"
         f"tok_per_s_per_chip={best_h / rep['n_chips']:.1f},"
         f"pump_max_depth={rep['overlap']['max_depth']},"
         f"pad_overhead={bk['pad_overhead']:.3f}")
    emit("offline_sync_tokps", 1e6 / best_s, f"tok_per_s={best_s:.1f}")
    emit("offline_overlap_ratio", 0,
         f"overlap_ratio={best_h / best_s:.3f},"
         f"retrace_free={int(rep['retrace_free'])}")

    # Paged-pool variant (DESIGN.md §13 x §16): the same saturation
    # pipeline over the paged, prefix-sharing pool — bucketed prefill
    # through the padded write barrier — vs the synchronous tick driver
    # on the SAME paged config and the same shared-prefix trace.  Half
    # the prompts share a two-page prefix so dedup actually fires.
    n_paged = 2 * n  # longer trace: steadier ratio, more completions
                     # for the pump to overlap against the tick driver

    def paged_workload(rid0):
        rng = np.random.default_rng(19)
        prefix = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
        reqs = []
        for i in range(n_paged):
            plen = 8 + int(rng.integers(0, 41))
            body = [int(t) for t in rng.integers(1, cfg.vocab, plen)]
            if i % 2 and plen > 16:
                body = prefix + body[16:]
            reqs.append(Request(rid=rid0 + i, prompt=body,
                                max_new=max_new, arrival=0.0))
        return reqs

    # Denser ladder than the flat harness: a dedup hit on the shared
    # two-page prefix leaves a 1..8-token remainder to prefill (the 8
    # rung — padding that to 16 doubles the prefill FLOPs on exactly
    # the requests paging makes cheap), and the page-multiple middle
    # rungs keep the worst-case pad under one page for the rest.
    pharness = OfflineInference(
        cfg, params, n_slots=4, cache_len=cache_len, prefill_chunk=chunk,
        buckets=(8, 16, 24, 32, 40, 48, 64), overlap=True, queue_size=16,
        callback=callback, page_size=8,
    )
    pharness.warmup()
    best_p, prep = 0.0, None
    for p in range(SERVE_PASSES):
        r = pharness.run(paged_workload(1000 * (p + 1)))
        if r["tok_per_s"] > best_p:
            best_p, prep = r["tok_per_s"], r
    pharness.require_steady_state()

    peng = ContinuousBatcher(cfg, params, n_slots=4, cache_len=cache_len,
                             prefill_chunk=chunk, page_size=8)
    simulate(peng, paged_workload(0))    # warmup: compile + one full pass
    [callback(r) for r in peng.sched.completed]
    best_ps = 0.0
    for p in range(SERVE_PASSES):
        n_warm = len(peng.sched.completed)
        t0 = time.perf_counter()
        simulate(peng, paged_workload(1000 * (p + 1)))
        done = peng.sched.completed[n_warm:]
        for r in done:                   # host work serialized again
            callback(r)
        wall = time.perf_counter() - t0
        best_ps = max(best_ps, sum(len(r.out) for r in done) / wall)

    pg = prep["paging"][0]
    emit("offline_paged_tokps", 1e6 / best_p,
         f"tok_per_s={best_p:.1f},"
         f"dedup_hits={pg['dedup_hits']},"
         f"pad_overhead={prep['buckets']['pad_overhead']:.3f}")
    emit("offline_paged_overlap_ratio", 0,
         f"overlap_ratio={best_p / best_ps:.3f},"
         f"retrace_free={int(prep['retrace_free'])}")


# ------------------------------------------------------------ checkpointer
CKPT_STEPS = 6


def ckpt_async():
    """Async RRNS-coded checkpointing (DESIGN.md §14): per-step wall of a
    training loop saving EVERY step through the background Checkpointer vs
    blocking ``write_step_dir`` calls — same jitted compute, same tree.
    The committed gate metric is ``overlap_ratio`` = blocking/async wall,
    best of SERVE_PASSES passes: the async critical path replaces
    encode+fsync with a host-snapshot memcpy, so the ratio must stay
    >= 1.0 on any machine where the writer thread actually overlaps
    compute.  Rows land in BENCH_ckpt.json for trend tracking."""
    import shutil
    import tempfile

    from repro.train import checkpointer as cp

    rng = np.random.default_rng(13)
    tree = {
        f"w{i}": jnp.asarray(rng.standard_normal((1 << 15,)).astype(np.float32))
        for i in range(4)
    }  # 512 KiB of state -> ~2.5 MiB RRNS wire per step
    w = jnp.asarray(rng.standard_normal((512, 512)).astype(np.float32))

    @jax.jit
    def compute(x):  # stand-in train step, sized >= one write
        for _ in range(20):
            x = jnp.tanh(x @ x)
        return x

    jax.block_until_ready(compute(w))  # compile outside the timed region

    def blocking_pass(d):
        t0 = time.perf_counter()
        for s in range(1, CKPT_STEPS + 1):
            jax.block_until_ready(compute(w))
            cp.write_step_dir(d, s, tree)
        return (time.perf_counter() - t0) / CKPT_STEPS

    def async_pass(d):
        t0 = time.perf_counter()  # includes the close() drain: total wall
        with cp.Checkpointer(d, "1", queue_size=2) as saver:
            for s in range(1, CKPT_STEPS + 1):
                jax.block_until_ready(compute(w))
                saver.maybe_save(s, tree)
        return (time.perf_counter() - t0) / CKPT_STEPS

    def best_of(passes, fn):
        best = float("inf")
        for _ in range(passes):
            d = tempfile.mkdtemp(prefix="bench_ckpt_")
            try:
                best = min(best, fn(d))
            finally:
                shutil.rmtree(d, ignore_errors=True)
        return best

    t_block = best_of(SERVE_PASSES, blocking_pass)
    t_async = best_of(SERVE_PASSES, async_pass)
    emit("ckpt_blocking_step", t_block * 1e6, f"steps={CKPT_STEPS}")
    emit("ckpt_async_step", t_async * 1e6,
         f"speedup={t_block/t_async:.2f}")
    emit("ckpt_async_ratio", 0, f"overlap_ratio={t_block/t_async:.3f}")


# ----------------------------------------------------------------- crypto
CRYPTO_REQS = 8
CRYPTO_LIMBS = 4
CRYPTO_EXP_BITS = 16


def crypto_modexp():
    """Batched RNS modexp on the serve engine (DESIGN.md §15): the crypto
    lane with 4 slots (ladder chunks interleaved across co-resident
    requests through ONE jitted step graph) vs a 1-slot lane that must
    ladder requests back to back — same graphs, same workload, every
    result checked against ``pow()``.  The committed gate metric is
    ``throughput_ratio`` = batched/solo requests-per-second, each the
    best of SERVE_PASSES timed passes (runner-noise-proof like the serve
    and ckpt gates).  Also records one dual-base Montgomery product,
    pure-jnp vs the fused Pallas kernel (interpret mode off-TPU — a
    bitwise-identity row, not a perf row).  Rows land in
    BENCH_crypto.json."""
    import math
    import random

    from repro.configs import get_config
    from repro.core import backend
    from repro.core.array import RnsArray
    from repro.core.montgomery import DualRep, mont_mul
    from repro.models import init_params
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.crypto import CryptoContext, CryptoRequest

    ctx = CryptoContext(n_limbs=CRYPTO_LIMBS, exp_bits=CRYPTO_EXP_BITS)
    cfg = get_config("gemma-2b").smoke()
    params = init_params(cfg, jax.random.key(0))
    rng = random.Random(31)
    MMp = ctx.baseB.M * ctx.baseBp.M

    def modulus():
        while True:
            N = rng.randrange(5, ctx.n_max) | 1
            if math.gcd(N, MMp) == 1:
                return N

    cases = [(lambda N: (rng.randrange(1, N),
                         rng.randrange(1 << CRYPTO_EXP_BITS), N))(modulus())
             for _ in range(CRYPTO_REQS)]
    rid = iter(range(1, 1 << 30))  # fresh rids per pass (wire keys are held)

    def run(slots):
        eng = ContinuousBatcher(cfg, params, n_slots=1, cache_len=16,
                                prefill_chunk=8, crypto_slots=slots,
                                crypto_ctx=ctx, crypto_chunk=4)

        def one_pass():
            for a, e, N in cases:
                eng.submit(CryptoRequest(rid=next(rid), op="modexp",
                                         a=a, b=e, n=N))
            t0 = time.perf_counter()
            done = eng.run_to_completion()
            wall = time.perf_counter() - t0
            for r in done:
                assert r.result == pow(r.a, r.b, r.n), r.rid
            eng.drain_completed()
            return len(done) / wall

        one_pass()                  # warmup: compile admit/step/final
        return max(one_pass() for _ in range(SERVE_PASSES))

    rps_b = run(4)
    rps_s = run(1)
    emit("crypto_modexp_batched", 1e6 / rps_b,
         f"req_per_s={rps_b:.2f},slots=4,exp_bits={CRYPTO_EXP_BITS}")
    emit("crypto_modexp_solo", 1e6 / rps_s, f"req_per_s={rps_s:.2f}")
    emit("crypto_modexp_ratio", 0,
         f"throughput_ratio={rps_b/rps_s:.3f},reqs={CRYPTO_REQS}")

    # one Montgomery product, jnp vs fused Pallas, bitwise on all channels
    N = modulus()
    c = ctx.consts_for(N)

    def dual(vals):
        lo = np.stack([ctx.encode_lo(v) for v in vals])
        hi = np.stack([ctx.encode_hi(v) for v in vals])
        return DualRep(
            RnsArray.from_packed(ctx.baseB, jnp.asarray(lo, ctx.baseB.dtype),
                                 mb=ctx.mb),
            RnsArray.from_packed(ctx.baseBp, jnp.asarray(hi, ctx.baseBp.dtype)),
        )

    Bm = 256
    x = dual([rng.randrange(2 * N) for _ in range(Bm)])
    y = dual([rng.randrange(2 * N) for _ in range(Bm)])
    neg, n_hi = jnp.asarray(c["neg"]), jnp.asarray(c["n_hi"])
    with backend("jnp"):
        f_jnp = jax.jit(lambda u, v: mont_mul(u, v, neg, n_hi).lo.to_packed())
        t_j = _time(f_jnp, x, y, iters=5)
    with backend("pallas"):
        f_pal = jax.jit(lambda u, v: mont_mul(u, v, neg, n_hi).lo.to_packed())
        t_p = _time(f_pal, x, y, iters=5)
    bitwise = bool(jnp.all(f_jnp(x, y) == f_pal(x, y)))
    emit("crypto_mont_mul_jnp", t_j, f"batch={Bm},limbs={CRYPTO_LIMBS}")
    emit("crypto_mont_mul_pallas", t_p,
         f"bitwise={bitwise},note=interpret-mode-not-perf")
    assert bitwise, "Pallas Montgomery product diverged from the jnp path"


# --------------------------------------------------------- division/scaling
def division_scaling():
    base = make_base(4, bits=8)
    rng = np.random.default_rng(5)
    X = int(rng.integers(1, base.M))
    D = int(rng.integers(1, X))
    xp = pack(base, jnp.asarray(base.residues_of(X)), jnp.asarray(X % base.ma))
    dp = pack(base, jnp.asarray(base.residues_of(D)), jnp.asarray(D % base.ma))
    f_div = jax.jit(lambda a, b: divmod_rns(base, a, b))
    q, r = f_div(xp, dp)
    ok = (rns_to_int(base, np.asarray(q[..., :-1])),
          rns_to_int(base, np.asarray(r[..., :-1]))) == divmod(X, D)
    ncmp = 2 * base.M.bit_length() + 1
    emit("divmod_rns", _time(f_div, xp, dp, iters=5),
         f"comparisons={ncmp},correct={ok}")
    f_h = jax.jit(lambda a: halve(base, a))
    emit("scale_halve", _time(f_h, xp), "exact=True")


TABLES = [
    table1_opcount,
    compare_latency,
    compare_kernel,
    mrc_parallel_depth,
    extension_methods,
    grad_codec,
    grad_codec_allreduce,
    codec_correct,
    rns_array_api,
    serve_batching,
    serve_paged,
    serve_offline,
    ckpt_async,
    crypto_modexp,
    division_scaling,
]


def main(argv=None) -> None:
    global NS, KERNEL_NS, MRC_NS, BATCH, ALLREDUCE_SIZES, EXT_TRIALS, \
        SERVE_REQS, CKPT_STEPS, CRYPTO_REQS
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_codec.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default BENCH_codec.json)")
    ap.add_argument("--json-api", default="BENCH_api.json", metavar="PATH",
                    help="with --json: where the rns_array_* rows (typed-API "
                         "dispatch overhead) are additionally written")
    ap.add_argument("--json-serve", default="BENCH_serve.json", metavar="PATH",
                    help="with --json: where the serve_* rows (continuous-"
                         "batching latency/throughput) are additionally "
                         "written")
    ap.add_argument("--json-ckpt", default="BENCH_ckpt.json", metavar="PATH",
                    help="with --json: where the ckpt_* rows (async "
                         "checkpoint overlap) are additionally written")
    ap.add_argument("--json-crypto", default="BENCH_crypto.json",
                    metavar="PATH",
                    help="with --json: where the crypto_* rows (batched "
                         "modexp lane throughput) are additionally written")
    ap.add_argument("--small", action="store_true",
                    help="CI smoke sizes: trimmed sweeps, same coverage")
    args = ap.parse_args(argv)
    if args.small:
        NS = (4, 8)
        KERNEL_NS = (4,)
        MRC_NS = (16,)
        BATCH = 256
        ALLREDUCE_SIZES = (1 << 12,)
        EXT_TRIALS = 64
        SERVE_REQS = 4
        CKPT_STEPS = 4
        CRYPTO_REQS = 4
    print("name,us_per_call,derived")
    for fn in TABLES:
        fn()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RESULTS, f, indent=1, sort_keys=True)
        print(f"# wrote {len(RESULTS)} rows to {args.json}")
        api_rows = {k: v for k, v in RESULTS.items()
                    if k.startswith("rns_array_")}
        with open(args.json_api, "w") as f:
            json.dump(api_rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(api_rows)} rows to {args.json_api}")
        # serve_* = tick-clock engine rows, offline_* = saturation-harness
        # rows (DESIGN.md §16) — one committed trajectory file for both
        serve_rows = {k: v for k, v in RESULTS.items()
                      if k.startswith(("serve_", "offline_"))}
        with open(args.json_serve, "w") as f:
            json.dump(serve_rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(serve_rows)} rows to {args.json_serve}")
        ckpt_rows = {k: v for k, v in RESULTS.items()
                     if k.startswith("ckpt_")}
        with open(args.json_ckpt, "w") as f:
            json.dump(ckpt_rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(ckpt_rows)} rows to {args.json_ckpt}")
        crypto_rows = {k: v for k, v in RESULTS.items()
                       if k.startswith("crypto_")}
        with open(args.json_crypto, "w") as f:
            json.dump(crypto_rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(crypto_rows)} rows to {args.json_crypto}")


if __name__ == "__main__":
    main()
