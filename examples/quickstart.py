"""Quickstart: the paper's RNS comparison in five minutes — typed API.

Everything goes through ``RnsArray`` (repro.core.array): ONE type carrying
residues + the redundant m_a channel, with the paper's algorithms as
methods and operators.  Backend selection (pure jnp vs the fused Pallas
kernels) is a context manager, not per-call knobs.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core import (
    Layout,
    RnsArray,
    backend,
    classic_compare_ge,
    make_base,
    rns_to_int,
)

# 1. Build an RNS base: 8 15-bit prime moduli + a redundant modulus m_a.
base = make_base(8, bits=15)
print(f"base: n={base.n} moduli, dynamic range M ~ 2^{base.M.bit_length()}, "
      f"m_a={base.ma}")

# 2. Lift two big integers into the representation.  ``encode`` computes the
#    residue channels AND the consistent redundant m_a channel in one go.
rng = np.random.default_rng(0)
N1 = int(rng.integers(0, 1 << 63)) % base.M
N2 = int(rng.integers(0, 1 << 63)) % base.M
a = RnsArray.encode(base, jnp.asarray([N1]))
b = RnsArray.encode(base, jnp.asarray([N2]))
print(f"layout={a.layout.name}, channels={a.n_channels} "
      f"(n base + m_a riding along)")

# 3. Compare with ONE mixed-radix conversion (Algorithm 1 / Theorem 1).
ge = bool((a >= b)[0])
print(f"N1 >= N2?  RNSComp says {ge}, truth is {N1 >= N2}")
assert ge == (N1 >= N2)

# 4. The classical method needs TWO conversions (the paper's baseline).
assert bool(classic_compare_ge(base, a.x, b.x)[0]) == (N1 >= N2)

# 5. Arithmetic stays exact and in-representation; division and scaling are
#    comparison-driven (the operations the paper's conclusion unlocks).
small = make_base(4, bits=8)
x = RnsArray.encode(small, jnp.asarray([100_000, 54_321]))
d = RnsArray.encode(small, jnp.asarray([317, 1000]))
q, r = x.divmod(d)
assert q.to_int().tolist() == [100_000 // 317, 54]
assert r.to_int().tolist() == [100_000 % 317, 321]
print(f"divmod in pure RNS: 100000 = {int(q.to_int()[0])}*317 "
      f"+ {int(r.to_int()[0])} ✓")
assert x.scale_pow2(3).to_int().tolist() == [100_000 // 8, 54_321 // 8]

# 6. Batched + fused on TPU: the SAME call sites, under the pallas backend
#    (off-TPU the kernels run in interpret mode — same bits, slower).
batch = 4096
m = np.asarray(base.moduli_np)
xs1 = rng.integers(0, m, size=(batch, base.n)).astype(np.int32)
xs2 = rng.integers(0, m, size=(batch, base.n)).astype(np.int32)
lift = lambda xs: RnsArray.from_parts(base, jnp.asarray(xs)).normalize(
    Layout.BASE_MA)                 # BASE -> BASE_MA: compute m_a channel
A, B = lift(xs1), lift(xs2)
with backend("pallas"):
    verdicts = A >= B           # fused Algorithm-1 kernel
vals1 = [rns_to_int(base, row) for row in xs1]   # host-side big-int oracle
vals2 = [rns_to_int(base, row) for row in xs2]
truth = np.asarray(vals1) >= np.asarray(vals2)
assert (np.asarray(verdicts) == truth).all()
jnp_verdicts = A >= B           # default backend: jitted jnp route
assert (np.asarray(verdicts) == np.asarray(jnp_verdicts)).all()
print(f"fused Pallas kernel: {batch} comparisons, all correct and "
      f"bitwise-identical to the jnp backend ✓")
