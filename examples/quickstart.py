"""Quickstart: the paper's RNS comparison in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

import repro  # noqa: F401  (enables x64)
from repro.core import make_base, rns_compare_ge, classic_compare_ge, rns_to_int
from repro.kernels import compare_op

# 1. Build an RNS base: 8 15-bit prime moduli + a redundant modulus m_a.
base = make_base(8, bits=15)
print(f"base: n={base.n} moduli, dynamic range M ~ 2^{base.M.bit_length()}, "
      f"m_a={base.ma}")

# 2. Represent two big integers as residue vectors (+ redundant residues).
rng = np.random.default_rng(0)
N1 = int(rng.integers(0, 1 << 63)) % base.M
N2 = int(rng.integers(0, 1 << 63)) % base.M
x1, x2 = jnp.asarray(base.residues_of(N1)), jnp.asarray(base.residues_of(N2))
a1, a2 = jnp.asarray(N1 % base.ma), jnp.asarray(N2 % base.ma)

# 3. Compare with ONE mixed-radix conversion (Algorithm 1 / Theorem 1).
ge = bool(rns_compare_ge(base, x1, a1, x2, a2))
print(f"N1 >= N2?  RNSComp says {ge}, truth is {N1 >= N2}")
assert ge == (N1 >= N2)

# 4. The classical method needs TWO conversions (the paper's baseline).
assert bool(classic_compare_ge(base, x1, x2)) == (N1 >= N2)

# 5. Batched + fused on TPU (interpret=True runs the same kernel on CPU).
batch = 4096
m = np.asarray(base.moduli_np)
xs1 = rng.integers(0, m, size=(batch, base.n)).astype(np.int32)
xs2 = rng.integers(0, m, size=(batch, base.n)).astype(np.int32)
vals1 = [rns_to_int(base, r) for r in xs1]
vals2 = [rns_to_int(base, r) for r in xs2]
as1 = np.asarray([v % base.ma for v in vals1], np.int32)
as2 = np.asarray([v % base.ma for v in vals2], np.int32)
verdicts = compare_op(
    base, jnp.asarray(xs1), jnp.asarray(as1), jnp.asarray(xs2),
    jnp.asarray(as2), interpret=True,
)
truth = np.asarray(vals1) >= np.asarray(vals2)
assert (np.asarray(verdicts) == truth).all()
print(f"fused Pallas kernel: {batch} comparisons, all correct ✓")
