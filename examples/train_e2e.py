"""End-to-end training driver evidence: a mid-size decoder (≈27M params)
trained for 300 steps on the learnable synthetic stream, with periodic
fingerprinted checkpoints — the CPU-scale stand-in for the assignment's
"train a ~100M model for a few hundred steps" driver (the same code path
pjit-shards on the production mesh; see launch/train.py / dryrun.py).

    PYTHONPATH=src python examples/train_e2e.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_config
from repro.models import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

STEPS = 300
cfg = dataclasses.replace(
    get_config("llama3.2-3b").smoke(),
    n_layers=8, d_model=384, n_heads=6, n_kv=2, head_dim=64, d_ff=1024,
    vocab=8192,
)
cfg.validate()
params = init_params(cfg, jax.random.key(0))
n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
print(f"model: {n_params/1e6:.1f}M params, {cfg.n_layers}L d={cfg.d_model}")

opt_cfg = AdamWConfig(lr=6e-4, warmup=20, decay_steps=STEPS, weight_decay=0.01)
opt = adamw_init(params)
step_fn = jax.jit(make_train_step(cfg, opt_cfg))
loader = SyntheticLM(cfg, seq=128, batch=8, pattern="arith")
pf = Prefetcher(loader)
t0 = time.time()
try:
    for _ in range(STEPS):
        s, batch = pf.next()
        params, opt, m = step_fn(
            params, opt, jax.tree_util.tree_map(jnp.asarray, batch)
        )
        if s % 25 == 0 or s == STEPS - 1:
            print(f"step {s:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['gnorm']):.3f} "
                  f"({(time.time()-t0)/(s+1)*1e3:.0f} ms/step)")
        if (s + 1) % 100 == 0:
            ckpt.save("/tmp/train_e2e_ck", s + 1, {"params": params, "opt": opt})
finally:
    pf.close()
final = float(m["loss"])
print(f"final loss {final:.4f} (init ~ln({cfg.vocab})={jnp.log(cfg.vocab):.2f})")
assert final < 3.0, "expected large loss reduction on the arithmetic stream"
print("trained 300 steps with periodic fingerprinted checkpoints ✓")
