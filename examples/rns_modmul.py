"""Crypto-scale demo: dual-base RNS Montgomery multiplication with the
paper's comparison — the paper's own motivating context (§1, §3.1).

A ~1000-bit modular exponentiation runs entirely in RNS: products via
Montgomery multiplication (base extension = exact MRC), and the final
comparison/normalization via Algorithm 1, whose redundant modulus m_a is a
modulus of the SECOND base B' — "readily available", as the paper argues.

    PYTHONPATH=src python examples/rns_modmul.py
"""
import time

import numpy as np

import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs.paper_rns import make_paper_bases
from repro.core import RNSMontgomery, RnsArray, rns_to_int

B, Bp = make_paper_bases()
print(f"base B : n={B.n} x {B.bits}-bit moduli  (M ~ 2^{B.M.bit_length()})")
print(f"base B': n={Bp.n} (supplies the redundant modulus m_a={B.ma})")

rng = np.random.default_rng(0)
# an odd ~1000-bit modulus N with M > 4N
N = (int(rng.integers(1, 1 << 62)) << 940) | int(rng.integers(1, 1 << 62)) | 1
mont = RNSMontgomery(B, Bp, N)

X = int(rng.integers(0, 1 << 63)) % N
E = 0b101101  # exponent

# Montgomery ladder pieces: to Montgomery domain, square/multiply, back.
R = B.M % N
xm = mont.to_dual(X * R % N)
acc = mont.to_dual(R)  # 1 in Montgomery domain

t0 = time.time()
for bit in bin(E)[2:]:
    acc = mont.mul(acc, acc)
    if bit == "1":
        acc = mont.mul(acc, xm)
one = mont.to_dual(1)
result = mont.mul(acc, one)  # leave Montgomery domain
got = rns_to_int(B, np.asarray(result.xB)) % N
dt = time.time() - t0
want = pow(X, E, N)
assert got == want, "modular exponentiation mismatch"
print(f"X^{E} mod N correct over {B.M.bit_length()}-bit RNS "
      f"({dt*1e3:.0f} ms incl. host conversions) ✓")

# Final-normalization comparison WITHOUT leaving RNS: result < N ?
# The Montgomery result's residues lift into the typed RnsArray frontend;
# the m_a channel would be carried alongside in a real pipeline (it is a
# modulus of B', "readily available" per the paper) — here we attach it
# via from_parts and compare with the overloaded operator.
r_arr = RnsArray.from_parts(B, result.xB, jnp.asarray(got % B.ma))
# N is ~1000 bits (beyond any tensor dtype), so lift its residues exactly
# from the host side:
n_arr = RnsArray.from_parts(
    B, jnp.asarray(B.residues_of(N)), jnp.asarray(N % B.ma)
)
needs_sub = bool(r_arr >= n_arr)
print(f"Algorithm-1 comparison (result >= N): {needs_sub} "
      f"(truth: {got >= N}) ✓")
assert needs_sub == (got >= N)
