"""Train a small LM with the paper's RNS-exact gradient aggregation and
verify the loss trajectory matches plain fp32 all-reduce.

The gradients are quantized to fixed point, encoded into residue channels,
psum'd per channel (exact ring homomorphism), and decoded — with sign and
clip decisions available through Algorithm-1 comparisons WITHOUT
reconstruction (repro/dist/grad_codec.py).

    PYTHONPATH=src python examples/rns_gradient_training.py
"""
import numpy as np

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_config
from repro.dist.grad_codec import GradCodec
from repro.launch.train import make_rns_dp_step
from repro.models import init_params
from repro.train.data import SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

STEPS = 45
cfg = get_config("llama3.2-3b").smoke()
opt_cfg = AdamWConfig(lr=1e-3, warmup=5, decay_steps=STEPS, weight_decay=0.0)
codec = GradCodec.make(world=8)
print(f"codec: {codec.base.n}+1 channels of 15-bit moduli, "
      f"M ~ 2^{codec.base.M.bit_length()}, quant step 2^-{codec.frac_bits}")

rns_step, ndev = make_rns_dp_step(cfg, opt_cfg, codec)
fp_step = jax.jit(make_train_step(cfg, opt_cfg))
loader = SyntheticLM(cfg, seq=32, batch=8, pattern="arith")


def run(step_fn):
    params = init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    losses = []
    for s in range(STEPS):
        batch = jax.tree_util.tree_map(jnp.asarray, loader.batch_at(s))
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses


l_rns = run(rns_step)
l_fp = run(fp_step)
print(f"{'step':>4} {'rns_loss':>9} {'fp32_loss':>9}")
for i in range(0, STEPS, 4):
    print(f"{i:4d} {l_rns[i]:9.4f} {l_fp[i]:9.4f}")
drift = max(abs(a - b) for a, b in zip(l_rns, l_fp))
print(f"max |loss drift| over {STEPS} steps: {drift:.4f}")
assert drift < 0.05, "RNS aggregation diverged from fp32"
assert l_rns[-1] < l_rns[0] - 1.0, "did not learn"
print("RNS-aggregated training matches fp32 and learns ✓")
