"""Full-range differential oracle: the paper's "full range, no special
moduli" claim as an executable hypothesis program.

Every RNS op with an integer meaning is differential-tested against
Python's native big ints at ADVERSARIAL points of the dynamic range —
0/1, the +-M/2 signed boundary, the M-1 wrap edge, equal-value pairs —
over randomly drawn moduli sets with no special form (odd, pairwise
coprime, not 2^k or 2^k +- 1), at ranges from 60 to 270 bits (past the
256-bit crypto floor, far past int64).  No tier-1 test reaches these
points: the seeded suites stay on make_base's fixed prime ladders and
int64-encodable values.

Structure notes for the compile budget: bases live in module-level pools
(one jitted graph per base per op, cached), every jitted call keeps a
fixed batch shape, and values are drawn per example — so 200 examples
per op cost 200 device calls, not 200 traces.
"""
import functools
import random

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import RNSBase, RnsArray, make_base, rns_to_int  # noqa: E402

B = 8            # value pairs per example (fixed shape -> one trace/base)
EXAMPLES = 200   # the ISSUE's acceptance floor, per op


def _random_base(seed: int, n: int, bits: int = 15) -> RNSBase:
    """n random pairwise-coprime NON-SPECIAL moduli + a coprime m_a: odd,
    composite allowed, never 2^k or 2^k +- 1 — the paper's "no special
    form" setting, where CRT shortcuts for friendly moduli cannot hide."""
    rng = random.Random(seed)
    special = {1 << k for k in range(bits + 1)}
    special |= {v + 1 for v in special} | {v - 1 for v in special}
    ms: list[int] = []
    while len(ms) < n + 1:
        c = rng.randrange(3, 1 << bits) | 1
        if c in special:
            continue
        from math import gcd

        if all(gcd(c, m) == 1 for m in ms):
            ms.append(c)
    return RNSBase(moduli=tuple(ms[:n]), ma=ms[n], bits=bits)


# 60 to 270 bits of dynamic range; the last base crosses the 256-bit
# floor of the crypto workloads (ISSUE 8 tentpole).
POOL = [
    _random_base(11, 4),
    _random_base(23, 6),
    _random_base(37, 10),
    _random_base(59, 20),
]
SMALL = make_base(3, bits=15)     # M < 2**62: the to_int contract's range


def _encode(base: RNSBase, vals: list[int]) -> RnsArray:
    """Host big-int encode (RnsArray.encode is int64-bound by design):
    exact residues per channel + the m_a channel, lifted as BASE_MA."""
    rows = [list(base.residues_of(v)) + [v % base.ma] for v in vals]
    return RnsArray.from_packed(base, jnp.asarray(rows, base.dtype))


def _edge_points(M: int) -> list[int]:
    h = M // 2
    return [0, 1, 2, h - 1, h, h + 1, M - 2, M - 1]


def _value(draw, M: int) -> int:
    """One full-range value: half the draws land on an edge point."""
    if draw(st.booleans()):
        return draw(st.sampled_from(_edge_points(M)))
    return draw(st.integers(0, M - 1))


@functools.lru_cache(maxsize=None)
def _compare_fn(bi: int):
    base = POOL[bi]

    def f(xp, yp):
        return RnsArray.from_packed(base, xp).compare_ge(
            RnsArray.from_packed(base, yp))

    return jax.jit(f)


@settings(max_examples=EXAMPLES, deadline=None)
@given(st.data())
def test_compare_ge_full_range_oracle(data):
    """Theorem 1 at the wrap edges and the M/2 boundary, on non-special
    moduli: self >= other must equal the big-int >= at EVERY point of
    [0, M) — including equal pairs, where approximate CRT comparison is
    known to break."""
    bi = data.draw(st.integers(0, len(POOL) - 1))
    base = POOL[bi]
    xs = [_value(data.draw, base.M) for _ in range(B)]
    ys = [_value(data.draw, base.M) for _ in range(B)]
    eq_at = data.draw(st.integers(0, B - 1))
    ys[eq_at] = xs[eq_at]  # force at least one equal pair per example
    got = np.asarray(_compare_fn(bi)(
        _encode(base, xs).to_packed(), _encode(base, ys).to_packed()))
    want = np.asarray([x >= y for x, y in zip(xs, ys)])
    np.testing.assert_array_equal(got, want)


@functools.lru_cache(maxsize=None)
def _divmod_fn(bi: int):
    base = POOL[bi]

    def f(xp, dp):
        q, r = RnsArray.from_packed(base, xp).divmod(
            RnsArray.from_packed(base, dp))
        return q.to_packed(), r.to_packed()

    return jax.jit(f)


@settings(max_examples=EXAMPLES, deadline=None)
@given(st.data())
def test_divmod_full_range_oracle(data):
    """Restoring division (2*nbits+1 Alg.-1 comparisons) == Python's
    divmod over the whole range, divisors from 1 to M-1 including
    d > x, d == x, and powers of two."""
    bi = data.draw(st.integers(0, 1))   # 60- and 90-bit ranges
    base = POOL[bi]
    xs = [_value(data.draw, base.M) for _ in range(B)]
    ds = []
    for i in range(B):
        kind = data.draw(st.integers(0, 3))
        if kind == 0:
            ds.append(data.draw(st.sampled_from(
                [1, 2, base.M - 1, base.M // 2])))
        elif kind == 1:
            ds.append(1 << data.draw(st.integers(0, base.M.bit_length() - 1)))
        elif kind == 2:
            ds.append(max(1, xs[i]))    # d == x (quotient exactly 1)
        else:
            ds.append(data.draw(st.integers(1, base.M - 1)))
    qp, rp = _divmod_fn(bi)(
        _encode(base, xs).to_packed(), _encode(base, ds).to_packed())
    qp, rp = np.asarray(qp), np.asarray(rp)
    for i in range(B):
        q = rns_to_int(base, qp[i, : base.n])
        r = rns_to_int(base, rp[i, : base.n])
        assert (q, r) == divmod(xs[i], ds[i]), (xs[i], ds[i])


@settings(max_examples=EXAMPLES, deadline=None)
@given(st.data())
def test_to_int_signed_boundary_oracle(data):
    """Signed decode at the +-(M-1)//2 embedding boundary: encode_signed
    -> to_int must round-trip exactly where v and v + M collide mod M."""
    M = SMALL.M
    half = (M - 1) // 2
    vals = []
    for _ in range(B):
        if data.draw(st.booleans()):
            vals.append(data.draw(st.sampled_from(
                [-half, -half + 1, -1, 0, 1, half - 1, half])))
        else:
            vals.append(data.draw(st.integers(-half, half)))
    arr = RnsArray.encode_signed(SMALL, jnp.asarray(vals, jnp.int64))
    assert arr.to_int().tolist() == vals


@functools.lru_cache(maxsize=None)
def _extend_fn(bi: int, targets: tuple):
    base = POOL[bi]
    return jax.jit(
        lambda xp: RnsArray.from_packed(base, xp).extend(targets))


@settings(max_examples=EXAMPLES, deadline=None)
@given(st.data())
def test_extend_full_range_oracle(data):
    """Exact MRC base extension == v mod t for arbitrary coprime AND
    non-coprime targets, at the wrap edges — the hop every dual-base
    Montgomery product rides twice."""
    bi = data.draw(st.integers(0, len(POOL) - 1))
    base = POOL[bi]
    other = POOL[(bi + 1) % len(POOL)]
    # targets: another pool base's channels + small non-coprime odds
    targets = tuple(other.moduli[:3]) + (3, 255, (1 << 15) - 19)
    xs = [_value(data.draw, base.M) for _ in range(B)]
    got = np.asarray(_extend_fn(bi, targets)(_encode(base, xs).to_packed()))
    want = np.asarray([[v % t for t in targets] for v in xs])
    np.testing.assert_array_equal(got, want)
