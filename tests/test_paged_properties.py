"""Property-based paged-serve state machines (hypothesis.stateful).

Two machines drive the HOST side of the paged pool — no model, no device
arrays — through random operation interleavings:

* ``PageAllocatorMachine`` mirrors ``PageAllocator`` against an exact
  shadow model (free-list order, refcounts, LRU retention order), so
  alloc/ref/revive/deref/adopt sequences must reproduce the model's
  predictions bit-for-bit — including WHICH page an eviction recycles;
* ``PagedServeMachine`` interleaves submit / admit / chunked + bucketed
  prefill (with the scratch-page dance of the padded write barrier) /
  decode / early-EOS retirement / warm-restart adoption on a pool small
  enough to force deferrals and evictions, checking global invariants
  after every step: page conservation (free / retained / referenced
  partition the pool), refcounts equal table mappings, registered pages
  are never free (no resurrected pid), and every registered page with
  no readers is parked in the retained LRU.

Requires the optional ``hypothesis`` dev dependency (requirements-dev
.txt); skips cleanly when absent.  The CI ``soak`` job raises the
example budget via ``HYPOTHESIS_PROFILE=soak``.
"""
import os
from collections import Counter

import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt",
)

from hypothesis import HealthCheck, settings, strategies as st
from hypothesis.stateful import RuleBasedStateMachine, invariant, rule

import repro  # noqa: F401
from repro.serve.scheduler import (
    DECODE,
    PREFILL,
    PageAllocator,
    PagedScheduler,
    Request,
)

settings.register_profile(
    "default", max_examples=20, stateful_step_count=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.register_profile(
    "soak", max_examples=150, stateful_step_count=100, deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))


class PageAllocatorMachine(RuleBasedStateMachine):
    """Exact-mirror model of the allocator: every transition's return
    value AND the full (free order, refcounts, LRU order) state must
    match the shadow model, so LRU-retention misorderings or free-list
    corruption fail on the precise step that introduced them."""

    N = 8

    def __init__(self):
        super().__init__()
        self.al = PageAllocator(self.N)
        self.free = list(range(self.N - 1, 0, -1))  # pop() -> lowest pid
        self.rc = {p: 0 for p in range(1, self.N)}
        self.lru: list[int] = []  # retained, LRU first

    @rule()
    def alloc(self):
        if not (self.free or self.lru):
            return  # pool dry: reservation gating forbids alloc here
        pid, evicted = self.al.alloc()
        if self.free:
            assert (pid, evicted) == (self.free.pop(), False)
        else:
            assert (pid, evicted) == (self.lru.pop(0), True)  # LRU victim
        self.rc[pid] = 1

    @rule(data=st.data())
    def ref(self, data):
        live = [p for p, c in self.rc.items() if c > 0]
        if not live:
            return
        p = data.draw(st.sampled_from(live))
        self.al.ref(p)
        self.rc[p] += 1

    @rule(data=st.data())
    def revive(self, data):
        if not self.lru:
            return
        p = data.draw(st.sampled_from(self.lru))
        self.al.ref(p)  # retained -> active: leaves the evictable set
        self.lru.remove(p)
        self.rc[p] = 1

    @rule(data=st.data(), retain=st.booleans())
    def deref(self, data, retain):
        live = [p for p, c in self.rc.items() if c > 0]
        if not live:
            return
        p = data.draw(st.sampled_from(live))
        disp = self.al.deref(p, retain=retain)
        self.rc[p] -= 1
        if self.rc[p] > 0:
            assert disp == "shared"
        elif retain:
            assert disp == "retained"
            self.lru.append(p)  # parks at the MRU end
        else:
            assert disp == "freed"
            self.free.append(p)  # LIFO reuse

    @rule(data=st.data())
    def adopt(self, data):
        if not self.free:
            return
        p = data.draw(st.sampled_from(self.free))
        self.al.adopt_retained(p)
        self.free.remove(p)
        self.lru.append(p)

    @invariant()
    def mirrors_model(self):
        assert list(self.al.free) == self.free
        assert list(self.al.retained) == self.lru
        assert [self.al.refcount[p] for p in range(1, self.N)] == [
            self.rc[p] for p in range(1, self.N)]
        assert self.al.in_use == sum(1 for c in self.rc.values() if c > 0)
        assert self.al.available == len(self.free) + len(self.lru)


class PagedServeMachine(RuleBasedStateMachine):
    """Random interleavings over a live ``PagedScheduler``: 3 slots over
    an 8-usable-page pool (worst-case single request needs 5 units), so
    admissions defer, retained prefixes get evicted, and bucketed
    prefills race chunked ones across slots."""

    CACHE_LEN, PAGE, CHUNK = 32, 8, 8
    BUCKETS = (8, 16, 32)

    def __init__(self):
        super().__init__()
        self.s = PagedScheduler(
            3, self.CACHE_LEN, page_size=self.PAGE, n_pages=9,
            prefill_chunk=self.CHUNK, prefill_buckets=self.BUCKETS,
        )
        self.rid = 0
        self.adopt_tok = 1000  # unique tokens: adopted chains never collide
        self.prefill_pos: dict[int, int] = {}  # slot index -> next start
        self.retired: set = set()

    @rule(data=st.data())
    def submit(self, data):
        if len(self.s.queue) >= 4:
            return  # bounded backlog keeps runs converging
        plen = data.draw(st.integers(1, 24))
        max_new = data.draw(st.integers(1, min(6, self.CACHE_LEN - plen)))
        prompt = data.draw(
            st.lists(st.integers(1, 3), min_size=plen, max_size=plen))
        eos = data.draw(st.sampled_from([-1, 2]))  # early-EOS coverage
        self.s.submit(Request(rid=self.rid, prompt=prompt, max_new=max_new,
                              eos=eos))
        self.rid += 1

    @rule()
    def admit(self):
        slot = self.s.admit_next()
        if slot is not None:
            self.prefill_pos[slot.index] = slot.prefill_start

    @rule(data=st.data(), bucketed=st.booleans())
    def prefill(self, data, bucketed):
        slots = [sl for sl in self.s.slots if sl.state == PREFILL]
        if not slots:
            return
        slot = data.draw(st.sampled_from(slots))
        prompt = [int(t) for t in slot.req.prompt]
        plen = len(prompt)
        start = self.prefill_pos[slot.index]
        need = plen - start
        if bucketed and self.s.bucket_for(need) is not None:
            # the padded-bucket path: one barrier over the whole tail,
            # pads absorbed by a transient scratch page
            self.s.plan_write(slot, start, need)
            pid, _ = self.s.alloc_scratch(slot)
            assert pid not in self.s.table[slot.index]
            self.s.free_scratch(pid)
            start = plen
        else:
            # the chunk loop writes its chunk-grid pads THROUGH the table
            self.s.plan_write(slot, start, self.CHUNK)
            start += self.CHUNK
        self.prefill_pos[slot.index] = start
        if start >= plen:
            self.s.register_prompt(slot, prompt)
            first = data.draw(st.integers(1, 3))
            idx = slot.index
            if self.s.start_decode(slot, first):
                self._retire(idx)

    @rule(data=st.data())
    def decode(self, data):
        slots = self.s.decoding_slots()
        if not slots:
            return
        slot = data.draw(st.sampled_from(slots))
        self.s.plan_write(slot, slot.next_pos, 1)
        self.s.advance(slot)
        idx = slot.index
        if self.s.record_token(slot, data.draw(st.integers(1, 3))):
            self._retire(idx)

    @rule(data=st.data(), depth=st.integers(1, 2))
    def warm_adopt(self, data, depth):
        """Restore-time seeding: free pages become retained registry
        chains, parents first — exactly the state release left them in a
        previous process."""
        parent = None
        for _ in range(depth):
            if not self.s.alloc.free:
                return
            pid = data.draw(st.sampled_from(list(self.s.alloc.free)))
            toks = tuple(range(self.adopt_tok, self.adopt_tok + self.PAGE))
            self.adopt_tok += self.PAGE
            self.s.adopt_page(pid, parent, toks)
            parent = pid

    def _retire(self, slot_index):
        req = self.s.completed[-1]
        assert req.rid not in self.retired  # no resurrected request
        self.retired.add(req.rid)
        self.s.release_pages(slot_index)

    @invariant()
    def pool_is_conserved(self):
        al = self.s.alloc
        free, retained = set(al.free), set(al.retained)
        assert free.isdisjoint(retained)
        for p in range(1, al.n_pages):
            rc = al.refcount[p]
            assert rc >= 0
            if p in free or p in retained:
                assert rc == 0
            elif rc == 0:
                pytest.fail(f"page {p} orphaned: rc 0, not free/retained")
        assert al.in_use == sum(
            1 for p in range(1, al.n_pages) if al.refcount[p] > 0)

    @invariant()
    def refcounts_equal_table_mappings(self):
        # no scratch page is live between rules, so every reference is a
        # table mapping (shared pages count once per reader row)
        cnt = Counter(pid for row in self.s.table for pid in row if pid)
        for p in range(1, self.s.alloc.n_pages):
            assert self.s.alloc.refcount[p] == cnt.get(p, 0), f"page {p}"

    @invariant()
    def registry_and_retention_agree(self):
        al, reg = self.s.alloc, self.s.registry
        registered = set(reg.by_pid)
        assert registered.isdisjoint(al.free)  # no resurrected pid
        # a registered page with no readers is always parked retained;
        # the converse is deliberately false — subtree-dropped
        # descendants of an evicted parent linger retained (unreachable,
        # evictable) until the pool recycles them
        assert {p for p in registered if al.refcount[p] == 0} <= set(
            al.retained)
        # registry coherence: nodes and the pid index describe each other
        assert registered == set(reg.nodes.values())

    @invariant()
    def reservations_match_slots(self):
        assert self.s.alloc.reserved == sum(
            sl.reserved_left for sl in self.s.slots)
        assert self.s.alloc.reserved <= self.s.alloc.available


TestPageAllocator = PageAllocatorMachine.TestCase
TestPagedServe = PagedServeMachine.TestCase
