"""Guard the dry-run deliverable: every runnable (arch x shape x mesh) cell
has a recorded artifact, every cell fits HBM (TPU-adjusted), and the
roofline terms are present and positive.
"""
import json
import os

import pytest

from repro.configs import ALIASES, get_config, shape_cells

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(DRYRUN), reason="dry-run artifacts not generated"
)


def _cells():
    for arch in ALIASES:
        for shape in shape_cells(get_config(arch)):
            for mesh in ("single", "multi"):
                yield arch, shape, mesh


@pytest.mark.parametrize("arch,shape,mesh", list(_cells()))
def test_cell_recorded_and_fits(arch, shape, mesh):
    path = os.path.join(DRYRUN, f"{arch}__{shape}__{mesh}.json")
    assert os.path.exists(path), f"missing dry-run cell {arch}/{shape}/{mesh}"
    with open(path) as f:
        r = json.load(f)
    assert r["devices"] == (512 if mesh == "multi" else 256)
    t = r["roofline"]
    assert t["memory_s"] > 0
    assert t["bottleneck"] in ("compute", "memory", "collective")
    assert r["memory"]["fits_hbm"], f"{arch}/{shape}/{mesh} over HBM"
    if r["kind"] == "train":
        assert 0 < r["useful_flops_ratio"] <= 1.5
