"""Docs health: doctests over the documented public APIs, and README /
DESIGN.md relative links that actually resolve.  The CI docs job runs this
file plus ``pytest --doctest-modules`` over the same modules; keeping it in
tier-1 means a broken example or dead link fails locally too.
"""
import doctest
import importlib
import os
import re

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# The modules whose docstrings carry runnable examples (layouts, the x64
# requirement, fused-fallback conditions, the RRNS repair API, the serve
# engine's admission/retirement loop).  Resolved via importlib: package
# __init__ re-exports shadow same-named submodule attributes
# (repro.core.mrc the module vs mrc the function).
DOCTEST_MODULES = (
    "repro.dist.grad_codec",
    "repro.core.array",
    "repro.core.dispatch",
    "repro.core.mrc",
    "repro.core.extend",
    "repro.serve.scheduler",
    "repro.serve.batcher",
    "repro.serve.crypto",
    "repro.core.montgomery",
    "repro.train.checkpointer",
)


@pytest.mark.parametrize("name", DOCTEST_MODULES)
def test_doctests(name):
    result = doctest.testmod(importlib.import_module(name), verbose=False)
    assert result.attempted > 0, f"{name} lost its doctest examples"
    assert result.failed == 0


_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


@pytest.mark.parametrize("doc", ["README.md", "DESIGN.md"])
def test_relative_links_resolve(doc):
    with open(os.path.join(ROOT, doc)) as f:
        targets = _MD_LINK.findall(f.read())
    if doc == "README.md":
        assert targets, "README.md lost its navigation links"
    missing = []
    for t in targets:
        if t.startswith(("http://", "https://", "mailto:")):
            continue
        t = t.split("#", 1)[0]
        if t and not os.path.exists(os.path.join(ROOT, t)):
            missing.append(t)
    assert not missing, f"{doc} has broken relative links: {missing}"
