"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train-loss / prefill+decode step on CPU; asserts shapes and finiteness.

Full configs are exercised only via the allocation-free dry-run
(launch/dryrun.py); these tests prove the family code paths are sound.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import decode_step, init_params, prefill, train_logits

B, S = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S), dtype=np.int32)
        )
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model), dtype=np.float32)
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model), dtype=np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward(arch):
    cfg = get_config(arch).smoke().validate()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.key(0))
    logits, aux = jax.jit(lambda p, b: train_logits(cfg, p, b))(params, _batch(cfg, rng))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).smoke().validate()
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.key(1))
    prefix = cfg.n_patches if cfg.family == "vlm" else 0
    s_total = S + prefix  # vlm caches cover the patch prefix too
    cache_len = s_total + 4
    last, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, cache_len)
    )(params, _batch(cfg, rng))
    assert last.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(last.astype(jnp.float32)).all())

    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(s_total))
    )(params, cache, tok)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["len"]) == s_total + 1


def test_decode_matches_prefill_on_dense():
    """Consistency: decoding token s with a cache built from tokens[:s] must
    reproduce the training forward's logits at position s (dense arch)."""
    cfg = get_config("gemma-2b").smoke().validate()
    rng = np.random.default_rng(2)
    params = init_params(cfg, jax.random.key(2))
    batch = _batch(cfg, rng)
    full_logits, _ = train_logits(cfg, params, batch)

    prompt = {"tokens": batch["tokens"][:, : S - 1]}
    # pad prompt to chunk boundary is not needed (S-1=31 < q_chunk)
    _, cache = prefill(cfg, params, prompt, cache_len=S + 4)
    logits, _ = decode_step(
        cfg, params, cache, batch["tokens"][:, S - 1 :], jnp.int32(S - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_ssm_decode_matches_forward():
    """Same consistency check for the SSD recurrence (chunked vs stepwise)."""
    cfg = get_config("mamba2-370m").smoke().validate()
    rng = np.random.default_rng(3)
    params = init_params(cfg, jax.random.key(3))
    batch = _batch(cfg, rng)
    full_logits, _ = train_logits(cfg, params, batch)

    prompt = {"tokens": batch["tokens"][:, : S - 16]}  # chunk multiple (16)
    _, cache = prefill(cfg, params, prompt, cache_len=S)
    logits, cache = decode_step(
        cfg, params, cache, batch["tokens"][:, S - 16 : S - 15], jnp.int32(S - 16)
    )
    # step a few more tokens and compare the last
    for i in range(S - 15, S):
        logits, cache = decode_step(
            cfg, params, cache, batch["tokens"][:, i : i + 1], jnp.int32(i)
        )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_kv_quant_decode_close_to_bf16():
    """int8 KV cache: decode logits stay close to the unquantized path."""
    import dataclasses

    cfg = get_config("gemma-7b").smoke().validate()
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    rng = np.random.default_rng(5)
    params = init_params(cfg, jax.random.key(5))
    batch = _batch(cfg, rng)

    _, cache = prefill(cfg, params, batch, cache_len=S + 4)
    _, qcache = prefill(qcfg, params, batch, cache_len=S + 4)
    assert qcache["k"].dtype == jnp.int8

    tok = batch["tokens"][:, :1]
    l1, _ = decode_step(cfg, params, cache, tok, jnp.int32(S))
    l2, _ = decode_step(qcfg, params, qcache, tok, jnp.int32(S))
    # int8 quantization error is small relative to logit scale
    denom = float(jnp.std(l1.astype(jnp.float32)))
    err = float(jnp.max(jnp.abs(l1.astype(jnp.float32) - l2.astype(jnp.float32))))
    assert err < 0.15 * max(denom, 1.0), (err, denom)


def test_windowed_ring_cache_matches_forward():
    """gemma3-style grouped window cache: decode with ring buffers must
    reproduce the training forward's last-position logits."""
    cfg = get_config("gemma3-1b").smoke().validate()
    assert cfg.window and cfg.window_cache
    rng = np.random.default_rng(7)
    params = init_params(cfg, jax.random.key(7))
    batch = _batch(cfg, rng)
    full_logits, _ = train_logits(cfg, params, batch)

    prompt = {"tokens": batch["tokens"][:, : S - 1]}
    _, cache = prefill(cfg, params, prompt, cache_len=S + 4)
    assert "lk" in cache and cache["lk"].shape[2] == cfg.window
    logits, cache2 = decode_step(
        cfg, params, cache, batch["tokens"][:, S - 1 :], jnp.int32(S - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    assert int(cache2["len"]) == S


def test_windowed_ring_cache_long_decode():
    """Ring wrap-around: decode several tokens past the window size and
    compare against the mask-only (full cache) implementation."""
    import dataclasses

    cfg = get_config("gemma3-1b").smoke().validate()
    cfg = dataclasses.replace(cfg, window=8)  # tiny window, S=32 >> W
    ref_cfg = dataclasses.replace(cfg, window_cache=False)
    rng = np.random.default_rng(8)
    params = init_params(cfg, jax.random.key(8))
    batch = _batch(cfg, rng)

    prompt = {"tokens": batch["tokens"][:, : S - 4]}
    _, cache = prefill(cfg, params, prompt, cache_len=S + 4)
    _, ref_cache = prefill(ref_cfg, params, prompt, cache_len=S + 4)
    for i in range(S - 4, S):
        tok = batch["tokens"][:, i : i + 1]
        logits, cache = decode_step(cfg, params, cache, tok, jnp.int32(i))
        ref_logits, ref_cache = decode_step(
            ref_cfg, params, ref_cache, tok, jnp.int32(i)
        )
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits, np.float32),
            rtol=2e-2, atol=2e-2,
        )
