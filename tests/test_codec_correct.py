"""RRNS locate-and-correct gradient codec (DESIGN.md §10).

Tier-1 coverage (no optional deps): with the second redundant modulus
(``GradCodec.make(correct=True)``) every single corrupted channel must be
located and corrected back to a bitwise-identical buffer — for corruption in
base AND redundant channels, on buffers produced by both the jnp and fused
encode paths, and composed with ``normalize`` after signed sums.  Multi-
channel corruption must be refused (never silently miscorrected), and the
repair must ride the train step / launch driver end to end.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.fault import repair_packed
from repro.dist.grad_codec import GradCodec, rns_psum, rns_psum_tree


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _chans(codec):
    return tuple(codec.base.moduli) + codec.redundant


def _corrupt(buf, ch: int, m: int, delta: int = 7):
    """Shift every element's channel ``ch`` by delta mod m (always a real,
    still-canonical corruption since 0 < delta < m)."""
    assert 0 < delta < m
    return buf.at[..., ch].set(jnp.mod(buf[..., ch] + delta, m))


# ----------------------------------------------------------- construction
def test_correct_codec_shape_and_redundant_ordering():
    codec = GradCodec.make(world=4, correct=True)
    assert codec.n_channels == codec.base.n + 2
    assert codec.mb is not None and codec.use_fused
    # the locate guarantee needs the redundant pair to dominate every base
    # pair product: redundant moduli must be the largest of the whole set
    assert min(codec.redundant) > max(codec.base.moduli)
    # detect-only codecs are untouched: same base, same wire format as ever
    plain = GradCodec.make(world=4)
    assert plain.mb is None and plain.n_channels == plain.base.n + 1


def test_locate_requires_second_redundant():
    plain = GradCodec.make(world=2)
    buf = plain.encode(jnp.asarray([1.0]))
    with pytest.raises(ValueError, match="correct=True"):
        plain.locate_fault(buf)
    with pytest.raises(ValueError, match="correct=True"):
        plain.correct_packed(buf)


# ---------------------------------------------------- every-channel repair
@pytest.mark.parametrize("fused", [True, False])
def test_correct_every_channel_roundtrip(fused):
    """The acceptance bar: corrupting ANY channel i of the (n+2)-channel
    encoding and running correct_packed yields a buffer bitwise-equal to the
    uncorrupted one — jnp and fused encode paths alike."""
    codec = GradCodec.make(world=4, correct=True, fused=fused)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(257).astype(np.float32))
    buf = codec.encode_packed(g).astype(jnp.int32)  # fused or jnp encode
    for ch, m in enumerate(_chans(codec)):
        bad = _corrupt(buf, ch, int(m))
        fault = codec.locate_fault(bad)
        assert bool(jnp.all(fault == ch)), f"channel {ch} not located"
        fixed, fault2 = codec.correct_packed(bad)
        np.testing.assert_array_equal(np.asarray(fault2), np.asarray(fault))
        np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))


def test_redundant_channel_corruption_does_not_misfire():
    """Corruption in a REDUNDANT channel must locate as that redundant
    channel — never as a base channel (which would 'repair' good data)."""
    codec = GradCodec.make(world=4, correct=True)
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(100).astype(np.float32))
    buf = codec.encode(g).astype(jnp.int32)
    n = codec.base.n
    for j, mr in enumerate(codec.redundant):
        for delta in (1, 17, int(mr) - 1):
            bad = _corrupt(buf, n + j, int(mr), delta)
            fault = codec.locate_fault(bad)
            assert bool(jnp.all(fault == n + j))
            fixed, _ = codec.correct_packed(bad)
            np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))


def test_clean_buffer_is_untouched():
    codec = GradCodec.make(world=4, correct=True)
    g = jnp.asarray(
        np.random.default_rng(2).standard_normal(64).astype(np.float32)
    )
    buf = codec.encode(g).astype(jnp.int32)
    fault = codec.locate_fault(buf)
    assert bool(jnp.all(fault == -1))
    fixed, _ = codec.correct_packed(buf)
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(buf))


def test_two_channel_corruption_detected_but_refused():
    """More corruption than the code can correct must come back as -2 with
    the buffer passed through unmodified — never a silent miscorrection."""
    codec = GradCodec.make(world=4, correct=True)
    g = jnp.asarray(
        np.random.default_rng(3).standard_normal(128).astype(np.float32)
    )
    buf = codec.encode(g).astype(jnp.int32)
    chans = _chans(codec)
    for c1, c2 in [(0, 1), (0, 3), (2, 4), (3, 4)]:
        bad = _corrupt(_corrupt(buf, c1, int(chans[c1]), 5),
                       c2, int(chans[c2]), 11)
        fault = codec.locate_fault(bad)
        assert bool(jnp.all(fault == -2)), (c1, c2)
        fixed, _ = codec.correct_packed(bad)
        np.testing.assert_array_equal(np.asarray(fixed), np.asarray(bad))
        # and the cheap detector flags it too
        assert not bool(jnp.any(codec.verify_packed(bad)))


def test_verify_packed_two_redundant_channels():
    """With m_b the detector must catch corruption of EITHER redundant
    channel (the other still pins the true wrap count)."""
    codec = GradCodec.make(world=4, correct=True)
    g = jnp.asarray(
        np.random.default_rng(4).standard_normal(32).astype(np.float32)
    )
    folded = codec.fold(codec.encode(g).astype(jnp.int32))
    assert bool(jnp.all(codec.verify_packed(folded)))
    n = codec.base.n
    for j, mr in enumerate(codec.redundant):
        bad = _corrupt(folded, n + j, int(mr), 1)
        assert not bool(jnp.any(codec.verify_packed(bad)))


# ----------------------------------------- summed buffers (wraps) + queries
def test_correct_summed_buffer_then_normalize_sign():
    """Correction composed with normalize after signed sums: repair a
    corrupted post-psum buffer at wraps=world-1, then normalize re-anchors
    the redundant channels so Algorithm-1 sign queries apply to the sum."""
    W = 4
    codec = GradCodec.make(world=W, correct=True)
    rng = np.random.default_rng(5)
    gs = rng.standard_normal((W, 200)).astype(np.float32)
    summed = jnp.asarray(
        sum(np.asarray(codec.encode(jnp.asarray(x)), np.int64) for x in gs)
        .astype(np.int32)
    )
    folded = codec.fold(summed)  # the codeword of the integer sum S < W*M
    for ch in (0, codec.base.n, codec.base.n + 1):
        m = int(_chans(codec)[ch])
        bad = _corrupt(folded, ch, m, 5)
        fixed, fault = codec.correct_packed(bad, wraps=W - 1)
        assert bool(jnp.all(fault == ch))
        np.testing.assert_array_equal(np.asarray(fixed), np.asarray(folded))
        q = np.clip(
            np.round(gs.astype(np.float64) * (1 << codec.frac_bits)),
            -codec.qmax, codec.qmax,
        )
        np.testing.assert_array_equal(
            np.asarray(codec.is_negative(codec.normalize(fixed))),
            q.sum(0) < 0,
        )


def test_wraps_range_validates_against_survivor_product():
    codec = GradCodec.make(world=4, correct=True)
    buf = codec.encode(jnp.asarray([1.0])).astype(jnp.int32)
    with pytest.raises(ValueError, match="survivor"):
        codec.locate_fault(buf, wraps=1 << 16)  # R = (wraps+1)*M too wide


# ------------------------------------------------------- transport plumbing
@pytest.mark.parametrize("fused", [True, False])
def test_correct_codec_transport_matches_plain_decode(fused):
    """The (n+2)-channel wire format must flow through rns_psum and the
    bucketed rns_psum_tree unchanged: decoded gradients bitwise-match this
    codec's own jnp fold+decode oracle (the correct codec uses a different
    moduli set than the detect-only one, so that's the right reference)."""
    codec = GradCodec.make(world=2, correct=True, fused=fused)
    mesh = _mesh1()
    rng = np.random.default_rng(6)
    g = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    out = jax.jit(shard_map(lambda x: rns_psum(codec, x, "data"), mesh,
                            in_specs=P(), out_specs=P(),
                            check_rep=False))(g)
    want = codec.decode(codec.fold(codec.encode(g).astype(jnp.int32)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    tree = {"a": g, "b": g[:37].reshape(37, 1) * 2.0}
    got = jax.jit(shard_map(lambda t: rns_psum_tree(codec, t, "data"), mesh,
                            in_specs=(P(),), out_specs=P(),
                            check_rep=False))(tree)
    for leaf, ref in zip(jax.tree_util.tree_leaves(got),
                         jax.tree_util.tree_leaves(tree)):
        np.testing.assert_allclose(
            np.asarray(leaf), np.asarray(ref),
            atol=2.0 ** -codec.frac_bits,
        )


def test_repair_packed_report_and_channel_major():
    codec = GradCodec.make(world=2, correct=True)
    g = jnp.asarray(
        np.random.default_rng(7).standard_normal(50).astype(np.float32)
    )
    wire = codec.encode_packed(g, channel_major=True)  # (n+2, B)
    bad = wire.at[0, 3].set(jnp.mod(wire[0, 3] + 9, codec.base.moduli[0]))
    fixed, report = repair_packed(codec, bad, channel_major=True)
    assert report == {"repaired": 1, "unrecoverable": 0}
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(wire))
    clean, report0 = repair_packed(codec, wire, channel_major=True)
    assert report0 == {"repaired": 0, "unrecoverable": 0}
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(wire))


def test_train_step_rns_repair_fixes_injected_corruption():
    """make_train_step(rns_repair=True) with a corrupting transport hook:
    the injected wire fault is repaired (metric counts it) and the params
    update is BITWISE identical to the uncorrupted run."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config("mamba2-370m").smoke()
    opt_cfg = AdamWConfig(warmup=2, decay_steps=4)
    params = init_params(cfg, jax.random.key(0))
    batch = jax.tree_util.tree_map(
        jnp.asarray, SyntheticLM(cfg, seq=16, batch=2).batch_at(0)
    )
    codec = GradCodec.make(world=2, correct=True)
    mesh = _mesh1()

    def corrupt(buf):
        return buf.at[0, 0].set(
            jnp.mod(buf[0, 0] + 1, codec.base.moduli[0])
        )

    def run(hook):
        step = make_train_step(cfg, opt_cfg, rns_codec=codec,
                               rns_axis="data", rns_repair=True,
                               transport_hook=hook)
        fn = jax.jit(shard_map(step, mesh,
                               in_specs=(P(), P(), P("data")),
                               out_specs=(P(), P(), P()),
                               check_rep=False))
        return fn(params, adamw_init(params), batch)

    p_clean, _, m_clean = run(None)
    p_fixed, _, m_fixed = run(corrupt)
    assert int(m_clean["repaired"]) == 0
    assert int(m_fixed["repaired"]) == 1
    assert int(m_fixed["unrepairable"]) == 0
    for a, b in zip(jax.tree_util.tree_leaves(p_clean),
                    jax.tree_util.tree_leaves(p_fixed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_rns_repair_requires_correct_codec():
    from repro.configs import get_config
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import make_train_step

    cfg = get_config("mamba2-370m").smoke()
    with pytest.raises(ValueError, match="correct=True"):
        make_train_step(cfg, AdamWConfig(), rns_codec=GradCodec.make(world=2),
                        rns_repair=True)


def test_launch_rns_correct_smoke(capsys):
    """launch/train.py --rns-correct finishes a smoke run with one injected
    corruption and logs the repaired step (the acceptance criterion)."""
    from repro.launch.train import main as train_main

    train_main(["--arch", "mamba2-370m", "--steps", "3", "--batch", "2",
                "--seq", "16", "--rns-correct", "--inject-corrupt-step",
                "1"])
    out = capsys.readouterr().out
    assert "[rns-correct] repaired 1" in out
    assert "at step 1" in out
    assert "done" in out
