"""Trip-count-aware HLO cost analyzer: exactness on known modules.

XLA's own cost_analysis counts while bodies once; these tests pin our
analyzer to ground truth on matmuls, scans (trip counts), and SPMD
collectives — the primitives the roofline derives from.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_costs import analyze_module


def test_plain_matmul_flops_exact():
    f = lambda x, w: x @ w
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    mc = analyze_module(c.as_text())
    assert mc.flops == 2 * 128 * 256 * 512


def test_scan_trip_count_multiplies():
    def g(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    L = 7
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    c = jax.jit(g).lower(xs, ws).compile()
    mc = analyze_module(c.as_text())
    assert mc.flops == L * 2 * 64 * 64 * 64
    assert mc.while_loops == 1 and mc.dynamic_loops == 0
    # XLA's own number misses the loop (cost_analysis returns a list of
    # per-partition dicts on recent jaxlibs):
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    assert xla_cost["flops"] < mc.flops


def test_nested_scan_trip_counts():
    def h(x, ws):
        def outer(x, wpair):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, wpair)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    xs = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 2, 32, 32), jnp.float32)
    c = jax.jit(h).lower(xs, ws).compile()
    mc = analyze_module(c.as_text())
    assert mc.flops == 3 * 2 * 2 * 32 * 32 * 32


def test_fori_loop_flops():
    def f(x, w):
        return jax.lax.fori_loop(0, 5, lambda i, x: jnp.tanh(x @ w), x)

    xs = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    mc = analyze_module(c.as_text())
    assert mc.flops == 5 * 2 * 16 * 16 * 16


def test_bytes_positive_and_dus_not_full_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice_in_dim(buf, upd, 3, axis=0)

    bs = jax.ShapeDtypeStruct((4096, 128), jnp.float32)
    us = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    c = jax.jit(f, donate_argnums=(0,)).lower(bs, us).compile()
    mc = analyze_module(c.as_text())
    # in-place update traffic ~ slice-sized, far below the full buffer
    assert 0 < mc.bytes < 4096 * 128 * 4
