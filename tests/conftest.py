"""Shared test configuration and serve-engine fixtures.

Puts ``src/`` on sys.path so a bare ``pytest`` works without PYTHONPATH, and
documents the optional dev dependency policy: suites that use hypothesis
guard their own import with ``pytest.importorskip`` so a missing optional
dependency reports as an explicit SKIP, never a collection ERROR.

The serve suites (``test_serve_batcher``/``test_serve_paged``/
``test_serve_offline``/``test_serve_soak``) share one smoke model and one
engine factory from here instead of keeping per-file copies: ``cfg``/
``params`` are session-scoped fixtures, and ``make_engine`` builds a
``ContinuousBatcher`` parameterized over paged/monolithic x bucketed/
chunked.  ``kv_row``/``logical_rows`` read a request's written KV span
back out of either cache layout for bitwise comparisons.
"""
import os
import sys

import pytest

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# one geometry for every serve suite: 4 pages per slot, page == chunk
CACHE_LEN = 32
CHUNK = 8
PAGE = 8
N_PG = CACHE_LEN // PAGE


@pytest.fixture(scope="session")
def cfg():
    from repro.configs import get_config

    return get_config("gemma-2b").smoke()


@pytest.fixture(scope="session")
def params(cfg):
    import jax

    from repro.models import init_params

    return init_params(cfg, jax.random.key(0))


def make_engine(cfg, params, *, paged=False, buckets=None, **kw):
    """Engine factory over the shared serve geometry.  ``paged=True``
    switches to the paged pool (page_size=PAGE unless overridden);
    ``buckets`` arms length-bucketed prefill — on the paged family that
    exercises the padded write barrier (DESIGN.md §13)."""
    from repro.serve.batcher import ContinuousBatcher

    kw.setdefault("n_slots", 3)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("prefill_chunk", CHUNK)
    if paged:
        kw.setdefault("page_size", PAGE)
    if buckets is not None:
        kw.setdefault("prefill_buckets", buckets)
    return ContinuousBatcher(cfg, params, **kw)


def kv_row(engine, slot_index, plen, n_out):
    """A request's monolithic KV row over its full written span
    [0, plen+n_out-1) (idle-row junk writes park at cache_len-1, outside
    every span)."""
    import numpy as np

    end = plen + n_out - 1  # last written position + 1
    k = np.asarray(engine.cache["k"])[:, slot_index, :end]
    v = np.asarray(engine.cache["v"])[:, slot_index, :end]
    return k, v


def run_with_row_snapshots(eng, reqs):
    """Submit ``reqs``, run to completion, and capture every LLM
    request's written KV span [0, plen+n_out-1) AT RETIREMENT — the one
    moment the span is complete and (on the paged pool) the slot's
    page-table row is still mapped.  Works on both cache layouts, so a
    paged+bucketed engine and a monolithic chunk-loop engine can be
    compared request-by-request even under slot churn and page reuse.
    Returns ({rid: retired Request}, {rid: (k_rows, v_rows)})."""
    rows = {}
    orig = eng.sched.record_token

    def spy(slot, token, now=0.0):
        req, idx = slot.req, slot.index
        done = orig(slot, token, now)
        if done:
            plen, n_out = len(req.prompt), len(req.out)
            end = plen + n_out - 1  # last written position + 1
            if eng.paged:
                r = logical_rows(eng, eng.sched.table[idx])
                rows[req.rid] = (r["k"][:, :end].copy(),
                                 r["v"][:, :end].copy())
            else:
                rows[req.rid] = kv_row(eng, idx, plen, n_out)
        return done

    eng.sched.record_token = spy
    try:
        for r in reqs:
            eng.submit(r)
        done = eng.run_to_completion()
    finally:
        eng.sched.record_token = orig
    return {r.rid: r for r in done}, rows


def logical_rows(eng, table_row):
    """Gather one slot's logical (L, cache_len, g, hd) K/V rows out of the
    paged pool through a page-table row snapshot."""
    import numpy as np

    pages = np.asarray(table_row)
    rows = {}
    for name in ("k", "v"):
        pool = np.asarray(eng.cache[name])  # (L, P, page, g, hd)
        L, _, page, g, hd = pool.shape
        rows[name] = pool[:, pages].reshape(L, len(pages) * page, g, hd)
    return rows
