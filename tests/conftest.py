"""Shared test configuration.

Puts ``src/`` on sys.path so a bare ``pytest`` works without PYTHONPATH, and
documents the optional dev dependency policy: suites that use hypothesis
guard their own import with ``pytest.importorskip`` so a missing optional
dependency reports as an explicit SKIP, never a collection ERROR.
"""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
