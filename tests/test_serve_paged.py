"""Paged, prefix-sharing KV cache invariants (DESIGN.md §13).

The tier-1 contract of the paged pool under the continuous batcher:

* BITWISE identity — requests sharing a system-prompt prefix through
  deduplicated pages produce tokens AND a full logical KV row
  bitwise-identical to solo un-paged runs (the gathered page-table view
  equals the monolithic slot row);
* PAGE savings — N requests sharing a 75%-length common prefix peak at
  STRICTLY fewer physical pages than N monolithic rows would hold, under
  the same persistent jitted decode step (no retrace, via jit cache
  stats);
* copy-on-write — a full-prefix admission that must write into a shared
  page copies it first; the source page's readers are untouched;
* eviction — recycling retained pages under pool pressure keeps every
  retired fingerprint valid, drops the evicted page's whole registry
  subtree (a reused pid can never resurrect an orphan chain), and a
  verify MISMATCH at eviction lands in ``verify_log`` under the page's
  publisher rid;
* shared-fingerprint repair — a corrupted shared page codeword is
  detected and repaired ONCE, after which every reader re-verifies;
* validation — capacity errors report derived legal values, not just the
  rejected inputs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from conftest import (
    CACHE_LEN,
    CHUNK,
    N_PG,
    PAGE,
    logical_rows as _logical_rows,
    make_engine,
    run_with_row_snapshots,
)
from repro.configs import get_config
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.scheduler import (
    FREE,
    PagedScheduler,
    PrefixRegistry,
    Request,
)


def _engine(cfg, params, **kw):
    return make_engine(cfg, params, paged=True, **kw)


def _prefix_reqs(cfg, n, plen, shared, max_new, seed=3):
    """n requests whose prompts share a ``shared``-token common prefix."""
    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, shared)]
    return [
        Request(rid=i, prompt=prefix + [int(t) for t in rng.integers(
            1, cfg.vocab, plen - shared)], max_new=max_new)
        for i in range(n)
    ]


def _solo_run(cfg, params, req, n_out):
    """Un-paged single-slot reference: (tokens, k_row, v_row)."""
    eng = make_engine(cfg, params, n_slots=1)
    eng.submit(Request(rid=req.rid, prompt=list(req.prompt),
                       max_new=req.max_new))
    done = eng.run_to_completion()
    assert len(done) == 1
    k = np.asarray(eng.cache["k"])[:, 0]
    v = np.asarray(eng.cache["v"])[:, 0]
    return done[0].out, k, v


# ------------------------------------------------------ bitwise identity
def test_shared_prefix_bitwise_tokens_and_kv(cfg, params):
    """Three requests behind one system prefix: tokens and the FULL
    logical KV (gathered through the page table) match solo un-paged runs
    bitwise."""
    reqs = _prefix_reqs(cfg, 3, plen=19, shared=16, max_new=6)
    eng = _engine(cfg, params)
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=list(r.prompt),
                           max_new=r.max_new))
    eng.try_admit()
    assert eng.page_stats()["dedup_hits"] > 0  # prefix actually shared
    # snapshot table rows while mapped (release zeroes them at retirement;
    # page CONTENT stays intact because nothing else is admitted after)
    tables = {r.rid: list(eng.sched.table[i]) for i, r in enumerate(reqs)}
    while eng.sched.busy:
        eng.step()
    done = {r.rid: r for r in eng.sched.completed}

    for r in reqs:
        sout, sk, sv = _solo_run(cfg, params, r, len(done[r.rid].out))
        assert done[r.rid].out == sout  # greedy tokens bitwise-identical
        rows = _logical_rows(eng, tables[r.rid])
        # the written region: prompt + all decode writes (the final
        # generated token is never written back)
        end = len(r.prompt) + len(sout) - 1
        np.testing.assert_array_equal(rows["k"][:, :end], sk[:, :end])
        np.testing.assert_array_equal(rows["v"][:, :end], sv[:, :end])


def test_full_prefix_hit_cow_bitwise(cfg, params):
    """A prompt that exactly equals already-registered pages must CoW the
    final shared page (first-token logits need a write into it) and still
    match the solo run bitwise."""
    rng = np.random.default_rng(11)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
    eng = _engine(cfg, params, prefill_chunk=4)
    eng.submit(Request(rid="warm", prompt=prefix + [5], max_new=3))
    eng.run_to_completion()
    eng.submit(Request(rid="hit", prompt=list(prefix), max_new=4))
    done = eng.run_to_completion()
    assert eng.page_stats()["cow_copies"] >= 1
    hit = [r for r in done if r.rid == "hit"][0]
    sout, _, _ = _solo_run(cfg, params, hit, len(hit.out))
    assert hit.out == sout


# ----------------------------------------------------- page-count savings
def test_75pct_shared_prefix_uses_strictly_fewer_pages(cfg, params):
    """8 requests sharing a 75%-length common prefix peak at strictly
    fewer physical pages than 8 monolithic rows (8 * n_pg), under ONE
    persistent decode trace."""
    n = 8
    reqs = _prefix_reqs(cfg, n, plen=24, shared=18, max_new=8)
    eng = _engine(cfg, params, n_slots=n, n_pages=1 + n * N_PG)
    for r in reqs:
        eng.submit(r)
    eng.try_admit()
    assert len(eng.sched.decoding_slots()) == n  # all co-resident
    eng.run_to_completion()
    st = eng.page_stats()
    assert st["pages_in_use_peak"] < n * N_PG  # strictly fewer than rows
    assert st["dedup_hits"] >= (n - 1) * (18 // PAGE)
    sizes = eng.jit_cache_sizes()
    assert sizes["decode"] == 1 and sizes["extend"] == 1  # no retrace


def test_admission_defers_on_page_pressure(cfg, params):
    """With a pool smaller than slots * n_pg, admission is gated by PAGES:
    requests defer while reservations can't be covered, then admit as
    retirements free pages — and everything still completes."""
    eng = _engine(cfg, params, n_slots=4, n_pages=N_PG + 2)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=[i * 7 + 1] * 12, max_new=6))
    done = eng.run_to_completion()
    assert len(done) == 4
    assert eng.page_stats()["deferrals"] > 0
    assert eng.page_stats()["pages_in_use"] == 0  # all released


# ------------------------------------------------------------ fingerprints
def test_eviction_and_reuse_keep_fingerprints_valid(cfg, params):
    """Pool pressure evicts retained (registered) pages and recycles them;
    every retirement's per-page verification still passes."""
    eng = _engine(cfg, params, n_slots=2, n_pages=N_PG + 2,
                  rns_verify=True)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=[i * 3 + 2] * 12, max_new=6))
    eng.run_to_completion()
    st = eng.page_stats()
    assert st["pages_evicted"] >= 1
    assert all(eng.verify_log.values())
    assert st["fingerprints"]["failed"] == 0
    assert st["fingerprints"]["verified"] > 0


def test_shared_page_corruption_repaired_once_for_all_readers(cfg, params):
    """Corrupt the ONE stored codeword of a page shared by three readers:
    detected via the redundant channels, repaired in place once, and every
    reader's retirement verification passes against the fixed codeword."""
    rng = np.random.default_rng(7)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, PAGE)]
    eng = _engine(cfg, params, rns_verify=True)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=prefix + [40 + i], max_new=4))
    eng.try_admit()
    shared = [p for p in range(eng.n_pages)
              if eng.sched.alloc.refcount[p] > 1]
    assert len(shared) == 1  # exactly the one deduplicated prefix page
    pid = shared[0]
    assert pid in eng.wire
    eng.corrupt_wire(pid, channel=1, delta=3)
    assert not eng.wire_ok(pid)  # redundant channels catch it
    assert eng.repair_wire(pid) == {"repaired": 1, "unrecoverable": 0}
    assert eng.wire_ok(pid)
    eng.run_to_completion()
    assert all(eng.verify_log.values())  # every reader re-verified
    assert eng.wire.stats["repaired"] == 1


def test_registry_eviction_cannot_resurrect_orphan_chain():
    """Evicting a registered page drops its ENTIRE descendant subtree:
    children are keyed by the raw parent pid, so if the chain survived and
    the pool reused that pid for different content, match() would walk
    through the reused pid into stale pages whose KV was computed under a
    different prefix (silently wrong tokens)."""
    reg = PrefixRegistry(page_size=2)
    reg.add(None, (1, 2), pid=3)
    reg.add(3, (3, 4), pid=4)
    reg.add(4, (5, 6), pid=5)
    reg.drop(3)  # pid 3 evicted under pool pressure
    assert reg.nodes == {} and reg.by_pid == {}  # whole chain unregistered
    reg.add(None, (9, 9), pid=3)  # pool reuses pid 3 for NEW content
    # the old descendants (pids 4, 5) must not ride behind the reused pid
    assert reg.match([9, 9, 3, 4, 5, 6]) == [3]


def test_eviction_verify_failure_lands_in_verify_log(cfg, params):
    """Corrupt RETAINED pages' stored codewords, then force pool pressure
    to evict them: the eviction-time mismatch is recorded in verify_log
    under the pages' publisher rids, not just counted in wire stats."""
    eng = _engine(cfg, params, n_slots=2, n_pages=N_PG + 2,
                  rns_verify=True)
    eng.submit(Request(rid=0, prompt=[2] * 12, max_new=6))
    eng.submit(Request(rid=1, prompt=[5] * 12, max_new=6))
    eng.run_to_completion()
    assert eng.verify_log == {0: True, 1: True}
    retained = list(eng.sched.alloc.retained)
    assert retained  # registered prefix pages parked for reuse
    pubs = {eng._page_pub[pid] for pid in retained}
    for pid in retained:
        eng.corrupt_wire(pid, channel=1, delta=3)  # stored codeword rots
    for i in (2, 3):  # distinct prompts: no dedup revival, pure pressure
        eng.submit(Request(rid=i, prompt=[i * 3 + 2] * 12, max_new=6))
    eng.run_to_completion()
    assert eng.page_stats()["pages_evicted"] >= 1
    bad = [r for r, ok in eng.verify_log.items() if not ok]
    assert bad and set(bad) <= pubs  # surfaced under the publisher rid(s)
    assert eng.wire.stats["failed"] >= 1


# ---------------------------------------------------------------- sharding
def test_paged_pool_shards_on_mesh(cfg, params):
    """The pooled buffer takes ``cache_specs(paged_pool=True)``'s layout:
    rank-5 leaves with the page-pool axis carrying the batch sharding."""
    mesh = jax.make_mesh((1,), ("data",))
    eng = _engine(cfg, params, mesh=mesh)
    spec = eng.cache_pspecs["k"]
    assert len(spec) == 5
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=4))
    done = eng.run_to_completion()
    assert len(done[0].out) == 4


# -------------------------------------------------------------- validation
def test_capacity_errors_report_derived_legal_values(cfg, params):
    """Constructor rejections name the legal values, not just the bad
    inputs (page divisors, chunk-compatible sizes, pool minimums)."""
    with pytest.raises(ValueError, match=r"valid page sizes: \[1, 2, 4, "):
        _engine(cfg, params, page_size=5)
    with pytest.raises(ValueError, match="chunk-compatible page sizes"):
        # 32 % 24 and 24 % 32 both nonzero: neither grid contains the other
        _engine(cfg, params, cache_len=96, page_size=32, prefill_chunk=24)
    with pytest.raises(ValueError, match=f"minimum n_pages: {N_PG + 2}"):
        _engine(cfg, params, n_pages=N_PG + 1)
    with pytest.raises(ValueError,
                       match=r"valid prefill_chunk values: \[1, 2, 4, "):
        _engine(cfg, params, prefill_chunk=7)
    with pytest.raises(ValueError, match="nearest legal cache_len: 512 or"):
        _engine(cfg, params, cache_len=513, page_size=None)


# ------------------- padded write barrier (bucketed prefill, DESIGN §13)
def test_bucketed_paged_bitwise_vs_chunk_loop_shared_prefix(cfg, params):
    """THE padded-write-barrier contract: length-bucketed single-call
    prefill on the paged, prefix-sharing pool produces tokens AND logical
    KV rows bitwise-identical to the monolithic chunk loop.  Pad
    positions ride the per-slot scratch page — never a mapped, shared, or
    retained physical page — so dedup'd prefixes stay byte-exact while
    every prompt prefills in ONE extend call of its bucket width."""
    def mk_reqs():
        shared = _prefix_reqs(cfg, 3, plen=19, shared=16, max_new=6)
        rng = np.random.default_rng(29)
        extras = [Request(rid=10 + i, prompt=[int(t) for t in rng.integers(
            1, cfg.vocab, p)], max_new=6) for i, p in enumerate((5, 11, 23))]
        return shared + extras

    eng_b = _engine(cfg, params, prefill_buckets=(8, 16, 32),
                    rns_verify=True)
    done_b, rows_b = run_with_row_snapshots(eng_b, mk_reqs())
    eng_c = make_engine(cfg, params, rns_verify=True)  # monolithic loop
    done_c, rows_c = run_with_row_snapshots(eng_c, mk_reqs())

    assert sorted(done_b) == sorted(done_c)
    for rid, rb in done_b.items():
        assert rb.out == done_c[rid].out
        (bk, bv), (ck, cv) = rows_b[rid], rows_c[rid]
        np.testing.assert_array_equal(bk, ck)
        np.testing.assert_array_equal(bv, cv)
    # every retirement's fingerprints verified clean, on BOTH engines
    assert eng_b.verify_log and all(eng_b.verify_log.values())
    assert all(eng_c.verify_log.values())
    st = eng_b.bucket_stats()
    assert sum(st["hits"].values()) == 6 and st["fallbacks"] == 0
    pg = eng_b.page_stats()
    assert pg["dedup_hits"] >= 2 * (16 // PAGE)  # prefix shared via pages
    assert pg["pages_in_use"] == 0  # every span + scratch page released
    assert pg["fingerprints"]["failed"] == 0
    sizes = eng_b.jit_cache_sizes()
    assert sizes["decode"] == 1 and sizes["extend"] == 3  # one per width


def test_bucketed_full_prefix_hit_cow_bitwise(cfg, params):
    """A full-prefix hit restarting mid-page (prefill_chunk < page_size)
    must CoW the final shared page and then extend through a PADDED
    bucket: the pads ride the scratch page, the CoW'd page takes only the
    real tail, and tokens still match the solo run bitwise."""
    rng = np.random.default_rng(11)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
    eng = _engine(cfg, params, prefill_chunk=4,
                  prefill_buckets=(8, 16, 32), rns_verify=True)
    eng.submit(Request(rid="warm", prompt=prefix + [5], max_new=3))
    eng.run_to_completion()
    eng.submit(Request(rid="hit", prompt=list(prefix), max_new=4))
    done = eng.run_to_completion()
    assert eng.page_stats()["cow_copies"] >= 1
    hit = [r for r in done if r.rid == "hit"][0]
    sout, _, _ = _solo_run(cfg, params, hit, len(hit.out))
    assert hit.out == sout
    assert all(eng.verify_log.values())
    assert eng.bucket_stats()["hits"]["8"] >= 1  # the padded 4-token tail


def test_bucket_pads_write_only_span_pages_and_scratch(cfg, params):
    """Direct pool-level check of the barrier: a bucketed prefill whose
    bucket overshoots both the prompt AND the table row (pad positions
    clip past cache_len) may touch ONLY the slot's own span page and the
    transient scratch page.  Every other physical page — the retained
    prefix pages it maps, the parking page the clipped pads would
    otherwise junk — is byte-identical before and after."""
    rng = np.random.default_rng(23)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, 16)]
    eng = _engine(cfg, params, prefill_buckets=(32,), rns_verify=True)
    eng.submit(Request(rid="pub", prompt=prefix + [7, 8, 9], max_new=2))
    eng.run_to_completion()
    before = {n: np.asarray(eng.cache[n]).copy() for n in ("k", "v")}
    reg_pids = set(eng.sched.registry.by_pid)
    assert reg_pids  # the prefix pages are retained, shareable content

    grabbed = []
    orig = eng.sched.alloc_scratch

    def spy(slot):
        pid, acts = orig(slot)
        grabbed.append(pid)
        return pid, acts

    eng.sched.alloc_scratch = spy
    try:
        # 6-token tail behind the shared prefix, forced through the one
        # oversized bucket: 26 pads, positions 32..47 clip past the table
        eng.submit(Request(rid="sub", prompt=prefix + [11] * 6, max_new=2))
        eng.try_admit()  # admission == the single bucketed extend
    finally:
        eng.sched.alloc_scratch = orig
    assert grabbed and len(grabbed) == 1
    scratch = grabbed[0]
    slot = eng.sched.decoding_slots()[0]
    row = list(eng.sched.table[slot.index])
    assert scratch not in row  # never mapped through the table
    assert eng.sched.alloc.refcount[scratch] == 0  # freed after the call
    allowed = {row[2], scratch}  # real span [16, 22) -> logical page 2
    assert reg_pids.isdisjoint(allowed)
    after = {n: np.asarray(eng.cache[n]) for n in ("k", "v")}
    for pid in range(eng.n_pages):
        if pid in allowed:
            continue
        for name in ("k", "v"):
            np.testing.assert_array_equal(after[name][:, pid],
                                          before[name][:, pid])
    eng.run_to_completion()
    assert all(eng.verify_log.values())


def test_bucketed_admission_reserves_scratch_headroom():
    """Host-side reservation math for the bucketed path: real-span pages
    in page units PLUS one scratch unit, consumed exactly by plan_write +
    alloc_scratch; the chunk-loop plan for the same request reserves by
    the chunk-grid pad end instead (no scratch)."""
    s = PagedScheduler(2, 32, page_size=8, n_pages=9, prefill_chunk=8,
                       prefill_buckets=(8, 16, 32))
    assert s.bucket_for(3) == 8 and s.bucket_for(9) == 16
    assert s.bucket_for(33) is None  # over-bucket -> chunk-loop fallback
    s.submit(Request(rid="a", prompt=list(range(10)), max_new=5, eos=-1))
    slot = s.admit_next()
    assert slot is not None
    # ceil((10 + 5 - 1) / 8) = 2 span pages + 1 scratch page
    assert slot.reserved_left == 3
    s.plan_write(slot, 0, 10)  # maps the two span pages
    pid, _ = s.alloc_scratch(slot)
    assert pid not in s.table[slot.index]
    assert s.alloc.refcount[pid] == 1 and not s.alloc.is_retained(pid)
    s.free_scratch(pid)
    assert s.alloc.refcount[pid] == 0 and not s.alloc.is_retained(pid)
    assert slot.reserved_left == 0  # budget exactly spent
    c = PagedScheduler(2, 32, page_size=8, n_pages=9, prefill_chunk=8)
    c.submit(Request(rid="a", prompt=list(range(10)), max_new=5, eos=-1))
    assert c.admit_next().reserved_left == 2  # chunk grid, no scratch


def test_scheduler_deferral_is_pure_host_logic():
    """PagedScheduler admission math without any model: worst-case
    reservation blocks the queue head until pages free up."""
    s = PagedScheduler(4, 32, page_size=8, n_pages=6, prefill_chunk=8)
    s.submit(Request(rid="a", prompt=list(range(24)), max_new=8, eos=-1))
    a = s.admit_next()
    assert a is not None
    for st in range(0, 24, 8):
        s.plan_write(a, st, 8)
    s.submit(Request(rid="b", prompt=list(range(50, 70)), max_new=8,
                     eos=-1))
    assert s.admit_next() is None  # needs 4 pages, only 1 available
    assert s.stats["deferrals"] == 1
    s.release_pages(a.index)
    s.slots[a.index].state = FREE
    s.slots[a.index].req = None
    admitted = s.admit_next()  # pages back -> queue head admits
    assert admitted is not None
    assert admitted.index == a.index  # ...into the actually-released slot
