"""Core RNS library tests: exactness against Python big-int oracles.

Covers the paper's Theorem 1 (full-range comparison), Remark 1 (the
N1 ≡ N2 mod m_a special cases), the MRC (Alg. 2), to_ma (Alg. 3), the three
base-extension methods, signed embedding, division/scaling, and Montgomery
modular multiplication.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import (
    RNSBase,
    make_base,
    add,
    sub,
    mul,
    mrc,
    mrc_unrolled,
    mrs_ge,
    mrs_to_int,
    to_ma,
    int_to_rns,
    rns_to_int,
    tensor_to_rns,
    rns_to_tensor,
    rns_compare_ge,
    classic_compare_ge,
    approx_crt_ge,
    extend_mrc,
    extend_shenoy,
    extend_kawamura,
    encode_signed,
    is_negative,
    abs_ge_threshold,
    pack,
    divmod_rns,
    halve,
    scale_pow2,
    parity,
    RNSMontgomery,
)

BASE8 = make_base(4, bits=8)      # small: exhaustive-ish hypothesis ranges
BASE15 = make_base(6, bits=15)    # default TPU profile
BASE31 = make_base(4, bits=31)    # int64-lane profile


def _pair(base, N1, N2):
    x1 = jnp.asarray(base.residues_of(N1))
    x2 = jnp.asarray(base.residues_of(N2))
    a1 = jnp.asarray(N1 % base.ma)
    a2 = jnp.asarray(N2 % base.ma)
    return x1, a1, x2, a2


# ---------------------------------------------------------------- base
def test_base_tables():
    b = BASE8
    assert b.M == np.prod([int(m) for m in b.moduli], dtype=object)
    for j in range(b.n):
        for i in range(j + 1, b.n):
            assert b.inv_tri_np[j, i] * b.moduli[j] % b.moduli[i] == 1
    acc = 1
    for i in range(b.n):
        assert int(b.betas_ma_np[i]) == acc % b.ma
        acc *= b.moduli[i]


def test_base_rejects_non_coprime():
    with pytest.raises(ValueError):
        RNSBase(moduli=(6, 9), ma=5, bits=8)
    with pytest.raises(ValueError):
        RNSBase(moduli=(7, 11), ma=7, bits=8)


# ---------------------------------------------------------------- arith
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_arith_homomorphism(data):
    b = BASE15
    X = data.draw(st.integers(0, b.M - 1))
    Y = data.draw(st.integers(0, b.M - 1))
    x, y = jnp.asarray(b.residues_of(X)), jnp.asarray(b.residues_of(Y))
    assert rns_to_int(b, np.asarray(add(b, x, y))) == (X + Y) % b.M
    assert rns_to_int(b, np.asarray(sub(b, x, y))) == (X - Y) % b.M
    assert rns_to_int(b, np.asarray(mul(b, x, y))) == (X * Y) % b.M


# ---------------------------------------------------------------- MRC
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_mrc_reconstructs(data):
    for b in (BASE8, BASE15, BASE31):
        X = data.draw(st.integers(0, b.M - 1))
        d = mrc(b, jnp.asarray(b.residues_of(X)))
        assert mrs_to_int(b, np.asarray(d)) == X
        d2 = mrc_unrolled(b, jnp.asarray(b.residues_of(X)))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d2))


def test_mrc_batched():
    b = BASE15
    xs = np.stack([b.residues_of(i * 7919) for i in range(32)])
    ds = np.asarray(mrc(b, jnp.asarray(xs)))
    for i in range(32):
        assert mrs_to_int(b, ds[i]) == (i * 7919) % b.M


# ---------------------------------------------------------------- to_ma
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_to_ma(data):
    b = BASE15
    X = data.draw(st.integers(0, b.M - 1))
    d = mrc(b, jnp.asarray(b.residues_of(X)))
    assert int(to_ma(b, d)) == X % b.ma


# ------------------------------------------------------- comparison (Thm 1)
@settings(max_examples=200, deadline=None)
@given(st.data())
def test_theorem1_full_range(data):
    b = data.draw(st.sampled_from((BASE8, BASE15, BASE31)))
    N1 = data.draw(st.integers(0, b.M - 1))
    N2 = data.draw(st.integers(0, b.M - 1))
    got = bool(rns_compare_ge(b, *_pair(b, N1, N2)))
    assert got == (N1 >= N2)


@settings(max_examples=100, deadline=None)
@given(st.data())
def test_remark1_congruent_mod_ma(data):
    """The special case N1 ≡ N2 (mod m_a) of Remark 1."""
    b = BASE8
    N2 = data.draw(st.integers(0, b.M - 1))
    k = data.draw(st.integers(0, (b.M - 1 - N2) // b.ma))
    N1 = N2 + k * b.ma
    assert bool(rns_compare_ge(b, *_pair(b, N1, N2)))
    if N1 != N2:
        assert not bool(rns_compare_ge(b, *_pair(b, N2, N1)))


def test_compare_edges():
    for b in (BASE8, BASE15):
        M = b.M
        cases = [(0, 0), (0, M - 1), (M - 1, 0), (M - 1, M - 1), (1, 0), (0, 1),
                 (M // 2, M // 2 + 1), (M // 2 + 1, M // 2)]
        for N1, N2 in cases:
            assert bool(rns_compare_ge(b, *_pair(b, N1, N2))) == (N1 >= N2), (N1, N2)


def test_compare_batched_vectorized():
    b = BASE15
    rng = np.random.default_rng(0)
    N1 = [int(rng.integers(0, min(b.M, 2**63))) for _ in range(64)]
    N2 = [int(rng.integers(0, min(b.M, 2**63))) for _ in range(64)]
    x1 = jnp.asarray(np.stack([b.residues_of(v) for v in N1]))
    x2 = jnp.asarray(np.stack([b.residues_of(v) for v in N2]))
    a1 = jnp.asarray(np.asarray([v % b.ma for v in N1], dtype=b.dtype))
    a2 = jnp.asarray(np.asarray([v % b.ma for v in N2], dtype=b.dtype))
    got = np.asarray(rns_compare_ge(b, x1, a1, x2, a2))
    np.testing.assert_array_equal(got, np.asarray(N1) >= np.asarray(N2))


@settings(max_examples=100, deadline=None)
@given(st.data())
def test_classic_compare_matches(data):
    b = BASE8
    N1 = data.draw(st.integers(0, b.M - 1))
    N2 = data.draw(st.integers(0, b.M - 1))
    x1 = jnp.asarray(b.residues_of(N1))
    x2 = jnp.asarray(b.residues_of(N2))
    assert bool(classic_compare_ge(b, x1, x2)) == (N1 >= N2)


def test_approx_crt_fails_close_succeeds_far():
    """Documents the approximate method's failure band (paper §1)."""
    b = BASE15
    far_ok = 0
    for N1 in [b.M // 3, b.M // 2, 2 * b.M // 3]:
        N2 = N1 - b.M // 100
        x1, x2 = jnp.asarray(b.residues_of(N1)), jnp.asarray(b.residues_of(N2))
        far_ok += bool(approx_crt_ge(b, x1, x2))
    assert far_ok == 3
    # Adjacent values: exact method always right; approx method has no such
    # guarantee (no assertion that it fails — only that OURS succeeds).
    N1 = b.M // 2
    N2 = N1 + 1
    assert not bool(rns_compare_ge(b, *_pair(b, N1, N2)))


# ---------------------------------------------------------------- extension
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_extend_mrc_exact(data):
    b = BASE8
    X = data.draw(st.integers(0, b.M - 1))
    targets = (251, 241, 239)
    got = np.asarray(extend_mrc(b, jnp.asarray(b.residues_of(X)), targets))
    np.testing.assert_array_equal(got, [X % t for t in targets])


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_extend_shenoy_exact(data):
    b = BASE8
    X = data.draw(st.integers(0, b.M - 1))
    mr = b.ma
    targets = (251, 241)
    got = np.asarray(
        extend_shenoy(
            b, jnp.asarray(b.residues_of(X)), jnp.asarray(X % mr), mr, targets
        )
    )
    np.testing.assert_array_equal(got, [X % t for t in targets])


def test_extend_kawamura_interior_exact_and_edge_band():
    b = BASE15
    targets = (32717,)
    # interior values: exact
    for X in [b.M // 4, b.M // 2, (3 * b.M) // 5]:
        got = int(extend_kawamura(b, jnp.asarray(b.residues_of(X)), targets)[0])
        assert got == X % targets[0], X
    # near-top values: allowed to be off by one M (documented failure band)
    X = b.M - 1
    got = int(extend_kawamura(b, jnp.asarray(b.residues_of(X)), targets)[0])
    assert got in (X % targets[0], (X - b.M) % targets[0], (X + b.M) % targets[0])


# ---------------------------------------------------------------- signed
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_signed_roundtrip_and_sign(data):
    b = make_base(3, bits=15)
    bound = (b.M - 1) // 2
    v = data.draw(st.integers(-bound, bound))
    vv = jnp.asarray([v], dtype=jnp.int64)
    packed = encode_signed(b, vv)
    assert bool(is_negative(b, packed)[0]) == (v < 0)
    dec = int(rns_to_tensor(b, packed[..., :-1])[0])
    dec = dec - b.M if dec > b.M // 2 else dec
    assert dec == v


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_abs_threshold(data):
    b = make_base(3, bits=15)
    bound = (b.M - 1) // 2
    v = data.draw(st.integers(-bound, bound))
    thr = data.draw(st.integers(1, bound))
    packed = encode_signed(b, jnp.asarray([v], dtype=jnp.int64))
    assert bool(abs_ge_threshold(b, packed, thr)[0]) == (abs(v) >= thr)


# ---------------------------------------------------------------- tensor codec
def test_tensor_roundtrip():
    b = make_base(3, bits=15)
    rng = np.random.default_rng(1)
    v = rng.integers(-(2**40), 2**40, size=(4, 5), dtype=np.int64)
    res = tensor_to_rns(b, jnp.asarray(v))
    back = np.asarray(rns_to_tensor(b, res))
    back = np.where(back > b.M // 2, back - b.M, back)
    np.testing.assert_array_equal(back, v)


# ---------------------------------------------------------------- division
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_divmod(data):
    b = make_base(3, bits=8)
    X = data.draw(st.integers(0, b.M - 1))
    D = data.draw(st.integers(1, b.M - 1))
    xp = pack(b, jnp.asarray(b.residues_of(X)), jnp.asarray(X % b.ma))
    dp = pack(b, jnp.asarray(b.residues_of(D)), jnp.asarray(D % b.ma))
    q, r = divmod_rns(b, xp, dp)
    Q = rns_to_int(b, np.asarray(q[..., :-1]))
    R = rns_to_int(b, np.asarray(r[..., :-1]))
    assert (Q, R) == divmod(X, D)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_parity_halve_scale(data):
    b = BASE8
    X = data.draw(st.integers(0, b.M - 1))
    x = jnp.asarray(b.residues_of(X))
    assert int(parity(b, x)) == X % 2
    p = pack(b, x, jnp.asarray(X % b.ma))
    h = halve(b, p)
    assert rns_to_int(b, np.asarray(h[..., :-1])) == X // 2
    s = scale_pow2(b, p, 3)
    assert rns_to_int(b, np.asarray(s[..., :-1])) == X // 8


# ---------------------------------------------------------------- Montgomery
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_montgomery_modmul(data):
    bB = make_base(6, bits=15)
    bBp = RNSBase(
        moduli=tuple(int(m) for m in make_base(13, bits=15).moduli[6:12]),
        ma=make_base(13, bits=15).moduli[12],
        bits=15,
    )
    N = data.draw(st.integers(3, bB.M // 4 - 1)) | 1  # odd modulus
    import math

    if math.gcd(N, bB.M) != 1 or math.gcd(N, bBp.M) != 1:
        return
    mont = RNSMontgomery(bB, bBp, N)
    X = data.draw(st.integers(0, N - 1))
    Y = data.draw(st.integers(0, N - 1))
    r = mont.mul(mont.to_dual(X), mont.to_dual(Y))
    got = mont.from_dual(r)
    Minv = pow(bB.M, -1, N)
    assert got % N == (X * Y * Minv) % N
    assert got < 2 * N


# ---------------------------------------------------------- log-depth MRC
@settings(max_examples=40, deadline=None)
@given(st.data())
def test_mrc_tree_matches_sequential(data):
    """The divide-and-conquer (log²-depth) MRC produces identical digits to
    the sequential Alg. 2 — supports the paper's parallel-time claim."""
    from repro.core import mrc_tree

    b = data.draw(st.sampled_from((BASE8, BASE15, BASE31)))
    X = data.draw(st.integers(0, b.M - 1))
    x = jnp.asarray(b.residues_of(X))
    np.testing.assert_array_equal(
        np.asarray(mrc_tree(b, x)), np.asarray(mrc(b, x))
    )


def test_mrc_tree_batched_large_base():
    from repro.core import mrc_tree, make_base

    b = make_base(33, bits=15)  # odd n exercises uneven splits
    rng = np.random.default_rng(0)
    m = np.asarray(b.moduli_np)
    xs = jnp.asarray(rng.integers(0, m, size=(64, b.n)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(mrc_tree(b, xs)), np.asarray(mrc(b, xs))
    )
