"""Checkpointer robustness (DESIGN.md §14).

The tier-1 contract of the async RRNS-coded checkpointer:

* policy grammar — overlapping step/time intervals, most-specific first;
* error propagation — a failed background save surfaces on the next
  ``wait()`` / ``close()`` / ``join()``, never vanishes with its thread;
* atomicity — a committed ``step_<N>`` is all-or-nothing; SIGKILL mid-save
  leaves only a ``.tmp`` remnant that the next run sweeps;
* repair-on-restore — one corrupted RRNS channel per buffer is located
  and rebuilt in stride (reported); multi-channel damage is REFUSED and
  restore falls back to the next restorable step;
* kill-and-resume — a trainer SIGKILLed during an async save resumes
  from the survivor checkpoint bitwise-equal to an uninterrupted run;
* elastic restore — a ZeRO-1 state saved under one mesh device_puts onto
  a different mesh shape on load (checkpoints hold full host arrays);
* warm serve restart — the paged pool's prefix pages and their wire
  fingerprints persist and revalidate across an engine restart;
* legacy scanner — ``fault.scan_restorable`` skips torn / corrupt /
  foreign directories and lands on the newest verified legacy step.
"""
import json
import os
import signal
import subprocess
import sys
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (x64)
from repro.dist import fault
from repro.train import checkpoint
from repro.train import checkpointer as cp

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

TRAIN_ARGS = ["--arch", "gemma-2b", "--steps", "8", "--batch", "2",
              "--seq", "16", "--save-every", "4"]


# ------------------------------------------------------------ save policy
def test_parse_policy_overlapping_intervals():
    pol = cp.parse_policy("2@10,5,30s")
    due = [s for s in range(1, 21) if pol.step_due(s)]
    assert due == [2, 4, 6, 8, 10, 15, 20]  # dense early, sparse after
    assert pol.every_seconds == 30.0
    assert not pol.step_due(0)  # step 0 is the init state, never due


def test_policy_time_due_is_wall_clock_only():
    pol = cp.parse_policy("1m")
    assert not any(pol.step_due(s) for s in range(1, 200))
    assert pol.time_due(now=100.0, last=30.0)
    assert not pol.time_due(now=100.0, last=50.0)


@pytest.mark.parametrize("bad", ["0", "-1", "2@", "x", "3s,4s", "5,7"])
def test_parse_policy_rejects_malformed(bad):
    with pytest.raises(ValueError):
        cp.parse_policy(bad)


# ----------------------------------------------- lossless RRNS round trip
def test_write_read_round_trip_mixed_dtypes(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"step": np.array(7, dtype=np.int32),   # 0-d stays 0-d
                  "h": jnp.full((3,), 1.5, jnp.bfloat16)},
            "odd": np.frombuffer(b"xyz", dtype=np.uint8)}  # 3 bytes: padded
    cp.write_step_dir(str(tmp_path), 5, tree, extra={"opt_step": 5})
    restored, step, extra, rep = cp.restore(str(tmp_path))
    assert (step, extra) == (5, {"opt_step": 5})
    assert rep["repaired_leaves"] == 0 and rep["steps_skipped"] == 0
    assert restored["b"]["step"].shape == ()
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["h"],
                                  np.asarray(tree["b"]["h"]))
    np.testing.assert_array_equal(restored["odd"], tree["odd"])


def test_single_channel_corruption_repaired_on_restore(tmp_path):
    tree = {"w": np.arange(8, dtype=np.float32)}
    cp.write_step_dir(str(tmp_path), 1, tree)
    cp.inject_channel_corruption(str(tmp_path / "step_1"), leaf=0,
                                 channels=(2,), index=3)
    restored, step, _, rep = cp.restore(str(tmp_path))
    assert step == 1
    assert rep["repaired_leaves"] == 1 and rep["repaired_elements"] == 1
    np.testing.assert_array_equal(restored["w"], tree["w"])  # exact rebuild


def test_two_channel_damage_refused_with_fallback(tmp_path):
    cp.write_step_dir(str(tmp_path), 1, {"w": np.ones(4, np.float32)})
    cp.write_step_dir(str(tmp_path), 2, {"w": np.full(4, 2.0, np.float32)})
    # two BASE channels of one element: beyond single-channel repair
    cp.inject_channel_corruption(str(tmp_path / "step_2"), channels=(0, 1))
    with pytest.raises(cp.CheckpointCorrupt):
        cp.restore(str(tmp_path), step=2)  # explicit step: refuse loudly
    restored, step, _, rep = cp.restore(str(tmp_path))
    assert step == 1 and rep["steps_skipped"] == 1  # fell back, counted
    np.testing.assert_array_equal(restored["w"], np.ones(4))


def test_truncated_wire_file_falls_back(tmp_path):
    cp.write_step_dir(str(tmp_path), 1, {"w": np.ones(4)})
    cp.write_step_dir(str(tmp_path), 2, {"w": np.zeros(4)})
    f = tmp_path / "step_2" / "0.rns.npy"
    f.write_bytes(f.read_bytes()[:10])
    restored, step, _, rep = cp.restore(str(tmp_path))
    assert step == 1 and rep["steps_skipped"] == 1
    with pytest.raises(cp.CheckpointCorrupt):
        cp.read_step_dir(str(tmp_path / "step_2"))


def test_discover_ignores_tmp_and_foreign_entries(tmp_path):
    assert cp.discover_latest(str(tmp_path)) is None
    (tmp_path / "step_4.tmp").mkdir()
    (tmp_path / "step_abc").mkdir()
    (tmp_path / "notes.txt").write_text("x")
    assert cp.discover_steps(str(tmp_path)) == []
    cp.write_step_dir(str(tmp_path), 10, {"a": np.zeros(1)})
    cp.write_step_dir(str(tmp_path), 2, {"a": np.zeros(1)})
    assert cp.discover_steps(str(tmp_path)) == [2, 10]
    assert cp.discover_latest(str(tmp_path)) == 10


# ----------------------------------------------------- Checkpointer class
def test_checkpointer_policy_gc_and_tmp_sweep(tmp_path):
    (tmp_path / "step_7.tmp").mkdir()  # torn remnant of a "crash"
    tree = {"a": np.arange(3, dtype=np.float32)}
    with cp.Checkpointer(str(tmp_path), "2@4,3", keep=2) as saver:
        assert not (tmp_path / "step_7.tmp").exists()  # swept at init
        enq = [s for s in range(1, 10) if saver.maybe_save(s, tree)]
    assert enq == [2, 4, 6, 9]  # bounded interval first, then every 3
    assert cp.discover_steps(str(tmp_path)) == [6, 9]  # GC kept newest 2
    restored, step, _, _ = cp.restore(str(tmp_path))
    assert step == 9
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpointer_worker_error_surfaces_on_wait(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(cp, "write_step_dir", boom)
    saver = cp.Checkpointer(str(tmp_path), "1")
    saver.save(1, {"a": np.zeros(2)})
    with pytest.raises(RuntimeError, match="disk full"):
        saver.wait()
    saver.close()  # error already consumed: close is clean


def test_checkpointer_worker_error_surfaces_on_close(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(cp, "write_step_dir", boom)
    saver = cp.Checkpointer(str(tmp_path), "1")
    saver.save(1, {"a": np.zeros(2)})
    with pytest.raises(RuntimeError, match="disk full"):
        saver.close()


# ------------------------------------------- legacy checkpoint satellites
def test_save_commits_atomically_no_tmp_left(tmp_path):
    path = checkpoint.save(str(tmp_path), 2, {"a": np.arange(4)})
    assert os.path.basename(path) == "step_2"
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_save_async_error_reraised_on_join(tmp_path):
    target = tmp_path / "ck"
    target.write_text("a FILE where the ckpt dir should be")
    handle = checkpoint.save_async(str(target), 1, {"a": np.zeros(2)})
    with pytest.raises(OSError):
        handle.join()


def test_save_async_same_step_guard(tmp_path, monkeypatch):
    release, started = threading.Event(), threading.Event()
    real_save = checkpoint.save

    def slow_save(*a, **k):
        started.set()
        assert release.wait(10)
        return real_save(*a, **k)

    monkeypatch.setattr(checkpoint, "save", slow_save)
    handle = checkpoint.save_async(str(tmp_path), 3, {"a": np.zeros(2)})
    assert started.wait(10)
    with pytest.raises(RuntimeError, match="in flight"):
        checkpoint.save_async(str(tmp_path), 3, {"a": np.zeros(2)})
    release.set()
    assert handle.join() == str(tmp_path / "step_3")
    # the guard clears with the thread: the same step saves again fine
    checkpoint.save_async(str(tmp_path), 3, {"a": np.zeros(2)}).join()


def test_scan_restorable_edge_cases(tmp_path):
    # empty / missing dirs and non-checkpoint entries: None, no crash
    assert fault.scan_restorable(str(tmp_path)) is None
    assert fault.scan_restorable(str(tmp_path / "nope")) is None
    (tmp_path / "notes.txt").write_text("x")
    (tmp_path / "step_xyz").mkdir()
    assert fault.find_restorable(str(tmp_path)) is None

    checkpoint.save(str(tmp_path), 1, {"a": np.arange(3)})
    # newest step loses a tensor file -> scan falls back one step
    checkpoint.save(str(tmp_path), 2, {"a": np.arange(4)})
    os.remove(tmp_path / "step_2" / "0.npy")
    path, manifest, flat = fault.scan_restorable(str(tmp_path))
    assert path.endswith("step_1") and manifest["step"] == 1
    np.testing.assert_array_equal(flat["a"], np.arange(3))

    # torn save (no manifest with the fingerprints) -> skipped
    checkpoint.save(str(tmp_path), 3, {"a": np.arange(5)})
    os.remove(tmp_path / "step_3" / "manifest.json")
    assert fault.find_restorable(str(tmp_path)).endswith("step_1")

    # bit rot under an intact manifest -> fingerprint mismatch, skipped
    checkpoint.save(str(tmp_path), 4, {"a": np.arange(6)})
    rotten = np.load(tmp_path / "step_4" / "0.npy")
    rotten[0] ^= 1
    np.save(tmp_path / "step_4" / "0.npy", rotten)
    assert fault.find_restorable(str(tmp_path)).endswith("step_1")

    # a NEW-format (rrns-v1) dir is skipped cleanly by the legacy scanner
    cp.write_step_dir(str(tmp_path), 9, {"a": np.arange(7)})
    assert fault.find_restorable(str(tmp_path)).endswith("step_1")


# ------------------------------------------------- kill-and-resume chaos
def _leaf_shas(step_dir):
    with open(os.path.join(step_dir, "manifest.json")) as f:
        return [leaf["sha"] for leaf in json.load(f)["leaves"]]


def test_sigkill_mid_save_then_resume_bitwise_equal(tmp_path, capsys):
    """SIGKILL lands inside the background writer after the first leaf
    file of step_8: the torn .tmp never commits, step_4 survives, and the
    resumed trainer re-runs 4..8 to a checkpoint bitwise-identical to an
    uninterrupted run's."""
    from repro.launch.train import main as train_main

    ref, ck = str(tmp_path / "ref"), str(tmp_path / "ck")
    train_main(TRAIN_ARGS + ["--ckpt-dir", ref])  # uninterrupted baseline

    env = dict(os.environ, PYTHONPATH=SRC)
    env[cp.CRASH_STEP_ENV] = "8"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *TRAIN_ARGS,
         "--ckpt-dir", ck],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == -signal.SIGKILL, out.stderr[-2000:]
    names = os.listdir(ck)
    assert "step_8.tmp" in names and "step_8" not in names  # torn, by design
    assert "step_4" in names  # the committed survivor

    capsys.readouterr()
    train_main(TRAIN_ARGS + ["--ckpt-dir", ck])  # resume 4 -> 8
    log = capsys.readouterr().out
    assert "[resume] restored step 4" in log
    assert not os.path.exists(os.path.join(ck, "step_8.tmp"))  # swept
    assert _leaf_shas(os.path.join(ck, "step_8")) == \
        _leaf_shas(os.path.join(ref, "step_8"))  # bitwise-equal resume


def test_resume_repairs_single_channel_and_refuses_two(tmp_path, capsys):
    """The driver's --inject-ckpt-corrupt path: 1 channel is repaired in
    stride and logged; 2 base channels force fallback to the prior step."""
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    train_main(TRAIN_ARGS + ["--ckpt-dir", ck])
    capsys.readouterr()
    train_main(TRAIN_ARGS + ["--ckpt-dir", ck, "--inject-ckpt-corrupt", "1"])
    log = capsys.readouterr().out
    assert "repaired_leaves=1" in log and "restored step 8" in log
    train_main(TRAIN_ARGS + ["--ckpt-dir", ck, "--inject-ckpt-corrupt", "2"])
    log = capsys.readouterr().out
    assert "restored step 4" in log and "steps_skipped=1" in log


# ------------------------------------------------------- elastic restore
def test_elastic_restore_reshards_zero1_state():
    """Save a ZeRO-1 train state under a (4,2) mesh, restore it under a
    (2,4) mesh: values identical, shardings are the NEW mesh's.  One
    subprocess so the 8-device XLA flag never pollutes this process."""
    code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
import repro
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params
from repro.train.optimizer import adamw_init
from repro.train import checkpointer as cp
from repro.dist.sharding import named_shardings, opt_state_specs, param_specs

cfg = get_config("gemma-2b").smoke()
params = init_params(cfg, jax.random.key(0))
abs_p = jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)

def shardings(mesh):
    pspecs = param_specs(abs_p, mesh, n_experts=cfg.n_experts)
    z = opt_state_specs(abs_p, pspecs, mesh, zero1=True)
    return named_shardings(
        {"params": pspecs, "opt": {"m": z, "v": z, "step": P()}}, mesh)

meshA = jax.make_mesh((4, 2), ("data", "model"))
shA = shardings(meshA)
tree = jax.device_put({"params": params, "opt": adamw_init(params)}, shA)
ckpt = tempfile.mkdtemp()
cp.write_step_dir(ckpt, 7, tree)

meshB = jax.make_mesh((2, 4), ("data", "model"))
shB = shardings(meshB)
abs_tree = jax.tree_util.tree_map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
out, step, extra, rep = cp.restore(ckpt, abs_tree, shB)
assert step == 7 and rep["repaired_leaves"] == 0
flat_o = jax.tree_util.tree_leaves(out)
flat_s = jax.tree_util.tree_leaves(shB, is_leaf=lambda x: hasattr(x, "spec"))
assert len(flat_o) == len(flat_s)
assert all(o.sharding == s for o, s in zip(flat_o, flat_s))
jax.tree_util.tree_map(
    lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
    tree, out)
print("SUBPROC_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------- warm serve restart
@pytest.fixture(scope="module")
def scfg():
    from repro.configs import get_config

    return get_config("gemma-2b").smoke()


@pytest.fixture(scope="module")
def sparams(scfg):
    from repro.models import init_params

    return init_params(scfg, jax.random.key(0))


def _serve_engine(scfg, sparams, **kw):
    from repro.serve.batcher import ContinuousBatcher

    kw.setdefault("n_slots", 2)
    kw.setdefault("cache_len", 32)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("page_size", 8)
    kw.setdefault("rns_verify", True)
    return ContinuousBatcher(scfg, sparams, **kw)


def _shared_prefix_reqs(scfg, seed=5):
    from repro.serve.scheduler import Request

    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, scfg.vocab, 8)]
    return prefix, [Request(rid=i, prompt=prefix + [30 + i], max_new=3)
                    for i in range(2)]


def test_warm_restart_adopts_pages_bitwise(tmp_path, scfg, sparams):
    from repro.serve.scheduler import Request

    prefix, reqs = _shared_prefix_reqs(scfg)
    eng = _serve_engine(scfg, sparams)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    saved = eng.save_warm_state(str(tmp_path))
    assert saved["pages_saved"] >= 1  # the retained shared-prefix chain

    fresh = _serve_engine(scfg, sparams)
    rep = fresh.load_warm_state(str(tmp_path))
    assert rep["adopted"] == saved["pages_saved"]
    assert rep["dropped"] == 0 and rep["repaired_pages"] == 0

    # the adopted pages dedup a new same-prefix request after the restart
    fresh.submit(Request(rid="new", prompt=prefix + [9], max_new=3))
    done = fresh.run_to_completion()
    assert fresh.page_stats()["dedup_hits"] >= 1
    assert fresh.verify_log["new"] is True  # retirement re-verify passes

    cold = _serve_engine(scfg, sparams)  # bitwise vs a cold engine
    cold.submit(Request(rid="new", prompt=prefix + [9], max_new=3))
    cdone = cold.run_to_completion()
    assert [r.out for r in done] == [r.out for r in cdone]


def test_warm_restart_repairs_corrupted_state_file(tmp_path, scfg, sparams):
    _, reqs = _shared_prefix_reqs(scfg)
    eng = _serve_engine(scfg, sparams)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    saved = eng.save_warm_state(str(tmp_path))
    # one RRNS channel of one saved leaf rots on disk
    cp.inject_channel_corruption(str(tmp_path / "step_0"), leaf=0,
                                 channels=(2,))
    fresh = _serve_engine(scfg, sparams)
    rep = fresh.load_warm_state(str(tmp_path))
    assert rep["ckpt_repaired_leaves"] == 1  # fixed at the checkpoint layer
    assert rep["adopted"] == saved["pages_saved"] and rep["dropped"] == 0


def test_warm_restart_drops_unrepairable_page(tmp_path, scfg, sparams):
    """A stored page codeword rotten in TWO base channels round-trips
    losslessly through the checkpoint, fails revalidation on load, and the
    page (with any descendants) is dropped instead of trusted."""
    _, reqs = _shared_prefix_reqs(scfg)
    eng = _serve_engine(scfg, sparams)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    retained = list(eng.sched.alloc.retained)
    assert retained
    eng.corrupt_wire(retained[0], channel=0, delta=3)
    eng.corrupt_wire(retained[0], channel=1, delta=3)
    saved = eng.save_warm_state(str(tmp_path))
    fresh = _serve_engine(scfg, sparams)
    rep = fresh.load_warm_state(str(tmp_path))
    assert rep["dropped"] >= 1
    assert rep["adopted"] == saved["pages_saved"] - rep["dropped"]
