"""Launcher integration: train driver resume-exactness, serve driver, and a
small-device-count dry-run lowering in a subprocess (so the 512-device
XLA_FLAGS never pollutes this process).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_driver_resumes_exactly(tmp_path):
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    train_main(["--arch", "gemma-2b", "--steps", "8", "--ckpt-dir", ck,
                "--save-every", "4", "--batch", "2", "--seq", "16"])
    # second run resumes from step 8's predecessor checkpoint and continues
    train_main(["--arch", "gemma-2b", "--steps", "10", "--ckpt-dir", ck,
                "--save-every", "4", "--batch", "2", "--seq", "16"])
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_"))
    assert "step_8" in steps


def test_serve_driver_runs(tmp_path):
    from repro.launch.serve import main as serve_main

    trace = str(tmp_path / "workload.serve-trace.jsonl")
    report = serve_main([
        "--arch", "gemma-2b", "--requests", "4", "--slots", "2",
        "--cache-len", "32", "--prefill-chunk", "8", "--max-new", "4",
        "--prompt-mean", "6", "--save-trace", trace,
        "--report", str(tmp_path / "report.json"),
    ])
    assert report["requests"] == 4 and report["tokens_out"] == 16
    assert report["jit_traces"] == {"decode": 1, "extend": 1, "insert": 1}
    # the saved trace replays to the identical deterministic tick metrics
    replay = serve_main([
        "--arch", "gemma-2b", "--trace", trace, "--slots", "2",
        "--cache-len", "32", "--prefill-chunk", "8",
    ])
    assert replay["latency_ticks"] == report["latency_ticks"]


def test_serve_driver_warm_restart(tmp_path):
    """--warm-restart persists the paged pool's prefix pages + wire
    fingerprints; a second identical run adopts them and dedups."""
    from repro.launch.serve import main as serve_main

    args = ["--arch", "gemma-2b", "--requests", "4", "--slots", "2",
            "--cache-len", "64", "--prefill-chunk", "8", "--page-size", "8",
            "--max-new", "4", "--prompt-mean", "10", "--rns-verify",
            "--seed", "3", "--warm-restart", str(tmp_path / "warm")]
    cold = serve_main(args)
    assert cold["warm_restart"]["restored"] is False  # nothing saved yet
    assert cold["warm_restart"]["pages_saved"] >= 1
    warm = serve_main(args)
    assert warm["warm_restart"]["restored"] is True
    assert warm["warm_restart"]["adopted"] >= 1
    assert warm["warm_restart"]["dropped"] == 0
    assert warm["paging"]["dedup_hits"] >= 1  # restart-surviving prefixes
    assert warm["rns"]["slots_failed"] == 0


@pytest.mark.parametrize("arch", ["mamba2-370m", "internvl2-26b"])
def test_serve_driver_single_shot_fallback(arch):
    """Gated families (ssm, vlm with its patch-prefix cache) still serve
    via the sequential fallback."""
    from repro.launch.serve import main as serve_main

    report = serve_main([
        "--arch", arch, "--requests", "2", "--max-new", "3",
        "--prompt-mean", "6",
    ])
    assert report["engine"] == "single-shot"
    assert report["requests"] == 2 and report["tokens_out"] == 6
    assert "jit_traces" not in report


def test_serve_driver_offline_mode(tmp_path):
    """--mode offline: warmed bucketed harness, retrace-free, report
    carries the saturation metrics and the overlap/bucket blocks."""
    from repro.launch.serve import main as serve_main

    report = serve_main([
        "--arch", "gemma-2b", "--mode", "offline", "--requests", "6",
        "--slots", "2", "--cache-len", "32", "--prefill-chunk", "8",
        "--buckets", "8,16,32", "--max-new", "4", "--prompt-mean", "6",
        "--report", str(tmp_path / "offline.json"),
    ])
    assert report["engine"] == "offline-harness"
    assert report["retrace_free"] is True
    assert report["requests"] == 6 and report["tokens_out"] == 24
    assert report["buckets"]["fallbacks"] == 0
    assert report["overlap"]["enabled"] and report["overlap"]["processed"] == 6
    assert report["ttft_s"]["n"] == 6
    assert (tmp_path / "offline.json").exists()


def test_serve_driver_loadgen_mode(tmp_path):
    """--mode loadgen: the QPS search runs to an SLO-pass attestation of
    a measured phase (generous SLO + low bracket keeps it fast)."""
    from repro.launch.serve import main as serve_main

    report = serve_main([
        "--arch", "gemma-2b", "--mode", "loadgen", "--slots", "2",
        "--cache-len", "32", "--prefill-chunk", "8",
        "--buckets", "8,16,32", "--max-new", "4", "--prompt-mean", "6",
        "--qps-lo", "20", "--qps-hi", "80", "--qps-iters", "1",
        "--phase-requests", "4",
        "--report", str(tmp_path / "loadgen.json"),
    ])
    assert report["mode"] == "loadgen"
    assert report["phases"]  # full transcript in the report
    if report["slo_pass"]:
        at = report["attestation"]
        assert at["slo_pass"] and at["retrace_free"]
        assert any(p["offered_qps"] == at["offered_qps"]
                   for p in report["phases"] if p["slo_pass"])
    assert (tmp_path / "loadgen.json").exists()


def test_serve_driver_mode_flag_validation():
    from repro.launch.serve import main as serve_main

    # --page-size composes with offline/loadgen now (padded write
    # barrier); the sim-only extras still do not
    with pytest.raises(SystemExit):
        serve_main(["--mode", "offline", "--page-size", "8",
                    "--rns-verify", "--warm-restart", "/tmp/nope"])
    with pytest.raises(SystemExit):
        serve_main(["--mode", "offline", "--rns-verify",
                    "--inject-wire-corrupt"])
    with pytest.raises(SystemExit):
        serve_main(["--mode", "loadgen", "--crypto-slots", "1"])
    with pytest.raises(SystemExit):
        serve_main(["--mode", "offline", "--buckets", "nope"])


def test_serve_driver_profiler_window(tmp_path):
    from repro.launch.serve import main as serve_main

    report = serve_main([
        "--arch", "gemma-2b", "--requests", "2", "--slots", "2",
        "--cache-len", "32", "--prefill-chunk", "8", "--max-new", "4",
        "--prompt-mean", "6", "--profile-start-step", "1",
        "--profile-steps", "2", "--profile-dir", str(tmp_path),
    ])
    prof = report["profile"]
    assert prof["captured_steps"] == 2
    assert prof["artifact"] and os.path.isdir(prof["artifact"])
    # the trace actually hit disk (an .xplane.pb under plugins/profile)
    hits = [f for _, _, fs in os.walk(prof["artifact"]) for f in fs
            if f.endswith(".xplane.pb")]
    assert hits, f"no xplane trace under {prof['artifact']}"


def test_train_driver_profiler_window(tmp_path, capsys):
    from repro.launch.train import main as train_main

    train_main(["--arch", "gemma-2b", "--steps", "4", "--batch", "2",
                "--seq", "16", "--profile-start-step", "1",
                "--profile-steps", "2", "--profile-dir", str(tmp_path)])
    assert "[profile] captured 2 step(s)" in capsys.readouterr().out
    hits = [f for _, _, fs in os.walk(str(tmp_path)) for f in fs
            if f.endswith(".xplane.pb")]
    assert hits, f"no xplane trace under {tmp_path}"


def test_serve_driver_rejects_duplicate_rids(tmp_path):
    from repro.launch.serve import main as serve_main

    trace = tmp_path / "dup.serve-trace.jsonl"
    trace.write_text(
        '{"rid": 3, "prompt": [1, 2], "max_new": 2}\n'
        '{"rid": 3, "prompt": [4, 5], "max_new": 2}\n'
    )
    with pytest.raises(ValueError, match="duplicate rids"):
        serve_main(["--arch", "gemma-2b", "--trace", str(trace),
                    "--cache-len", "32", "--prefill-chunk", "8"])


def test_dryrun_subprocess_small_mesh():
    """Lower+compile one cell with 8 fake devices in a subprocess —
    exercises the dryrun plumbing end-to-end without the 512-device cost."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg, pa, lowered, meta = lower_cell("whisper-tiny", "train_4k", mesh,
                                    microbatches=4)
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
print("SUBPROC_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=420,
    )
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]
