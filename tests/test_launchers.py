"""Launcher integration: train driver resume-exactness, serve driver, and a
small-device-count dry-run lowering in a subprocess (so the 512-device
XLA_FLAGS never pollutes this process).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_driver_resumes_exactly(tmp_path):
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    train_main(["--arch", "gemma-2b", "--steps", "8", "--ckpt-dir", ck,
                "--save-every", "4", "--batch", "2", "--seq", "16"])
    # second run resumes from step 8's predecessor checkpoint and continues
    train_main(["--arch", "gemma-2b", "--steps", "10", "--ckpt-dir", ck,
                "--save-every", "4", "--batch", "2", "--seq", "16"])
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_"))
    assert "step_8" in steps


def test_serve_driver_runs():
    from repro.launch.serve import main as serve_main

    toks = serve_main(["--arch", "mamba2-370m", "--batch", "2",
                       "--prompt", "16", "--decode", "4"])
    assert toks.shape == (2, 5)


def test_dryrun_subprocess_small_mesh():
    """Lower+compile one cell with 8 fake devices in a subprocess —
    exercises the dryrun plumbing end-to-end without the 512-device cost."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg, pa, lowered, meta = lower_cell("whisper-tiny", "train_4k", mesh,
                                    microbatches=4)
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
print("SUBPROC_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=420,
    )
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]
