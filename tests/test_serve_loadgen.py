"""Closed-loop QPS search invariants (DESIGN.md §16).

* the SLO check fires on each clause independently (TTFT p99, latency
  p99, tail-compensated saturation wall) and a keeping-up phase passes;
* ``poisson_requests`` synthesizes a well-formed open-loop phase
  (monotone arrivals, prompts that fit the cache, fresh rids);
* ``search_max_qps`` converges deterministically on a modeled system —
  the bracket protocol (floor fail / ceiling pass / bisect) and the
  attestation contract (always a MEASURED passing phase, never an
  interpolation) are exercised against a queueing stub;
* one real harness phase under a generous SLO passes end to end.
"""
import numpy as np
import pytest

import jax

import repro  # noqa: F401
from repro.configs import get_config
from repro.models import init_params
from repro.serve.loadgen import (
    SLO,
    phase_stats,
    poisson_requests,
    search_max_qps,
)
from repro.serve.offline import OfflineInference


def _phase(ttft_p99=0.1, lat_p99=0.5, wall=10.0, span=9.0):
    return {
        "ttft_s": {"p99": ttft_p99},
        "latency_s": {"p99": lat_p99},
        "wall_s": wall,
        "arrival_span_s": span,
    }


def test_slo_clauses_fire_independently():
    slo = SLO(ttft_p99_s=0.2, latency_p99_s=1.0, min_sustained_ratio=0.95)
    assert slo.check(_phase()) == []
    assert "ttft_p99" in slo.check(_phase(ttft_p99=0.3))[0]
    assert "latency_p99" in slo.check(_phase(lat_p99=1.5))[0]
    # saturation: wall beyond (span + latency budget) / ratio
    allowed = (9.0 + 1.0) / 0.95
    assert slo.check(_phase(wall=allowed + 0.1)) != []
    assert slo.check(_phase(wall=allowed - 0.1)) == []
    # small phase, big drain tail: the latency-budget compensation keeps
    # a keeping-up system passing even when wall >> arrival span
    assert slo.check(_phase(wall=0.9, span=0.1)) == []


def test_poisson_requests_shape():
    rng = np.random.default_rng(0)
    reqs = poisson_requests(32, 4.0, rng, vocab=100, prompt_mean=8,
                            max_new=8, cache_len=32, rid0=500)
    assert [r.rid for r in reqs] == list(range(500, 532))
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr) and arr[0] > 0
    for r in reqs:
        assert 1 <= len(r.prompt) <= 32 - 8  # plen+max_new <= cache_len
        assert r.eos == -1
    with pytest.raises(ValueError):
        poisson_requests(1, 0.0, rng, vocab=10, prompt_mean=4,
                         max_new=4, cache_len=32)


class _ModelHarness:
    """Deterministic queueing stub with capacity C requests/s: the wall
    is the arrival span plus the service backlog; per-request tails grow
    once offered exceeds capacity.  Duck-types ``OfflineInference.run``
    for the search (which only reads the report dict)."""

    def __init__(self, capacity_qps):
        self.c = capacity_qps

    def run(self, reqs):
        n = len(reqs)
        span = max(r.arrival for r in reqs)
        offered = n / span
        service = n / self.c
        wall = max(span, service) + 1.0 / self.c
        backlog = max(0.0, service - span)
        ttft = 0.01 + backlog / n
        lat = 0.05 + backlog
        return {
            "requests": n,
            "wall_s": wall,
            "arrival_span_s": span,
            "tok_per_s": n * 8 / wall,
            "ttft_s": {"n": n, "mean": ttft, "p50": ttft, "p95": ttft,
                       "p99": ttft},
            "latency_s": {"n": n, "mean": lat, "p50": lat, "p95": lat,
                          "p99": lat},
            "retrace_free": True,
        }


def _mk(n, qps, rng=np.random.default_rng(7)):
    return poisson_requests(n, qps, rng, vocab=100, prompt_mean=8,
                            max_new=8, cache_len=32)


def test_search_converges_on_modeled_capacity():
    slo = SLO(ttft_p99_s=0.5, latency_p99_s=1.0, min_sustained_ratio=0.95)
    out = search_max_qps(_ModelHarness(capacity_qps=10.0), _mk, slo,
                         qps_lo=1.0, qps_hi=100.0, iters=6,
                         phase_requests=64)
    assert out["slo_pass"]
    # capacity 10 qps: the knee must land near it, strictly inside the
    # bracket, and the attested phase is a MEASURED pass
    assert 5.0 < out["max_qps"] < 25.0
    at = out["attestation"]
    assert at["slo_pass"] and at["offered_qps"] == out["max_qps"]
    passing = [p for p in out["phases"] if p["slo_pass"]]
    assert any(p["offered_qps"] == at["offered_qps"] and
               p["sustained_qps"] == at["sustained_qps"] for p in passing)
    # phase transcript: lo probe + hi probe + iters bisections
    assert len(out["phases"]) == 2 + 6


def test_search_floor_fail_and_ceiling_pass():
    slo = SLO(ttft_p99_s=0.5, latency_p99_s=1.0)
    slow = _ModelHarness(capacity_qps=0.05)
    out = search_max_qps(slow, _mk, slo, qps_lo=1.0, qps_hi=10.0, iters=3)
    assert not out["slo_pass"] and out["max_qps"] == 0.0
    assert "floor" in out["note"] and "attestation" not in out

    fast = _ModelHarness(capacity_qps=1e6)
    out = search_max_qps(fast, _mk, slo, qps_lo=1.0, qps_hi=10.0, iters=3)
    assert out["slo_pass"] and out["max_qps"] == 10.0
    assert "ceiling" in out["note"]
    assert len(out["phases"]) == 2  # both probes, no bisection needed


def test_search_rejects_bad_bracket():
    slo = SLO()
    with pytest.raises(ValueError):
        search_max_qps(_ModelHarness(1.0), _mk, slo, qps_lo=5.0,
                       qps_hi=5.0)
    with pytest.raises(ValueError):
        search_max_qps(_ModelHarness(1.0), _mk, slo, qps_lo=1.0,
                       qps_hi=2.0, iters=-1)


def test_real_phase_meets_generous_slo():
    cfg = get_config("gemma-2b").smoke()
    params = init_params(cfg, jax.random.key(0))
    harness = OfflineInference(cfg, params, n_slots=4, cache_len=32,
                               prefill_chunk=8, buckets=(8, 16, 32),
                               queue_size=8)
    harness.warmup()
    rng = np.random.default_rng(11)
    reqs = poisson_requests(8, 50.0, rng, vocab=cfg.vocab, prompt_mean=8,
                            max_new=4, cache_len=32)
    ph = phase_stats(harness.run(reqs), offered_qps=50.0)
    harness.require_steady_state()
    assert ph["requests"] == 8 and ph["retrace_free"]
    assert ph["sustained_qps"] > 0
    # generous SLO: a smoke model on any host finishes 8 tiny requests
    # well inside a 60s budget
    assert SLO(ttft_p99_s=60.0, latency_p99_s=60.0,
               min_sustained_ratio=0.5).check(ph) == []
