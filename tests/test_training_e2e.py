"""End-to-end training behaviour: the system learns a learnable stream, the
RNS-allreduce path matches the fp32 path, and checkpoint resume replays the
exact loss trajectory.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.train.data import SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def _run(cfg, steps, pattern="arith", seed=0, step_fn=None):
    params = init_params(cfg, jax.random.key(seed))
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=1e-3, warmup=5, decay_steps=steps,
                          weight_decay=0.0)
    fn = step_fn or jax.jit(make_train_step(cfg, opt_cfg))
    loader = SyntheticLM(cfg, seq=32, batch=8, pattern=pattern)
    losses = []
    for s in range(steps):
        batch = jax.tree_util.tree_map(jnp.asarray, loader.batch_at(s))
        params, opt, m = fn(params, opt, batch)
        losses.append(float(m["loss"]))
    return losses


def test_model_learns_arith_stream():
    cfg = get_config("gemma-2b").smoke()
    losses = _run(cfg, 60)
    assert losses[0] > 5.0  # ~ln(512) at init
    assert min(losses[-10:]) < losses[0] - 1.5, losses[::10]


def test_rns_allreduce_training_matches_fp32():
    """The paper-codec gradient path trains to the same losses as plain
    fp32 (quantization at 2^-16 is below optimizer noise)."""
    from repro.launch.train import make_rns_dp_step
    from repro.dist.grad_codec import GradCodec

    cfg = get_config("gemma-2b").smoke()
    opt_cfg = AdamWConfig(lr=1e-3, warmup=5, decay_steps=20, weight_decay=0.0)
    codec = GradCodec.make(world=2)
    rns_fn, _ = make_rns_dp_step(cfg, opt_cfg, codec)
    l_rns = _run(cfg, 15, step_fn=rns_fn)
    l_fp = _run(cfg, 15)
    np.testing.assert_allclose(l_rns, l_fp, rtol=2e-2, atol=2e-2)
