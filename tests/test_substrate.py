"""Substrate tests: gradient codec, checkpointing + fingerprints + elastic
restore, optimizer, data pipeline, sharding rules.
"""
import os

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.dist.fault import tensor_fingerprint, verify_fingerprints
from repro.dist.grad_codec import GradCodec
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


# ------------------------------------------------------------- grad codec
def test_codec_roundtrip_exact():
    codec = GradCodec.make(world=512)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 33)).astype(np.float32))
    packed = codec.encode(g)
    dec = codec.decode(codec.fold(packed))
    # quantization error only (1/2^frac_bits), no ring error
    np.testing.assert_allclose(np.asarray(dec), np.asarray(g),
                               atol=2.0 ** -codec.frac_bits)


def test_codec_simulated_allreduce_exact():
    """Sum of W replicas' encodings == encoding-sum (ring homomorphism),
    and decode gives the exact integer mean."""
    codec = GradCodec.make(world=64)
    rng = np.random.default_rng(1)
    W = 64
    gs = rng.standard_normal((W, 128)).astype(np.float32)
    packs = [np.asarray(codec.encode(jnp.asarray(g))) for g in gs]
    summed = jnp.asarray(np.sum(packs, axis=0))  # what psum produces
    dec = codec.decode(codec.fold(summed)) / W
    q = np.clip(np.round(gs * (1 << codec.frac_bits)), -codec.qmax, codec.qmax)
    want = q.sum(0) / (1 << codec.frac_bits) / W
    np.testing.assert_allclose(np.asarray(dec), want, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_codec_sign_and_clip_via_paper_compare(data):
    codec = GradCodec.make(world=8)
    v = data.draw(st.floats(-100.0, 100.0, allow_nan=False))
    packed = codec.encode(jnp.asarray([np.float32(v)]))
    folded = codec.fold(packed)
    q = int(np.clip(round(v * (1 << codec.frac_bits)), -codec.qmax, codec.qmax))
    assert bool(codec.is_negative(folded)[0]) == (q < 0)
    thr = data.draw(st.integers(1, codec.qmax))
    assert bool(codec.abs_ge(folded, thr)[0]) == (abs(q) >= thr)


def test_rns_psum_under_shard_map():
    """End-to-end: rns_psum inside shard_map over a CPU 'data' axis of 1."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.dist.grad_codec import rns_psum

    codec = GradCodec.make(world=4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = jnp.asarray(np.random.default_rng(3).standard_normal(32), jnp.float32)
    f = shard_map(
        lambda x: rns_psum(codec, x, "data"), mesh,
        in_specs=P(), out_specs=P(), check_rep=False,
    )
    out = f(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                               atol=2.0 ** -codec.frac_bits)


# ------------------------------------------------------------ fingerprints
def test_fingerprint_detects_bitflip():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((128, 64)).astype(np.float32)
    fp = tensor_fingerprint(a)
    b = a.copy()
    b[17, 3] = np.float32(np.frombuffer(
        np.uint32(np.frombuffer(b[17, 3].tobytes(), np.uint32)[0] ^ 1).tobytes(),
        np.float32)[0])
    assert tensor_fingerprint(b) != fp
    assert verify_fingerprints({"a": b}, {"a": fp}) == ["a"]
    assert verify_fingerprints({"a": a}, {"a": fp}) == []


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_corruption(tmp_path):
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree, extra={"note": "hi"})
    abs_tree = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    got, step, extra = ckpt.restore(d, abs_tree)
    assert step == 3 and extra["note"] == "hi"
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))

    # corrupt a tensor -> restore must reject, find_restorable must skip
    path = os.path.join(d, "step_3", "0.npy")
    arr = np.load(path)
    arr.ravel()[0] += 1
    np.save(path, arr)
    with pytest.raises(IOError):
        ckpt.restore(d, abs_tree, step=3)
    assert ckpt.latest_step(d) is None


def test_checkpoint_resume_picks_newest_valid(tmp_path):
    tree = {"w": jnp.zeros((4,), jnp.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    ckpt.save(d, 5, jax.tree_util.tree_map(lambda x: x + 5, tree))
    # torn save: step_9 dir without manifest (simulates crash mid-save)
    os.makedirs(os.path.join(d, "step_9"))
    assert ckpt.latest_step(d) == 5


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore onto an explicit 1-device NamedSharding —
    the elastic path (mesh change) exercised at CPU scale."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    abs_tree = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    got, _, _ = ckpt.restore(d, abs_tree, shardings=sh)
    assert got["w"].sharding == sh["w"]


def test_checkpoint_async(tmp_path):
    tree = {"w": jnp.ones((8, 8), jnp.float32)}
    d = str(tmp_path / "ck")
    t = ckpt.save_async(d, 7, tree)
    t.join()
    assert ckpt.latest_step(d) == 7


# --------------------------------------------------------------- optimizer
def test_adamw_descends():
    cfg = AdamWConfig(lr=0.1, warmup=0, decay_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    opt = adamw_init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, opt, gnorm = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 1.0
    assert int(opt["step"]) == 50


# -------------------------------------------------------------------- data
def test_data_deterministic_and_prefetch():
    from repro.configs import get_config

    cfg = get_config("gemma-2b").smoke()
    loader = SyntheticLM(cfg, seq=16, batch=4, seed=9)
    b1, b2 = loader.batch_at(10), loader.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 17)

    pf = Prefetcher(loader, start_step=0, depth=2)
    s0, batch0 = pf.next()
    s1, _ = pf.next()
    pf.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(batch0["tokens"], loader.batch_at(0)["tokens"])


# ----------------------------------------------------------------- sharding
def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import param_specs
    from repro.configs import get_config
    from repro.models import abstract_params

    # axis_types / AxisType only exist on newer jax; the mesh is incidental
    # here (the assertions below test the rule function directly).
    kwargs = (
        {"axis_types": (jax.sharding.AxisType.Auto,) * 2}
        if hasattr(jax.sharding, "AxisType")
        else {}
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"), **kwargs)
    # fake a 16-wide model axis by monkeypatching shape lookups is overkill;
    # instead test the rule function directly.
    from repro.dist.sharding import _rule

    # divisible heads shard; indivisible replicate (never head_dim)
    assert _rule("wq", (2048, 16, 128), 16, parent="attn") == [None, "model", None]
    assert _rule("wq", (2048, 8, 256), 16, parent="attn") == [None, None, None]
    assert _rule("embed", (256000, 2048), 16) == ["model", None]
    assert _rule("wi", (2048, 2, 16384), 16, parent="mlp") == [None, None, "model"]
    # stacked leaves: stack dims (leading) must NEVER shard
    assert _rule("wo", (48, 16384, 6144), 16, parent="mlp") == [
        None, "model", None]
    assert _rule("wo", (18, 16384, 2048), 16, parent="mlp") == [
        None, "model", None]
    assert _rule("wo", (28, 16, 256, 3072), 16, parent="attn") == [
        None, "model", None, None]
    # MoE: experts when divisible (moonshot 64), else expert-ff (qwen 60)
    assert _rule("wi", (64, 2048, 2, 1408), 16, n_experts=64) == [
        "model", None, None, None]
    assert _rule("wi", (60, 2048, 2, 1408), 16, n_experts=60) == [
        None, None, None, "model"]
    assert _rule("wo", (60, 1408, 2048), 16, n_experts=60) == [
        None, "model", None]  # 60 experts indivisible -> shard expert-ff
    # unstacked shared-block leaves (zamba2) must not crash or shard stacks
    assert _rule("wo", (8192, 2048), 16, parent="mlp") == ["model", None]
    assert _rule("wo", (32, 64, 2048), 16, parent="attn") == ["model", None, None]
