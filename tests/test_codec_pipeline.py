"""Fused single-buffer RNS all-reduce pipeline (DESIGN.md §9).

Tier-1 coverage (no optional deps): the fused Pallas encode/decode kernels
must be BITWISE identical to the jnp codec path on the tier-1 base (n=3,
bits=15), the bucketed ``rns_psum_tree`` must issue exactly ONE per-channel
psum for a multi-leaf pytree, and every fallback/guard rail must hold
(block padding, dynamic-range corners, M >= 2**45 rejection, x64 guard).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.grad_codec import (
    GradCodec,
    rns_psum,
    rns_psum_tree,
    tree_decode,
    tree_pack,
)
from repro.kernels import codec_decode_op, codec_encode_op


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _adversarial_grads(codec, rng, n=2048):
    """Normal mass plus every clip/sign corner the encode must get right."""
    return jnp.asarray(np.concatenate([
        rng.standard_normal(n).astype(np.float32),
        (rng.standard_normal(64) * 1e7).astype(np.float32),  # clips at qmax
        np.asarray([0.0, -0.0, 1e-9, -1e-9, np.inf, -np.inf,
                    codec.clip, -codec.clip,
                    np.nextafter(np.float32(codec.clip), np.float32(np.inf)),
                    -np.nextafter(np.float32(codec.clip), np.float32(np.inf))],
                   np.float32),
    ]))


# ------------------------------------------------------------ fused encode
@pytest.mark.parametrize("world", [2, 512])
def test_encode_kernel_bitwise_vs_jnp(world):
    codec = GradCodec.make(world=world)  # tier-1 base: n=3, bits=15
    g = _adversarial_grads(codec, np.random.default_rng(world))
    want = np.asarray(codec.encode(g))
    got = np.asarray(codec_encode_op(codec, g, interpret=True))
    np.testing.assert_array_equal(got, want)


def test_encode_kernel_block_padding_and_layout():
    codec = GradCodec.make(world=8)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(301).astype(np.float32))  # 301 % 128
    want = np.asarray(codec.encode(g))
    got = np.asarray(codec_encode_op(codec, g, block_b=128, interpret=True))
    np.testing.assert_array_equal(got, want)
    major = np.asarray(
        codec_encode_op(codec, g, block_b=128, interpret=True,
                        channel_major=True)
    )
    assert major.shape == (codec.base.n + 1, 301)
    np.testing.assert_array_equal(major.T, want)
    # leading batch dims round-trip through the (..., n+1) layout
    g2 = g[:300].reshape(4, 75)
    np.testing.assert_array_equal(
        np.asarray(codec_encode_op(codec, g2, block_b=64, interpret=True)),
        np.asarray(codec.encode(g2)),
    )


# ------------------------------------------------------------ fused decode
def _summed_for(codec, q):
    """Emulate the post-psum channel sums of integer values ``q``."""
    from repro.core.convert import tensor_to_rns

    q = jnp.asarray(q, jnp.int64)
    res = tensor_to_rns(codec.base, q)
    xa = jnp.mod(q, codec.base.ma)
    xa = jnp.where(q < 0, jnp.mod(xa + codec.base.M_mod_ma, codec.base.ma), xa)
    return jnp.concatenate(
        [res.astype(jnp.int32), xa[..., None].astype(jnp.int32)], axis=-1
    )


def test_decode_kernel_bitwise_vs_jnp():
    codec = GradCodec.make(world=64)
    rng = np.random.default_rng(1)
    gs = rng.standard_normal((64, 700)).astype(np.float32)
    packs = np.stack([np.asarray(codec.encode(jnp.asarray(r))) for r in gs])
    summed = jnp.asarray(packs.sum(0).astype(np.int32))
    want = np.asarray(codec.decode(codec.fold(summed)))
    got = np.asarray(codec_decode_op(codec, summed, interpret=True))
    np.testing.assert_array_equal(got, want)


def test_decode_kernel_block_padding_edge():
    """Batch not a multiple of block_b exercises the padding path."""
    codec = GradCodec.make(world=16)
    rng = np.random.default_rng(2)
    for batch in (1, 7, 129, 300):
        q = rng.integers(-codec.qmax, codec.qmax, size=batch) * 16
        summed = _summed_for(codec, q)
        want = np.asarray(codec.decode(codec.fold(summed)))
        got = np.asarray(
            codec_decode_op(codec, summed, block_b=128, interpret=True)
        )
        np.testing.assert_array_equal(got, want)


def test_decode_kernel_extreme_negative_sums():
    """Maximally negative sums at qmax * world: the dynamic-range corner
    where the signed fold's borrow chain and the f32 cast both peak."""
    codec = GradCodec.make(world=512)
    corners = np.asarray(
        [-codec.qmax, codec.qmax, -codec.qmax + 1, -1, 0, 1], np.int64
    ) * 512
    summed = _summed_for(codec, corners)
    want = np.asarray(codec.decode(codec.fold(summed)))
    got = np.asarray(codec_decode_op(codec, summed, block_b=8, interpret=True))
    np.testing.assert_array_equal(got, want)
    # the most-negative value really decodes negative and at full magnitude
    assert got[0] == -float(codec.qmax * 512) * 2.0 ** -codec.frac_bits


def test_kernels_reject_wide_dynamic_range():
    """M >= 2**45 breaks the 3-limb discipline: both ops refuse, and the
    codec-level dispatch falls back to the jnp path instead of calling them."""
    codec = GradCodec.make(world=2, n=4)  # M ~ 2**60
    assert codec.base.M >= 1 << 45 and not codec.use_fused
    g = jnp.ones((8,), jnp.float32)
    with pytest.raises(ValueError, match="2\\*\\*45"):
        codec_encode_op(codec, g, interpret=True)
    with pytest.raises(ValueError, match="2\\*\\*45"):
        codec_decode_op(codec, jnp.ones((8, 5), jnp.int32), interpret=True)
    # fallback: encode_packed/decode_summed still work (jnp path)
    packed = codec.encode_packed(g)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(codec.encode(g)))
    dec = codec.decode_summed(packed.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.ones(8), atol=1e-4)
    # channel_major fallback must match the kernel's flatten-then-transpose
    # layout even for non-1D input (not an axis-reversed .T)
    g2 = jnp.asarray(
        np.random.default_rng(9).standard_normal((3, 4)).astype(np.float32)
    )
    major = codec.encode_packed(g2, channel_major=True)
    assert major.shape == (codec.base.n + 1, 12)
    np.testing.assert_array_equal(
        np.asarray(major), np.asarray(codec.encode(jnp.ravel(g2))).T
    )


def test_encode_requires_x64():
    """GradCodec.encode silently mis-quantizes without global x64; it must
    refuse loudly instead (regression for the silent-degradation bug)."""
    codec = GradCodec.make(world=2)
    g = jnp.ones((4,), jnp.float32)
    assert codec.encode(g) is not None  # x64 on (repro import): fine
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(RuntimeError, match="x64"):
            codec.encode(g)
    finally:
        jax.config.update("jax_enable_x64", True)


# --------------------------------------------------------- bucketed psum
def _count_collectives(jaxpr, name="psum"):
    """Recursively count ``name`` primitives across nested (closed) jaxprs."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for vv in v if isinstance(v, (list, tuple)) else [v]:
                core = getattr(vv, "jaxpr", None)
                if hasattr(core, "eqns"):        # ClosedJaxpr
                    n += _count_collectives(core, name)
                elif hasattr(vv, "eqns"):        # bare Jaxpr
                    n += _count_collectives(vv, name)
    return n


def _grad_tree(rng):
    return {
        "wq": jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32)),
        "mlp": [
            jnp.asarray(rng.standard_normal(300).astype(np.float32)),
            jnp.asarray(rng.standard_normal((2, 3, 5)).astype(np.float32)),
        ],
        "scale": jnp.asarray(rng.standard_normal((7,)).astype(np.float32)),
    }


def test_rns_psum_tree_single_collective():
    """The bucketing claim itself: a 4-leaf pytree moves in EXACTLY one
    psum, where the per-leaf path pays one per leaf."""
    codec = GradCodec.make(world=4)
    mesh = _mesh1()
    tree = _grad_tree(np.random.default_rng(3))
    bucketed = jax.make_jaxpr(shard_map(
        lambda t: rns_psum_tree(codec, t, "data"), mesh,
        in_specs=(P(),), out_specs=P(), check_rep=False))(tree)
    per_leaf = jax.make_jaxpr(shard_map(
        lambda t: jax.tree_util.tree_map(
            lambda g: rns_psum(codec, g, "data"), t),
        mesh, in_specs=(P(),), out_specs=P(), check_rep=False))(tree)
    assert _count_collectives(bucketed.jaxpr) == 1
    assert _count_collectives(per_leaf.jaxpr) == len(
        jax.tree_util.tree_leaves(tree)
    )


@pytest.mark.parametrize("fused", [True, False])
def test_rns_psum_tree_matches_per_leaf_bitwise(fused):
    codec = GradCodec.make(world=4, fused=fused)
    mesh = _mesh1()
    tree = _grad_tree(np.random.default_rng(4))
    out = jax.jit(shard_map(lambda t: rns_psum_tree(codec, t, "data"), mesh,
                            in_specs=(P(),), out_specs=P(),
                            check_rep=False))(tree)
    ref = jax.jit(shard_map(
        lambda t: jax.tree_util.tree_map(
            lambda g: rns_psum(codec, g, "data"), t),
        mesh, in_specs=(P(),), out_specs=P(), check_rep=False))(tree)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rns_psum_tree_fused_equals_unfused_bitwise():
    """The acceptance bar: fused and jnp transport agree BIT FOR BIT on the
    tier-1 base (n=3, bits=15) — encode residues and decoded f32 alike."""
    fused = GradCodec.make(world=4, fused=True)
    plain = GradCodec.make(world=4, fused=False)
    assert fused.use_fused and not plain.use_fused
    rng = np.random.default_rng(5)
    g = _adversarial_grads(fused, rng, n=500)
    tree = {"a": g, "b": g[:37].reshape(37, 1) * 3.0}
    mesh = _mesh1()
    run = lambda c: jax.jit(shard_map(
        lambda t: rns_psum_tree(c, t, "data"), mesh,
        in_specs=(P(),), out_specs=P(), check_rep=False))(tree)
    a, b = run(fused), run(plain)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tree_pack_layout_and_dtype_restore():
    codec = GradCodec.make(world=2)
    rng = np.random.default_rng(6)
    tree = {
        "f32": jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32)),
        "bf16": jnp.asarray(
            rng.standard_normal(10).astype(np.float32)
        ).astype(jnp.bfloat16),
    }
    buf, meta = tree_pack(codec, tree)
    assert buf.shape == (codec.base.n + 1, 22) and buf.dtype == jnp.int32
    out = tree_decode(codec, buf, meta, denom=1.0)
    assert out["bf16"].dtype == jnp.bfloat16
    assert out["f32"].shape == (3, 4)
    np.testing.assert_allclose(
        np.asarray(out["f32"]), np.asarray(tree["f32"]),
        atol=2.0 ** -codec.frac_bits,
    )
    with pytest.raises(ValueError, match="empty"):
        tree_pack(codec, {})


# ------------------------------------------------------ optimizer boundary
def test_adamw_grad_decode_hook_equivalent():
    """Decoding inside adamw_update (the codec seam) must be exactly the
    same update as decoding before the call."""
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    codec = GradCodec.make(world=2)
    cfg = AdamWConfig()
    rng = np.random.default_rng(8)
    params = {"w": jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32))}
    grads = {"w": jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32))}
    buf, meta = tree_pack(codec, grads)
    summed = buf  # world-of-one psum
    decoded = tree_decode(codec, summed, meta)
    p_ref, s_ref, g_ref = adamw_update(
        cfg, params, decoded, adamw_init(params)
    )
    p_hook, s_hook, g_hook = adamw_update(
        cfg, params, summed, adamw_init(params),
        grad_decode=lambda s: tree_decode(codec, s, meta),
    )
    assert float(g_ref) == float(g_hook)
    np.testing.assert_array_equal(np.asarray(p_ref["w"]),
                                  np.asarray(p_hook["w"]))
    np.testing.assert_array_equal(np.asarray(s_ref["m"]["w"]),
                                  np.asarray(s_hook["m"]["w"]))


def test_train_step_rns_codec_smoke():
    """make_train_step(rns_codec=...) under shard_map: runs, returns finite
    metrics, and the fused/unfused variants agree bitwise on params."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train.data import SyntheticLM
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config("mamba2-370m").smoke()
    opt_cfg = AdamWConfig(warmup=2, decay_steps=4)
    params = init_params(cfg, jax.random.key(0))
    batch = jax.tree_util.tree_map(
        jnp.asarray, SyntheticLM(cfg, seq=16, batch=2).batch_at(0)
    )
    mesh = _mesh1()

    outs = {}
    for fused in (True, False):
        codec = GradCodec.make(world=2, fused=fused)
        step = make_train_step(cfg, opt_cfg, rns_codec=codec,
                               rns_axis="data")
        fn = jax.jit(shard_map(step, mesh,
                               in_specs=(P(), P(), P("data")),
                               out_specs=(P(), P(), P()),
                               check_rep=False))
        p2, _, metrics = fn(params, adamw_init(params), batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["gnorm"]))
        outs[fused] = p2
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
