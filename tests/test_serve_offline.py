"""Saturation harness invariants (DESIGN.md §16).

The tier-1 contract of the offline measurement layer:

* bucketed prefill is bitwise-INVISIBLE — a prompt padded to its bucket
  and prefilled in ONE extend call produces the same tokens and the same
  KV rows as the chunked loop, for LLM and mixed LLM+crypto traffic;
* warmup pre-compiles every (bucket, family) graph and the timed run adds
  ZERO retraces (the ``extend`` cache counts exactly the warmed widths);
* the completion pump preserves FIFO under a slow callback, applies
  bounded-queue backpressure, and propagates the FIRST callback error
  from put()/flush()/close() — never a silent hang;
* the replica set dispatches a shared admission queue to the least-loaded
  replica and completes everything exactly once.
"""
import threading
import time

import numpy as np
import pytest

import repro  # noqa: F401
from conftest import CACHE_LEN, CHUNK, kv_row as _row, make_engine
from repro.serve.offline import (
    CompletionPump,
    OfflineInference,
    ReplicaSet,
    pow2_buckets,
    replica_meshes,
    sample_stats,
)
from repro.serve.scheduler import Request

BUCKETS = (8, 16, 32)


def _requests(cfg, seed=0, n=4):
    rng = np.random.default_rng(seed)
    # lengths straddle the buckets: 5 -> 8, 11 -> 16, 3 -> 8, 17+ -> 32
    plens = [5, 11, 3, 17, 23, 7][:n]
    return [
        Request(rid=i,
                prompt=[int(t) for t in rng.integers(1, cfg.vocab, p)],
                max_new=6, eos=-1)
        for i, p in enumerate(plens)
    ]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    return make_engine(cfg, params, **kw)


# -- bucketed prefill bitwise identity ------------------------------------


def test_bucketed_prefill_bitwise_identity(cfg, params):
    """Same trace through the chunk loop and through single-call bucketed
    prefill: tokens AND the full KV trajectory must match bitwise — the
    pad region beyond plen-1 is causally invisible (logit_index reads the
    last real position; decode overwrites the pad)."""
    chunked = _engine(cfg, params)
    for r in _requests(cfg):
        chunked.submit(r)
    chunk_done = {r.rid: r for r in chunked.run_to_completion()}

    bucketed = _engine(cfg, params, prefill_buckets=BUCKETS)
    reqs_b = _requests(cfg)
    for r in reqs_b:
        bucketed.submit(r)
    buck_done = {r.rid: r for r in bucketed.run_to_completion()}

    assert sorted(buck_done) == sorted(chunk_done)
    for rid, rb in buck_done.items():
        rc = chunk_done[rid]
        assert rb.out == rc.out
        bk, bv = _row(bucketed, rb.slot_index, len(rb.prompt), len(rb.out))
        ck, cv = _row(chunked, rc.slot_index, len(rc.prompt), len(rc.out))
        np.testing.assert_array_equal(bk, ck)
        np.testing.assert_array_equal(bv, cv)
    st = bucketed.bucket_stats()
    assert sum(st["hits"].values()) == len(reqs_b)  # every prompt bucketed
    assert st["fallbacks"] == 0
    assert st["pad_tokens"] > 0  # the identity was demonstrated ON pads


def test_bucketed_identity_with_crypto_family(cfg, params):
    """Mixed LLM + crypto traffic: bucketing the LLM lane must not
    disturb either lane's results (one shared engine step interleaves
    decode ticks and ladder chunks)."""
    from repro.serve.crypto import CryptoContext, CryptoRequest

    ctx = CryptoContext(n_limbs=8, exp_bits=16)

    def crypto_reqs(rid0):
        return [
            CryptoRequest(rid=rid0, op="modexp", a=12345, b=777, n=99991),
            CryptoRequest(rid=rid0 + 1, op="modmul", a=4321, b=8765,
                          n=99991),
        ]

    results = []
    for buckets in (None, BUCKETS):
        eng = _engine(cfg, params, prefill_buckets=buckets,
                      crypto_slots=2, crypto_ctx=ctx)
        for r in _requests(cfg, n=3):
            eng.submit(r)
        for r in crypto_reqs(100):
            eng.submit(r)
        eng.run_to_completion()
        llm = {r.rid: list(r.out) for r in eng.sched.completed}
        crypto = {r.rid: r.result for r in eng.crypto.completed}
        results.append((llm, crypto))
    assert results[0] == results[1]
    assert results[0][1][100] == pow(12345, 777, 99991)
    assert results[0][1][101] == (4321 * 8765) % 99991


def test_bucket_stats_count_fallback_traffic(cfg, params):
    """Over-bucket prompts fall back to the chunk loop; their chunk-grid
    pads AND real tokens must still land in the pad-overhead accounting.
    (Regression: fallback tokens used to vanish from both terms, so
    ``pad_overhead`` understated pad cost and overstated the bucketed
    share of traffic.)"""
    rng = np.random.default_rng(5)
    mk = lambda rid, plen: Request(
        rid=rid, prompt=[int(t) for t in rng.integers(1, cfg.vocab, plen)],
        max_new=2)
    eng = _engine(cfg, params, prefill_buckets=(8,))
    eng.submit(mk(0, 5))   # bucketed: 3 pads / 5 real
    eng.submit(mk(1, 20))  # fallback: ceil(20/8)*8 - 20 = 4 pads / 20 real
    eng.run_to_completion()
    st = eng.bucket_stats()
    assert st["fallbacks"] == 1 and st["hits"]["8"] == 1
    assert st["pad_tokens"] == 3 + 4
    assert st["real_tokens"] == 5 + 20
    assert st["pad_overhead"] == pytest.approx(7 / 25)
    # same contract on the paged engine ("real" = tokens the extend
    # computed, so the fallback's chunk-grid pads count there too)
    pgd = _engine(cfg, params, page_size=8, prefill_buckets=(8,))
    pgd.submit(mk(2, 20))
    pgd.run_to_completion()
    st = pgd.bucket_stats()
    assert st["fallbacks"] == 1
    assert st["pad_tokens"] == 4 and st["real_tokens"] == 20


def test_bucket_validation(cfg, params):
    # buckets + paged pool is a legal combination now (padded write
    # barrier): the ladder reaches the scheduler so admission reserves
    # by the same bucketed-vs-chunk rule the engine dispatches by
    eng = _engine(cfg, params, page_size=8, prefill_buckets=BUCKETS)
    assert eng.sched.prefill_buckets == BUCKETS
    with pytest.raises(ValueError, match="out of range"):
        _engine(cfg, params, prefill_buckets=(0, 8))
    with pytest.raises(ValueError, match="out of range"):
        _engine(cfg, params, prefill_buckets=(8, CACHE_LEN + 1))
    with pytest.raises(ValueError, match=">= 1 bucket"):
        _engine(cfg, params, prefill_buckets=())


def test_pow2_buckets_ladder():
    assert pow2_buckets(128) == (8, 16, 32, 64, 128)
    assert pow2_buckets(48) == (8, 16, 32, 48)  # cache_len appended
    assert pow2_buckets(8) == (8,)
    with pytest.raises(ValueError):
        pow2_buckets(0)


# -- warmup / steady state -------------------------------------------------


def test_warmup_compiles_buckets_and_run_is_retrace_free(cfg, params):
    harness = OfflineInference(
        cfg, params, n_slots=4, cache_len=CACHE_LEN, prefill_chunk=CHUNK,
        buckets=BUCKETS, overlap=True, queue_size=8,
    )
    warm = harness.warmup()
    # one compiled extend graph per bucket width, snapshot at warmup
    assert warm["jit_traces"][0]["extend"] == len(BUCKETS)
    rep = harness.run(_requests(cfg, seed=3, n=6))
    harness.require_steady_state()  # zero steady-state retraces
    assert rep["retrace_free"]
    assert rep["requests"] == 6
    assert rep["tokens_out"] == 6 * 6
    assert rep["buckets"]["fallbacks"] == 0
    assert sum(rep["buckets"]["hits"].values()) == 6
    assert rep["overlap"]["processed"] == 6


def test_run_before_warmup_refused(cfg, params):
    harness = OfflineInference(cfg, params, n_slots=2,
                               cache_len=CACHE_LEN, buckets=BUCKETS)
    with pytest.raises(RuntimeError, match="warmup"):
        harness.run(_requests(cfg, n=1))


# -- completion pump -------------------------------------------------------


def test_pump_preserves_order_under_slow_callback():
    def slow(x):
        time.sleep(0.002)
        return x * 10

    with CompletionPump(slow, queue_size=4) as pump:
        for i in range(16):
            pump.put(i)
        pump.flush()
        assert pump.completed == [(i, i * 10) for i in range(16)]


def test_pump_bounded_queue_backpressure():
    gate = threading.Event()

    def gated(x):
        gate.wait(5.0)
        return x

    pump = CompletionPump(gated, queue_size=2)
    pump.put(0)  # worker picks this up and parks on the gate
    time.sleep(0.05)
    pump.put(1), pump.put(2)  # queue now full
    t = threading.Thread(target=pump.put, args=(3,))
    t.start()
    t.join(0.1)
    assert t.is_alive()  # producer genuinely blocked on the bound
    gate.set()
    t.join(5.0)
    assert not t.is_alive()
    pump.flush()
    pump.close()
    st = pump.stats()
    assert st["processed"] == 4
    assert st["blocked_puts"] >= 1
    assert st["max_depth"] <= 2


def test_pump_callback_error_propagates_and_drains():
    gate = threading.Event()

    def boom(x):
        if x == 0:
            gate.wait(5.0)
            raise ValueError("detokenize failed on 0")
        return x

    pump = CompletionPump(boom, queue_size=2)
    pump.put(0)  # worker picks it up and parks on the gate
    time.sleep(0.05)
    pump.put(1), pump.put(2)  # queued behind the failure
    gate.set()
    with pytest.raises(ValueError, match="failed on 0"):
        pump.flush()
    pump.close()  # error already consumed: close is clean + idempotent
    pump.close()
    # nothing after the failure completes; the backlog drained as drops
    assert pump.completed == []
    assert pump.stats()["dropped"] == 2


def test_pump_error_surfaces_from_put_without_hanging():
    def boom(x):
        if x == 2:
            raise ValueError("detokenize failed on 2")
        return x

    pump = CompletionPump(boom, queue_size=2)
    with pytest.raises(ValueError, match="failed on 2"):
        for i in range(64):  # keeps producing past the failure: the
            pump.put(i)      # error must surface from put(), and drain-
        pump.flush()         # after-error keeps the bound from deadlock
    pump.close()
    done = [x for x, _ in pump.completed]
    assert 2 not in done  # the failed item never lands in completed
    assert done[:2] == [0, 1]


def test_pump_put_after_close_refused():
    pump = CompletionPump(lambda x: x)
    pump.close()
    with pytest.raises(RuntimeError, match="closed"):
        pump.put(0)


# -- replica set -----------------------------------------------------------


def test_replica_meshes_single_device_fallback():
    assert replica_meshes(1) in ([None], )  # 1 replica, 1 device
    assert replica_meshes(3) == [None, None, None]  # 1 device can't split
    with pytest.raises(ValueError):
        replica_meshes(0)


def test_replica_set_shared_queue_least_loaded(cfg, params):
    engines = [_engine(cfg, params, n_slots=2) for _ in range(2)]
    rs = ReplicaSet(engines)
    for r in _requests(cfg, seed=5, n=6):
        rs.submit(r)
    placed = rs.pump(0.0)
    # 2 replicas x 2 slots: exactly 4 dispatch, 2 park in the shared queue
    assert placed == 4
    assert rs.dispatched == [2, 2]  # least-loaded = even split
    assert len(rs.queue) == 2
    done = []
    t = 0.0
    while rs.busy:
        rs.pump(t)
        done.extend(rs.step_all(t))
        t += 1.0
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4, 5]
    assert sum(rs.dispatched) == 6
    assert min(rs.dispatched) >= 2  # nobody starved


def test_offline_harness_two_replicas_end_to_end(cfg, params):
    harness = OfflineInference(
        cfg, params, n_slots=2, cache_len=CACHE_LEN, prefill_chunk=CHUNK,
        buckets=BUCKETS, replicas=2, queue_size=8,
    )
    harness.warmup()
    rep = harness.run(_requests(cfg, seed=7, n=6))
    harness.require_steady_state()
    assert rep["replicas"] == 2
    assert sum(rep["dispatched"]) == 6
    assert min(rep["dispatched"]) >= 1  # both replicas served traffic
    assert rep["requests"] == 6
    assert rep["ttft_s"]["n"] == 6
    assert rep["latency_s"]["p99"] >= rep["ttft_s"]["p50"] >= 0


# -- stats guard -----------------------------------------------------------


def test_sample_stats_empty_guard():
    assert sample_stats([]) == {"n": 0, "mean": 0.0, "p50": 0.0,
                                "p95": 0.0, "p99": 0.0}
    st = sample_stats([1.0, 2.0, 3.0])
    assert st["n"] == 3 and st["p50"] == 2.0
