"""RnsArray typed-frontend guarantees (core/array.py, DESIGN.md §11):

* every legacy entry point (rns_compare_ge, compare_packed_ge, divmod_rns,
  encode_signed, halve/scale_pow2, extend_mrc, GradCodec.encode) is
  BITWISE-identical to its RnsArray counterpart on randomized inputs —
  the shim contract that let the legacy tests survive the API redesign
  unmodified;
* RnsArray is a real pytree: jit / vmap / tree_map / flatten round-trips
  preserve both the buffer and the static aux;
* the backend context manager swaps implementations (jnp <-> Pallas
  kernels) without changing a single output bit.

Randomized with seeded numpy (no optional deps) — the hypothesis-based
exactness suites in test_core_rns.py cover the underlying algorithms.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    Layout,
    RnsArray,
    backend,
    compare_packed_ge,
    divmod_rns,
    encode_signed,
    extend_mrc,
    get_backend,
    halve,
    make_base,
    pack,
    rns_compare_ge,
    rns_to_int,
    scale_pow2,
)
from repro.dist.grad_codec import GradCodec, tree_pack, tree_pack_rns

BASE8 = make_base(4, bits=8)
BASE15 = make_base(6, bits=15)


def _rand_pairs(base, k, rng):
    draw = lambda: int.from_bytes(rng.bytes(16), "little") % base.M
    vals1 = [draw() for _ in range(k)]
    vals2 = [draw() for _ in range(k)]
    # adversarial edges: equal, adjacent, extremes
    vals1[:4] = [0, base.M - 1, base.M // 2, vals2[3]]
    vals2[:4] = [0, base.M - 1, base.M // 2 + 1, vals2[3]]
    return vals1, vals2


def _lift(base, vals):
    x = jnp.asarray(np.stack([base.residues_of(v) for v in vals]))
    xa = jnp.asarray(np.asarray([v % base.ma for v in vals], base.dtype))
    return x, xa


# ----------------------------------------------------- shim bitwise identity
@pytest.mark.parametrize("base", [BASE8, BASE15], ids=["8bit", "15bit"])
def test_compare_shims_bitwise(base):
    rng = np.random.default_rng(0)
    vals1, vals2 = _rand_pairs(base, 64, rng)
    x1, a1 = _lift(base, vals1)
    x2, a2 = _lift(base, vals2)
    truth = np.asarray(vals1) >= np.asarray(vals2)

    legacy = np.asarray(rns_compare_ge(base, x1, a1, x2, a2))
    legacy_packed = np.asarray(
        compare_packed_ge(base, pack(base, x1, a1), pack(base, x2, a2))
    )
    arr1 = RnsArray.from_parts(base, x1, a1)
    arr2 = RnsArray.from_parts(base, x2, a2)
    typed = np.asarray(arr1.compare_ge(arr2))
    op = np.asarray(arr1 >= arr2)

    np.testing.assert_array_equal(legacy, truth)
    np.testing.assert_array_equal(legacy_packed, truth)
    np.testing.assert_array_equal(typed, truth)
    np.testing.assert_array_equal(op, truth)
    # strict/reversed operators agree with exact semantics
    np.testing.assert_array_equal(
        np.asarray(arr1 < arr2), ~truth
    )
    np.testing.assert_array_equal(
        np.asarray(arr1 > arr2), np.asarray(vals1) > np.asarray(vals2)
    )


def test_divmod_shim_bitwise():
    base = make_base(3, bits=8)
    rng = np.random.default_rng(1)
    X = [int(rng.integers(0, base.M)) for _ in range(8)]
    D = [max(1, int(rng.integers(1, base.M))) for _ in range(8)]
    xp = pack(base, *_lift(base, X))
    dp = pack(base, *_lift(base, D))

    q_legacy, r_legacy = divmod_rns(base, xp, dp)
    q, r = RnsArray.from_packed(base, xp).divmod(
        RnsArray.from_packed(base, dp)
    )
    np.testing.assert_array_equal(np.asarray(q_legacy),
                                  np.asarray(q.to_packed()))
    np.testing.assert_array_equal(np.asarray(r_legacy),
                                  np.asarray(r.to_packed()))
    for i in range(8):
        assert (
            rns_to_int(base, np.asarray(q.x[i])),
            rns_to_int(base, np.asarray(r.x[i])),
        ) == divmod(X[i], D[i])


def test_encode_signed_shim_bitwise():
    base = make_base(3, bits=15)
    rng = np.random.default_rng(2)
    bound = (base.M - 1) // 2
    v = jnp.asarray(rng.integers(-bound, bound, size=64, dtype=np.int64))
    legacy = np.asarray(encode_signed(base, v))
    arr = RnsArray.encode_signed(base, v)
    np.testing.assert_array_equal(legacy, np.asarray(arr.to_packed()))
    assert arr.signed and arr.layout is Layout.BASE_MA
    np.testing.assert_array_equal(np.asarray(arr.to_int()), np.asarray(v))
    np.testing.assert_array_equal(
        np.asarray(arr.is_negative()), np.asarray(v) < 0
    )


def test_halve_scale_extend_shims_bitwise():
    base = BASE8
    rng = np.random.default_rng(3)
    vals = [int(rng.integers(0, base.M)) for _ in range(16)]
    packed = pack(base, *_lift(base, vals))
    arr = RnsArray.from_packed(base, packed)

    np.testing.assert_array_equal(
        np.asarray(halve(base, packed)),
        np.asarray(arr.halve().to_packed()),
    )
    np.testing.assert_array_equal(
        np.asarray(scale_pow2(base, packed, 3)),
        np.asarray(arr.scale_pow2(3).to_packed()),
    )
    assert arr.scale_pow2(3).to_int().tolist() == [v // 8 for v in vals]
    targets = (251, 241)
    np.testing.assert_array_equal(
        np.asarray(extend_mrc(base, arr.x, targets)),
        np.asarray(arr.extend(targets)),
    )


@pytest.mark.parametrize("correct", [False, True], ids=["detect", "rrns"])
def test_grad_codec_encode_bitwise(correct):
    codec = GradCodec.make(world=4, correct=correct)
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal(128).astype(np.float32))

    raw = codec.encode(g)
    arr = codec.encode_array(g)
    assert arr.layout is codec.layout
    assert arr.signed and arr.mb == codec.mb
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(arr.to_packed()))

    wire = codec.encode_array(g, channel_major=True)
    assert wire.channel_axis == 0
    np.testing.assert_array_equal(
        np.asarray(codec.encode_packed(g, channel_major=True)),
        np.asarray(wire.residues),
    )
    # typed fold/normalize return in kind and match the raw path bitwise
    folded = codec.fold(arr)
    assert isinstance(folded, RnsArray)
    np.testing.assert_array_equal(
        np.asarray(codec.fold(raw)), np.asarray(folded.to_packed())
    )
    norm = codec.normalize(folded)
    np.testing.assert_array_equal(
        np.asarray(codec.normalize(codec.fold(raw))),
        np.asarray(norm.to_packed()),
    )


def test_grad_codec_correct_typed_wire():
    codec = GradCodec.make(world=2, correct=True)
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    wire = codec.encode_array(g, channel_major=True)
    m0 = int(codec.base.moduli[0])
    bad = type(wire).tree_unflatten(
        wire.tree_flatten()[1],
        (wire.residues.at[0, 3].set(jnp.mod(wire.residues[0, 3] + 5, m0)),),
    )
    fixed, fault = codec.correct_packed(bad)
    assert isinstance(fixed, RnsArray) and fixed.channel_axis == 0
    assert int(fault[3]) == 0 and int(jnp.sum(fault >= 0)) == 1
    np.testing.assert_array_equal(
        np.asarray(fixed.residues), np.asarray(wire.residues)
    )
    # raw path agrees bitwise
    fixed_raw, fault_raw = codec.correct_packed(bad.to_packed())
    np.testing.assert_array_equal(
        np.asarray(fixed_raw), np.asarray(fixed.to_packed())
    )
    np.testing.assert_array_equal(np.asarray(fault_raw), np.asarray(fault))


def test_tree_pack_rns_matches_raw():
    codec = GradCodec.make(world=2)
    rng = np.random.default_rng(6)
    tree = {
        "a": jnp.asarray(rng.standard_normal((4, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal(7).astype(np.float32)),
    }
    buf, meta = tree_pack(codec, tree)
    arr, meta2 = tree_pack_rns(codec, tree)
    assert isinstance(arr, RnsArray) and arr.channel_axis == 0
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(arr.residues))
    assert meta.shapes == meta2.shapes and meta.dtypes == meta2.dtypes


# ------------------------------------------------------------ pytree-ness
def test_pytree_roundtrip_jit_vmap_treemap():
    base = BASE8
    a = RnsArray.encode(base, jnp.asarray([[5, 9], [100, 2]]))

    leaves, treedef = jax.tree_util.tree_flatten(a)
    assert len(leaves) == 1
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.base == base and back.layout is a.layout
    np.testing.assert_array_equal(np.asarray(back.residues),
                                  np.asarray(a.residues))

    # jit: static aux survives, values untouched, arithmetic traces
    f = jax.jit(lambda u, v: u + v)
    s = f(a, a)
    assert isinstance(s, RnsArray) and s.layout is Layout.BASE_MA
    assert s.to_int().tolist() == [[10, 18], [200, 4]]

    # vmap over the leading batch axis
    digits = jax.vmap(lambda u: u.to_mrs())(a)
    assert digits.shape == (2, 2, base.n)

    # tree_map sees exactly one leaf
    shapes = jax.tree_util.tree_map(lambda x: x.shape, a)
    assert shapes.residues == (2, 2, a.n_channels)


def test_pytree_psum_single_collective():
    """An RnsArray flows through lax.psum as ONE leaf — the bucketed
    transport's single-collective guarantee survives the typed wire."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    codec = GradCodec.make(world=max(len(jax.devices()), 2))
    mesh = Mesh(np.array(jax.devices()), ("data",))
    g = jnp.ones((8,), jnp.float32)

    def step(x):
        arr = codec.encode_array(x, channel_major=True)
        return jax.lax.psum(arr, "data")

    jaxpr = jax.make_jaxpr(
        shard_map(step, mesh, in_specs=P(), out_specs=P(), check_rep=False)
    )(g)
    assert str(jaxpr).count("psum") == 1


def test_constructor_validation():
    base = BASE8
    with pytest.raises(ValueError):  # RRNS needs mb
        RnsArray(jnp.zeros((3, base.n + 2), jnp.int32), base,
                 layout=Layout.RRNS)
    with pytest.raises(ValueError):  # channel count mismatch
        RnsArray(jnp.zeros((3, base.n + 1), jnp.int32), base,
                 layout=Layout.BASE)
    with pytest.raises(ValueError):  # BASE layout cannot compare
        a = RnsArray.encode(base, jnp.asarray([1]), layout=Layout.BASE)
        _ = a >= a
    with pytest.raises(ValueError):  # unsigned arrays have no sign
        RnsArray.encode(base, jnp.asarray([1])).is_negative()
    arr = RnsArray.encode(base, jnp.asarray([7, 8]))
    wire = arr.with_channel_axis(0)
    assert wire.residues.shape == (arr.n_channels, 2)
    np.testing.assert_array_equal(
        np.asarray(wire.with_channel_axis(-1).residues),
        np.asarray(arr.residues),
    )


def test_signed_halve_rejected_and_operand_protocol():
    base = BASE8
    s = RnsArray.encode_signed(base, jnp.asarray([-7]))
    with pytest.raises(ValueError):  # floor(X/2) is wrong for negative v
        s.halve()
    with pytest.raises(ValueError):
        s.scale_pow2(2)
    a = RnsArray.encode(base, jnp.asarray([5]))
    with pytest.raises(TypeError):  # NotImplemented propagates, not AttrError
        _ = a <= "foo"
    with pytest.raises(TypeError):
        _ = a > object()
    with pytest.raises(TypeError):
        _ = a >= 1.5
    with pytest.raises(TypeError):
        _ = a < None
    # typed kernel entry points validate operands like the operators do
    from repro.kernels import compare_op, modmul_op

    other = RnsArray.encode(make_base(4, bits=9), jnp.asarray([5]))
    with pytest.raises(ValueError):
        modmul_op(a, other)
    with pytest.raises(ValueError):
        compare_op(a, other)
    with pytest.raises(ValueError):  # too FEW channels is a clear error
        RnsArray.from_packed(base, jnp.zeros((2, base.n - 1), jnp.int32))


def test_mixed_layout_and_base_rejected():
    a = RnsArray.encode(BASE8, jnp.asarray([1]))
    b = RnsArray.encode(BASE8, jnp.asarray([1]), layout=Layout.BASE)
    with pytest.raises(ValueError):
        _ = a + b
    c = RnsArray.encode(make_base(3, bits=8), jnp.asarray([1]))
    with pytest.raises(ValueError):
        _ = a + c


# ------------------------------------------------------------ backend knob
def test_backend_context_bitwise_and_restores():
    base = BASE15
    rng = np.random.default_rng(7)
    vals1, vals2 = _rand_pairs(base, 32, rng)
    a = RnsArray.from_parts(base, *_lift(base, vals1))
    b = RnsArray.from_parts(base, *_lift(base, vals2))

    assert get_backend() == "auto"
    with backend("jnp"):
        ge_jnp = np.asarray(a >= b)
        mul_jnp = np.asarray((a * b).residues)
        mrs_jnp = np.asarray(a.to_mrs())
    with backend("pallas"):
        assert get_backend() == "pallas"
        ge_pl = np.asarray(a >= b)
        mul_pl = np.asarray((a * b).residues)
        mrs_pl = np.asarray(a.to_mrs())
    assert get_backend() == "auto"

    np.testing.assert_array_equal(ge_jnp, ge_pl)
    np.testing.assert_array_equal(mul_jnp, mul_pl)
    np.testing.assert_array_equal(mrs_jnp, mrs_pl)
    np.testing.assert_array_equal(
        ge_jnp, np.asarray(vals1) >= np.asarray(vals2)
    )

    with pytest.raises(ValueError):
        with backend("cuda"):
            pass


def test_backend_overrides_codec_fused():
    codec = GradCodec.make(world=2)           # qualifies for the kernels
    assert codec.use_fused                    # auto: fused on
    with backend("jnp"):
        assert not codec.use_fused            # forced reference path
    unfused = GradCodec.make(world=2, fused=False)
    with backend("pallas"):
        assert unfused.use_fused              # forced kernels
    g = jnp.asarray(np.random.default_rng(8)
                    .standard_normal(32).astype(np.float32))
    with backend("jnp"):
        ref = np.asarray(codec.encode_packed(g))
    with backend("pallas"):
        fused = np.asarray(codec.encode_packed(g))
    np.testing.assert_array_equal(ref, fused)  # bitwise across backends
