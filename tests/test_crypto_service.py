"""Crypto service tests: Montgomery-over-RnsArray exactness (jnp and
Pallas bitwise-identical), modexp == pow() across multi-limb bases, the
engine's second request family (oracle results, fingerprint verify,
corrupt/repair, no-retrace), the mixed-workload bitwise-isolation
invariant, and the launcher's crypto trace family.

Every assertion is differential against Python's big ints — the whole
point of the crypto workload as a TEST program: pow()/divmod() are an
oracle the RNS dataflow cannot fool.
"""
import json
import math
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_config
from repro.core import backend, rns_to_int
from repro.core.array import Layout, RnsArray
from repro.core.base import RNSBase, gen_coprime_moduli
from repro.core.montgomery import (
    DualRep,
    RNSMontgomery,
    ladder_step,
    mont_consts,
    mont_mul,
)
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.crypto import CryptoContext, CryptoLane, CryptoRequest
from repro.serve.scheduler import Request

CACHE_LEN = 32
CHUNK = 8


@pytest.fixture(scope="module")
def cfg():
    return get_config("gemma-2b").smoke()


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))


def _bases(n_limbs: int) -> tuple[RNSBase, RNSBase, int]:
    """A dual Montgomery base pair (interleaved draw) + spare modulus for
    RRNS layouts."""
    k = n_limbs
    ms = gen_coprime_moduli(2 * k + 3, 15)
    B = RNSBase(moduli=tuple(ms[0:2 * k:2]), ma=ms[2 * k], bits=15)
    Bp = RNSBase(moduli=tuple(ms[1:2 * k:2]), ma=ms[2 * k + 1], bits=15)
    return B, Bp, ms[2 * k + 2]


def _dual_of(B, Bp, vals, *, mb=None):
    """Host-exact DualRep of a batch of big ints (< 2N in practice)."""
    lo_t = tuple(B.moduli) + (B.ma,) + ((mb,) if mb else ())
    lo = [[v % t for t in lo_t] for v in vals]
    hi = [list(Bp.residues_of(v)) for v in vals]
    return DualRep(
        RnsArray.from_packed(B, jnp.asarray(lo, B.dtype), mb=mb),
        RnsArray.from_packed(Bp, jnp.asarray(hi, Bp.dtype)),
    )


# --------------------------------------------------- kernel == reference
@pytest.mark.parametrize("layout", [Layout.BASE_MA, Layout.RRNS])
def test_mont_mul_pallas_bitwise_matches_jnp(layout):
    """One Montgomery product: the fused Pallas kernel must equal the
    pure-jnp reference BITWISE on every channel (redundant ones too),
    and both must equal the x*y*M^{-1} mod N big-int oracle."""
    B, Bp, spare = _bases(6)
    mb = spare if layout is Layout.RRNS else None
    N = (B.M // 5) | 1
    while math.gcd(N, B.M * Bp.M) != 1:
        N += 2
    c = mont_consts(B, Bp, N, layout=layout, mb=mb)
    rng = random.Random(7)
    xs = [rng.randrange(2 * N) for _ in range(5)]
    ys = [rng.randrange(2 * N) for _ in range(5)]
    x = _dual_of(B, Bp, xs, mb=mb)
    y = _dual_of(B, Bp, ys, mb=mb)
    outs = {}
    for name in ("jnp", "pallas"):
        with backend(name):
            r = mont_mul(x, y, c["neg"], c["n_hi"])
        outs[name] = (np.asarray(r.lo.to_packed()),
                      np.asarray(r.hi.to_packed()))
    np.testing.assert_array_equal(outs["jnp"][0], outs["pallas"][0])
    np.testing.assert_array_equal(outs["jnp"][1], outs["pallas"][1])
    Minv = pow(B.M, -1, N)
    lo_t = tuple(B.moduli) + (B.ma,) + ((mb,) if mb else ())
    for i, (a, b) in enumerate(zip(xs, ys)):
        R = rns_to_int(B, outs["jnp"][0][i][: B.n])
        assert R < 2 * N and R % N == (a * b * Minv) % N
        # redundant channels carry the TRUE residues of R (< 2N < M,
        # no wrap) — that is what makes the wire fingerprints work
        assert [int(v) for v in outs["jnp"][0][i]] == [R % t for t in lo_t]


def test_ladder_step_pallas_bitwise_matches_jnp():
    """The fused ladder-bit kernel (2 products + branchless select) ==
    the jnp composition, bitwise, for both bit values in one batch."""
    B, Bp, _ = _bases(6)
    N = (B.M // 6) | 1
    while math.gcd(N, B.M * Bp.M) != 1:
        N += 2
    c = mont_consts(B, Bp, N)
    rng = random.Random(11)
    r0 = _dual_of(B, Bp, [rng.randrange(2 * N) for _ in range(6)])
    r1 = _dual_of(B, Bp, [rng.randrange(2 * N) for _ in range(6)])
    bit = jnp.asarray([0, 1, 0, 1, 1, 0], jnp.int32)
    outs = {}
    for name in ("jnp", "pallas"):
        with backend(name):
            a, b = ladder_step(r0, r1, bit, c["neg"], c["n_hi"])
        outs[name] = [np.asarray(p) for p in
                      (a.lo.to_packed(), a.hi.to_packed(),
                       b.lo.to_packed(), b.hi.to_packed())]
    for got, want in zip(outs["pallas"], outs["jnp"]):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n_limbs", [6, 12])
@pytest.mark.parametrize("backend_name", ["jnp", "pallas"])
def test_modexp_matches_pow_oracle(n_limbs, backend_name):
    """Fixed-width Montgomery-ladder modexp == pow(a, e, N) on multi-limb
    bases (90 and 180 bits of range), under BOTH backends — the ISSUE's
    acceptance criterion."""
    B, Bp, _ = _bases(n_limbs)
    N = (B.M // 7) | 1
    while math.gcd(N, B.M * Bp.M) != 1:
        N += 2
    rng = random.Random(n_limbs)
    with backend(backend_name):
        mont = RNSMontgomery(B, Bp, N)
        for a, e in [(rng.randrange(1, N), rng.randrange(1 << 16)),
                     (rng.randrange(1, N), 0),
                     (rng.randrange(1, N), 1),
                     (N - 1, (1 << 16) - 1)]:
            assert mont.modexp(a, e) == pow(a, e, N), (a, e)


# ------------------------------------------------------- engine: crypto
def _ctx():
    return CryptoContext(n_limbs=4, exp_bits=16)


def _crypto_engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("crypto_slots", 2)
    kw.setdefault("crypto_ctx", _ctx())
    kw.setdefault("crypto_chunk", 4)
    return ContinuousBatcher(cfg, params, **kw)


def _crypto_reqs(ctx, seed=0, rid0=100):
    rng = random.Random(seed)
    MMp = ctx.baseB.M * ctx.baseBp.M

    def modulus():
        while True:
            N = rng.randrange(5, ctx.n_max) | 1
            if math.gcd(N, MMp) == 1:
                return N

    reqs, oracle = [], {}
    for i in range(3):
        N = modulus()
        a, e = rng.randrange(1, N), rng.randrange(1 << 16)
        reqs.append(CryptoRequest(rid=rid0 + i, op="modexp", a=a, b=e, n=N))
        oracle[rid0 + i] = pow(a, e, N)
    N = modulus()
    a, b = rng.randrange(1, N), rng.randrange(1, N)
    reqs.append(CryptoRequest(rid=rid0 + 3, op="modmul", a=a, b=b, n=N))
    oracle[rid0 + 3] = (a * b) % N
    a, d = rng.randrange(ctx.baseB.M), rng.randrange(1, ctx.baseB.M)
    reqs.append(CryptoRequest(rid=rid0 + 4, op="divmod", a=a, b=d))
    oracle[rid0 + 4] = divmod(a, d)
    return reqs, oracle


def test_engine_crypto_only_oracle_verify_and_no_retrace(cfg, params):
    eng = _crypto_engine(cfg, params, rns_verify=True)
    reqs, oracle = _crypto_reqs(eng.crypto_ctx)
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == sorted(oracle)
    for r in done:
        assert r.result == oracle[r.rid], (r.rid, r.op)
        assert eng.verify_log[r.rid] is True
        assert r.t_done is not None and r.t_admit is not None
    sizes = eng.jit_cache_sizes()
    for name in ("admit", "step", "final", "modmul", "divmod",
                 "fingerprint"):
        assert sizes[f"crypto_{name}"] == 1, sizes
    # slot churn: 3 modexps through 2 lane slots means at least one reuse
    by_slot = {}
    for r in done:
        if r.op == "modexp":
            by_slot.setdefault(r.slot_index, []).append(r.rid)
    assert set(by_slot) <= {0, 1}
    drained = eng.drain_completed()
    assert sorted(r.rid for r in drained) == sorted(oracle)
    assert eng.verify_log == {} and len(eng.crypto.completed) == 0


def test_engine_crypto_wire_corrupt_detect_and_repair(cfg, params):
    eng = _crypto_engine(cfg, params, rns_verify=True)
    ctx = eng.crypto_ctx
    N = 1000003
    assert math.gcd(N, ctx.baseB.M * ctx.baseBp.M) == 1
    eng.submit(CryptoRequest(rid=1, op="modexp", a=777, b=4321, n=N))
    eng.try_admit(0.0)   # slot bound, fingerprint published
    key = ("crypto", 1)
    assert eng.wire_ok(key)
    eng.corrupt_wire(key, channel=0, delta=5)
    assert not eng.wire_ok(key)               # detected by redundancy
    rep = eng.repair_wire(key)
    assert rep["repaired"] == 1 and rep["unrecoverable"] == 0
    assert eng.wire_ok(key)                   # located and corrected
    done = eng.run_to_completion()
    assert done[0].result == pow(777, 4321, N)
    assert eng.verify_log[1] is True          # retirement re-verified


def test_crypto_family_gating(cfg, params):
    # no crypto lane -> crypto submissions are refused with guidance
    eng = ContinuousBatcher(cfg, params, n_slots=2, cache_len=CACHE_LEN,
                            prefill_chunk=CHUNK)
    with pytest.raises(ValueError, match="crypto_slots"):
        eng.submit(CryptoRequest(rid=0, op="modexp", a=2, b=3, n=1000003))
    assert "crypto_admit" not in eng.jit_cache_sizes()
    # unknown family tag
    bad = Request(rid=1, prompt=[1, 2], max_new=2, family="audio")
    with pytest.raises(ValueError, match="unknown request family"):
        eng.submit(bad)
    # crypto_ctx without crypto_slots is a configuration error
    with pytest.raises(ValueError, match="crypto_slots"):
        ContinuousBatcher(cfg, params, n_slots=2, cache_len=CACHE_LEN,
                          prefill_chunk=CHUNK, crypto_ctx=_ctx())


def test_duplicate_rid_across_families_rejected(cfg, params):
    eng = _crypto_engine(cfg, params, rns_verify=True)
    eng.submit(Request(rid=7, prompt=[1, 2, 3], max_new=2))
    with pytest.raises(ValueError, match="rid 7"):
        eng.submit(CryptoRequest(rid=7, op="modexp", a=2, b=3, n=1000003))
    eng.submit(CryptoRequest(rid=8, op="modexp", a=2, b=3, n=1000003))
    with pytest.raises(ValueError, match="rid 8"):
        eng.submit(Request(rid=8, prompt=[1], max_new=1))


def test_context_and_lane_validation():
    ctx = _ctx()
    with pytest.raises(ValueError, match="unknown crypto op"):
        ctx.validate(CryptoRequest(rid=0, op="sqrt", a=1, b=1))
    with pytest.raises(ValueError, match="needs a modulus"):
        ctx.validate(CryptoRequest(rid=0, op="modexp", a=1, b=1))
    with pytest.raises(ValueError, match="must lie in"):
        ctx.validate(CryptoRequest(rid=0, op="modexp", a=1, b=1,
                                   n=ctx.n_max + 1))
    with pytest.raises(ValueError, match="coprime"):
        ctx.validate(CryptoRequest(rid=0, op="modexp", a=1, b=1,
                                   n=ctx.baseB.moduli[0] * 3))
    with pytest.raises(ValueError, match="exp_bits"):
        ctx.validate(CryptoRequest(rid=0, op="modexp", a=1,
                                   b=1 << ctx.exp_bits, n=1000003))
    with pytest.raises(ValueError, match="dynamic range"):
        ctx.validate(CryptoRequest(rid=0, op="divmod", a=ctx.baseB.M, b=1))
    with pytest.raises(ValueError, match="divide exp_bits"):
        CryptoLane(1, exp_bits=16, chunk=5)
    with pytest.raises(ValueError, match="BASE_MA or RRNS"):
        CryptoContext(n_limbs=3, layout=Layout.BASE)


# ------------------------------------------- mixed-workload isolation
def _llm_requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda rid, plen, max_new: Request(
        rid=rid, prompt=[int(t) for t in rng.integers(1, cfg.vocab, plen)],
        max_new=max_new,
    )
    return [mk(0, 5, 8), mk(1, 11, 7), mk(2, 3, 9)]


def _kv_row(engine, slot_index, plen, n_out):
    end = plen + n_out - 1
    k = np.asarray(engine.cache["k"])[:, slot_index, :end]
    v = np.asarray(engine.cache["v"])[:, slot_index, :end]
    return k, v


def _staggered_run(cfg, params, crypto_reqs):
    """The PR 5 staggered-overlap harness with crypto traffic interleaved
    at fixed ticks: r0 streams alone, r1 joins mid-decode, then r2, with
    crypto admissions/laddering sharing every step() tick."""
    eng = ContinuousBatcher(
        cfg, params, n_slots=3, cache_len=CACHE_LEN, prefill_chunk=CHUNK,
        crypto_slots=2, crypto_ctx=_ctx(), crypto_chunk=4,
    )
    reqs = _llm_requests(cfg)
    eng.submit(reqs[0])
    if crypto_reqs:
        eng.submit(crypto_reqs[0])       # crypto rides along from tick 0
    eng.try_admit()
    eng.step(), eng.step()
    eng.submit(reqs[1])
    for c in crypto_reqs[1:]:
        eng.submit(c)
    eng.try_admit()
    eng.step()
    eng.submit(reqs[2])
    eng.try_admit()
    assert len(eng.sched.decoding_slots()) == 3
    while eng.busy:
        eng.try_admit()
        eng.step()
    return eng, reqs


def test_mixed_workload_llm_bitwise_identical(cfg, params):
    """Crypto co-residency must be bitwise-invisible to the LLM lane:
    tokens AND the full KV trajectories of every request equal the
    crypto-free run's — and the crypto results equal a crypto-only run's
    (isolation holds in both directions)."""
    crypto_reqs, oracle = _crypto_reqs(_ctx())
    mixed, mreqs = _staggered_run(cfg, params, crypto_reqs)
    plain, preqs = _staggered_run(cfg, params, [])
    m_out = {r.rid: list(r.out) for r in mixed.sched.completed}
    p_out = {r.rid: list(r.out) for r in plain.sched.completed}
    assert m_out == p_out and sorted(m_out) == [0, 1, 2]
    for mr, pr in zip(sorted(mreqs, key=lambda r: r.rid),
                      sorted(preqs, key=lambda r: r.rid)):
        mk, mv = _kv_row(mixed, mr.slot_index, len(mr.prompt), len(mr.out))
        pk, pv = _kv_row(plain, pr.slot_index, len(pr.prompt), len(pr.out))
        np.testing.assert_array_equal(mk, pk)
        np.testing.assert_array_equal(mv, pv)
    # crypto side: same results as a crypto-only engine (and the oracle)
    solo = _crypto_engine(cfg, params, n_slots=3)
    solo_reqs, _ = _crypto_reqs(solo.crypto_ctx)
    for r in solo_reqs:
        solo.submit(r)
    solo_res = {r.rid: r.result for r in solo.run_to_completion()}
    for r in mixed.crypto.completed:
        assert r.result == oracle[r.rid] == solo_res[r.rid]
    # co-residency never retraced either lane's graphs
    sizes = mixed.jit_cache_sizes()
    assert sizes["decode"] == 1 and sizes["crypto_step"] == 1


# ------------------------------------------------------- launcher family
def test_launcher_crypto_trace_roundtrip_and_families(tmp_path):
    from repro.launch.serve import main as serve_main

    trace = str(tmp_path / "mixed.serve-trace.jsonl")
    report = serve_main([
        "--arch", "gemma-2b", "--requests", "1", "--max-new", "2",
        "--slots", "2", "--cache-len", "64", "--arrival-rate", "0",
        "--crypto-slots", "1", "--crypto-requests", "3",
        "--crypto-limbs", "3", "--crypto-exp-bits", "8",
        "--crypto-chunk", "4", "--save-trace", trace,
    ])
    assert report["crypto"]["requests"] == 3
    assert report["crypto"]["oracle_failed"] == 0
    assert report["requests"] == 4
    lines = [json.loads(s) for s in open(trace)]
    fams = [d.get("family", "llm") for d in lines]
    assert fams.count("crypto") == 3 and fams.count("llm") == 1
    # big ints round-trip through hex strings
    assert all(isinstance(d["a"], str) for d in lines
               if d.get("family") == "crypto")
    replay = serve_main([
        "--arch", "gemma-2b", "--trace", trace, "--slots", "2",
        "--cache-len", "64", "--crypto-slots", "1", "--crypto-limbs", "3",
        "--crypto-exp-bits", "8", "--crypto-chunk", "4",
    ])
    assert replay["crypto"]["oracle_failed"] == 0
    assert replay["requests"] == 4
    # --families filters the replay; llm-only needs no crypto lane
    llm_only = serve_main([
        "--arch", "gemma-2b", "--trace", trace, "--slots", "2",
        "--cache-len", "64", "--families", "llm",
    ])
    assert llm_only["requests"] == 1 and "crypto" not in llm_only
    # crypto lines without --crypto-slots are refused with guidance
    with pytest.raises(SystemExit):
        serve_main(["--arch", "gemma-2b", "--trace", trace,
                    "--slots", "2", "--cache-len", "64"])


def test_launcher_rejects_cross_family_duplicate_rids(tmp_path):
    from repro.launch.serve import load_trace

    trace = tmp_path / "dup.jsonl"
    trace.write_text(
        '{"rid": 0, "prompt": [1, 2], "max_new": 2}\n'
        '{"rid": 0, "family": "crypto", "op": "modexp",'
        ' "a": "0x2", "b": 3, "n": 101}\n'
    )
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="duplicate rids"):
        load_trace(str(trace), rng, 100)
    bad = tmp_path / "fam.jsonl"
    bad.write_text('{"rid": 0, "family": "audio", "prompt": [1],'
                   ' "max_new": 1}\n')
    with pytest.raises(ValueError, match="unknown family"):
        load_trace(str(bad), rng, 100)
