"""Flash-attention correctness: forward vs naive reference, and the
hand-written VJP vs autodiff through the reference — causal, GQA, windowed.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention


def naive_attention(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    _, skv, g, _ = k.shape
    r = h // g
    qg = q.reshape(b, sq, g, r, hd).astype(jnp.float32)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k.astype(jnp.float32)) * hd**-0.5
    ipos, jpos = jnp.arange(sq), jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= jpos[None, :] <= ipos[:, None]
    if window is not None:
        mask &= jpos[None, :] > ipos[:, None] - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bgrqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def _qkv(b=2, s=256, h=4, g=2, hd=32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("impl", ["vjp", "scan", "unrolled"])
@pytest.mark.parametrize("window", [None, 64])
def test_forward_matches_naive(impl, window):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=64, kv_chunk=64, impl=impl)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_custom_vjp_matches_autodiff(window, g):
    q, k, v = _qkv(g=g, seed=3)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=True, window=window,
                            q_chunk=64, kv_chunk=64, impl="vjp")
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_ref(q, k, v):
        o = naive_attention(q, k, v, causal=True, window=window)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_noncausal_cross_shape():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, q_chunk=64, kv_chunk=64,
                          impl="vjp")
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
