"""Dist-subsystem tests: activation constraints, spec trees, the RNS
gradient codec round trip, and fingerprint-verified checkpoint restore.

These run with the base dependencies only (no hypothesis), so the dist layer
keeps tier-1 coverage even where optional dev deps are absent.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.dist.act_sharding import constrain, current_mesh, use_mesh
from repro.dist.fault import (
    find_restorable,
    tensor_fingerprint,
    tree_fingerprints,
    verify_fingerprints,
)
from repro.dist.grad_codec import GradCodec, rns_psum
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    named_shardings,
    opt_state_specs,
    param_specs,
)
from repro.models import abstract_params


def _mesh2d():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class _FakeMesh:
    """Spec builders only consume .shape / .axis_names — this lets a 1-CPU
    host exercise the divisibility logic of a (data=4, model=8) mesh."""

    def __init__(self, **shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


# --------------------------------------------------------------- constrain
def test_constrain_noop_off_mesh():
    x = jnp.arange(12.0).reshape(3, 4)
    assert current_mesh() is None
    y = constrain(x, "batch", "ff")
    assert y is x  # literally untouched: no constraint op inserted


def test_use_mesh_installs_and_restores():
    mesh = _mesh2d()
    with use_mesh(mesh) as m:
        assert current_mesh() is mesh and m is mesh
        with use_mesh(None):
            assert current_mesh() is None
        assert current_mesh() is mesh
    assert current_mesh() is None


def test_constrain_on_mesh_preserves_values():
    mesh = _mesh2d()
    x = jnp.arange(16.0).reshape(4, 4)
    with mesh, use_mesh(mesh):
        y = jax.jit(lambda a: constrain(a, "batch", "ff"))(x)
        z = jax.jit(
            lambda a: constrain(a.reshape(2, 2, 2, 2),
                                "?batch_plus", None, "heads", None)
        )(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(z).reshape(4, 4), np.asarray(x))


def test_constrain_rank_mismatch_raises():
    mesh = _mesh2d()
    with use_mesh(mesh):
        with pytest.raises(ValueError):
            constrain(jnp.ones((2, 2)), "batch")


# ------------------------------------------------------------- spec trees
@pytest.mark.parametrize("arch", ["gemma-2b", "qwen2-moe-a2.7b", "zamba2-1.2b"])
def test_param_specs_structure(arch):
    cfg = get_config(arch)
    params_abs = abstract_params(cfg)
    mesh = _FakeMesh(data=4, model=8)
    specs = param_specs(params_abs, mesh, n_experts=cfg.n_experts)
    flat_p = jax.tree_util.tree_flatten_with_path(params_abs)[0]
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (path, spec)
        for ax, entry in enumerate(spec):
            if entry is not None:
                assert entry == "model"
                assert leaf.shape[ax] % mesh.shape["model"] == 0, (path, spec)
    # leading stack (scan) dims never shard
    for (path, leaf), spec in zip(flat_p, flat_s):
        keys = [str(getattr(k, "key", k)) for k in path]
        if keys[0] in ("layers", "groups", "enc_layers", "dec_layers", "tail"):
            assert len(spec) == 0 or spec[0] is None, (path, spec)


def test_param_specs_shard_what_divides():
    """On a mesh whose model axis divides heads/ff/vocab, the big matrices
    actually claim it (not vacuous all-replicated trees)."""
    cfg = get_config("gemma-2b")  # 8 heads, MQA kv=1, ff 16384, vocab 256128
    params_abs = abstract_params(cfg)
    mesh = _FakeMesh(data=4, model=8)
    specs = param_specs(params_abs, mesh, n_experts=cfg.n_experts)
    assert specs["embed"] == P("model", None)
    layer = specs["layers"]
    assert layer["attn"]["wq"] == P(None, None, "model", None)
    assert layer["attn"]["wk"] == P(None, None, None, None)  # kv=1: replicate
    assert layer["attn"]["wo"] == P(None, "model", None, None)
    assert layer["mlp"]["wi"] == P(None, None, None, "model")
    assert layer["mlp"]["wo"] == P(None, "model", None)
    assert layer["ln1"] == P(None, None)  # stacked norm scales: replicated


def test_param_specs_moe_expert_rules():
    cfg = get_config("qwen2-moe-a2.7b")  # 60 experts: indivisible by 8
    params_abs = abstract_params(cfg)
    specs = param_specs(
        params_abs, _FakeMesh(data=4, model=8), n_experts=cfg.n_experts
    )
    moe = specs["layers"]["moe"]
    # 60 experts don't divide model=8 -> the expert-ff dim shards instead,
    # and the leading (layers, experts) stack dims stay unsharded
    assert moe["wi"] == P(None, None, None, None, "model")
    assert moe["wo"] == P(None, None, "model", None)
    assert moe["shared_wi"] == P(None, None, None, "model")
    assert moe["shared_wo"] == P(None, "model", None)


def test_opt_state_and_batch_specs():
    cfg = get_config("gemma-2b")
    params_abs = abstract_params(cfg)
    mesh = _FakeMesh(data=4, model=8)
    pspecs = param_specs(params_abs, mesh, n_experts=cfg.n_experts)
    z = opt_state_specs(params_abs, pspecs, mesh, zero1=True)
    # ZeRO-1 adds 'data' to exactly one previously-unsharded divisible axis
    # (the 18-layer stack dim doesn't divide data=4, so d_model takes it)
    assert z["embed"] == P("model", "data")
    assert z["layers"]["mlp"]["wo"] == P(None, "model", "data")
    assert z["layers"]["ln1"] == P(None, "data")
    noz = opt_state_specs(params_abs, pspecs, mesh, zero1=False)
    assert jax.tree_util.tree_all(
        jax.tree_util.tree_map(
            lambda a, b: a == b, noz, pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
    )
    b = batch_specs(
        {"tokens": jax.ShapeDtypeStruct((8, 33), jnp.int32)}, mesh
    )
    assert b["tokens"] == P("data", None)
    # indivisible batch: replicate rather than produce an invalid spec
    b1 = batch_specs({"tokens": jax.ShapeDtypeStruct((2, 33), jnp.int32)}, mesh)
    assert b1["tokens"] == P(None, None)
    assert batch_specs(jax.ShapeDtypeStruct((), jnp.int32), mesh) == P()


def test_cache_specs_shapes():
    mesh = _FakeMesh(data=2, model=2)
    cache_abs = {
        "k": jax.ShapeDtypeStruct((4, 2, 64, 2, 32), jnp.float32),
        "v": jax.ShapeDtypeStruct((4, 2, 64, 2, 32), jnp.float32),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
        "ssm": {"S": jax.ShapeDtypeStruct((4, 2, 8, 16, 16), jnp.float32)},
    }
    specs = cache_specs(cache_abs, mesh)
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["len"] == P()
    assert specs["ssm"]["S"] == P(None, "data", None, None, None)
    # real-mesh path: NamedShardings materialize for every P leaf
    real = _mesh2d()
    sh = named_shardings(cache_specs(cache_abs, real), real)
    assert all(
        isinstance(s, NamedSharding) for s in jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    )


# -------------------------------------------------------------- grad codec
def test_codec_roundtrip_and_ring_homomorphism():
    codec = GradCodec.make(world=32)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((16, 9)).astype(np.float32))
    packed = codec.encode(g)
    assert packed.shape == g.shape + (codec.base.n + 1,)
    dec = codec.decode(codec.fold(packed))
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(g), atol=2.0 ** -codec.frac_bits
    )
    # channel-sum of encodings == encoding of the quantized sum
    W = 32
    gs = rng.standard_normal((W, 64)).astype(np.float32)
    summed = jnp.asarray(
        np.sum([np.asarray(codec.encode(jnp.asarray(x))) for x in gs], axis=0)
    )
    dec = codec.decode(codec.fold(summed))
    q = np.clip(np.round(gs.astype(np.float64) * (1 << codec.frac_bits)),
                -codec.qmax, codec.qmax)
    want = q.sum(0) / (1 << codec.frac_bits)
    np.testing.assert_allclose(np.asarray(dec), want, atol=1e-7)
    folded = codec.fold(summed)
    assert bool(np.all(codec.verify_packed(folded)))
    # Alg.-1 sign query on the SUM: normalize re-anchors the m_a channel
    np.testing.assert_array_equal(
        np.asarray(codec.is_negative(codec.normalize(folded))), q.sum(0) < 0
    )
    # transit corruption of the redundant channel is detected
    bad = np.asarray(folded).copy()
    bad[0, -1] = (bad[0, -1] + 1) % codec.base.ma
    assert not bool(codec.verify_packed(jnp.asarray(bad))[0])


def test_codec_sign_and_magnitude_queries():
    codec = GradCodec.make(world=8)
    vals = np.asarray([-77.25, -1e-4, 0.0, 0.5, 123.0], np.float32)
    folded = codec.fold(codec.encode(jnp.asarray(vals)))
    q = np.clip(np.round(vals.astype(np.float64) * (1 << codec.frac_bits)),
                -codec.qmax, codec.qmax).astype(np.int64)
    np.testing.assert_array_equal(
        np.asarray(codec.is_negative(folded)), q < 0
    )
    for thr in (1, 33, 1 << 20, codec.qmax):
        np.testing.assert_array_equal(
            np.asarray(codec.abs_ge(folded, thr)), np.abs(q) >= thr
        )


def test_rns_psum_matches_float_psum():
    from jax.experimental.shard_map import shard_map

    codec = GradCodec.make(world=4)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    g = jnp.asarray(
        np.random.default_rng(7).standard_normal(48), jnp.float32
    )
    rns = shard_map(lambda x: rns_psum(codec, x, "data"), mesh,
                    in_specs=P(), out_specs=P(), check_rep=False)
    fp = shard_map(
        lambda x: jax.lax.psum(x, "data") / jax.lax.psum(
            jnp.ones((), jnp.float32), "data"),
        mesh, in_specs=P(), out_specs=P(), check_rep=False,
    )
    np.testing.assert_allclose(
        np.asarray(rns(g)), np.asarray(fp(g)), atol=2.0 ** -codec.frac_bits
    )


def test_codec_world_sizing():
    with pytest.raises(ValueError):
        GradCodec.make(world=0)
    small = GradCodec.make(world=2)
    big = GradCodec.make(world=1 << 20)
    assert small.qmax > big.qmax > 0
    assert 2 * small.world * small.qmax < small.base.M


# ------------------------------------------------------------ fingerprints
def test_fingerprint_flip_and_tree_api():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((32, 8)).astype(np.float32)
    fps = tree_fingerprints({"w": a, "nested": {"b": a[:4]}})
    assert set(fps) == {"w", "nested/b"}
    b = a.copy()
    b[3, 3] += 1e-7
    assert tensor_fingerprint(b) != fps["w"]
    assert verify_fingerprints({"w": b, "nested": {"b": a[:4]}}, fps) == ["w"]
    # dtype matters, not just bytes-compatible content
    assert tensor_fingerprint(np.zeros(4, np.int32)) != tensor_fingerprint(
        np.zeros(4, np.float32)
    )


def test_checkpoint_fingerprint_save_verify_restore(tmp_path):
    from repro.train import checkpoint as ckpt

    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 2, tree)
    ckpt.save(d, 6, jax.tree_util.tree_map(lambda x: x + 1, tree))
    assert os.path.basename(find_restorable(d)) == "step_6"
    abs_tree = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    got, step, _ = ckpt.restore(d, abs_tree)
    assert step == 6
    np.testing.assert_array_equal(
        np.asarray(got["w"]), np.asarray(tree["w"]) + 1
    )
    # corrupt the newest step -> discovery falls back to the older valid one
    path = os.path.join(d, "step_6", "0.npy")
    arr = np.load(path)
    arr.ravel()[0] += 1
    np.save(path, arr)
    assert os.path.basename(find_restorable(d)) == "step_2"
    with pytest.raises(IOError):
        ckpt.restore(d, abs_tree, step=6)
    got, step, _ = ckpt.restore(d, abs_tree)
    assert step == 2
    # torn save (dir without manifest) is skipped silently
    os.makedirs(os.path.join(d, "step_9"))
    assert os.path.basename(find_restorable(d)) == "step_2"
    assert find_restorable(str(tmp_path / "missing")) is None


def test_checkpoint_fingerprints_align_with_adversarial_key_order(tmp_path):
    """Joined names ('a/b') can sort differently than the nested flatten
    order ('-' < '/'); manifest fingerprints must still align with names."""
    from repro.train import checkpoint as ckpt

    tree = {
        "a": {"b": jnp.arange(4, dtype=jnp.float32)},
        "a-x": jnp.ones((3,), jnp.int32),
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    assert os.path.basename(find_restorable(d)) == "step_1"
    abs_tree = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    got, step, _ = ckpt.restore(d, abs_tree)
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(got["a"]["b"]), np.asarray(tree["a"]["b"])
    )
