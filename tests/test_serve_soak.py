"""Randomized differential soak: paged+bucketed vs monolithic chunk loop.

Each seeded round generates a mixed LLM+crypto workload whose prompt
lengths straddle the bucket AND page boundaries (7/8/9, 15/16/17,
23/24), shares a one-page system prefix across a random subset, gives a
third of the requests a mid-stream EOS (timed from a probe run so it
fires at a real sampled token), and replays the identical trace through
two engines:

* the paged, prefix-sharing pool with bucketed single-call prefill
  (padded write barrier) and per-page RNS fingerprints, over a pool
  small enough that admissions defer and retained pages get evicted;
* the monolithic chunk-loop engine with whole-row fingerprints.

Tokens and every request's logical KV rows (snapshotted at retirement)
must match bitwise, crypto results must match exactly AND the python
oracle, and both engines' fingerprint verifies must come back clean.
One small seed runs in tier-1; the bigger seeds are ``-m slow`` (the CI
soak job).
"""
import numpy as np
import pytest

import repro  # noqa: F401
from conftest import CACHE_LEN, N_PG, make_engine, run_with_row_snapshots
from repro.serve.crypto import CryptoContext, CryptoRequest
from repro.serve.scheduler import Request

BUCKETS = (8, 16, 32)
EDGE_PLENS = (7, 8, 9, 15, 16, 17, 23, 24)  # page/bucket boundary ± 1


def _workload(cfg, seed, n):
    """n LLM request specs: boundary-straddling lengths, a shared
    one-page prefix on a random subset, bounded decode budgets."""
    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, cfg.vocab, 8)]
    specs = []
    for i in range(n):
        plen = (int(rng.choice(EDGE_PLENS)) if i % 2 == 0
                else int(rng.integers(3, 25)))
        if plen > 8 and rng.random() < 0.4:
            body = [int(t) for t in rng.integers(1, cfg.vocab, plen - 8)]
            prompt = prefix + body
        else:
            prompt = [int(t) for t in rng.integers(1, cfg.vocab, plen)]
        max_new = int(rng.integers(2, min(7, CACHE_LEN - plen + 1)))
        specs.append({"rid": i, "prompt": prompt, "max_new": max_new,
                      "eos": -1})
    return specs


def _time_eos(cfg, params, specs, seed):
    """Probe run (monolithic, no verify) to pick REAL mid-stream tokens
    as EOS for ~1/3 of the requests — early retirement then lands at a
    token both engines genuinely sample, at staggered depths."""
    probe = make_engine(cfg, params)
    for s in specs:
        probe.submit(Request(rid=s["rid"], prompt=list(s["prompt"]),
                             max_new=s["max_new"], eos=-1))
    out = {r.rid: r.out for r in probe.run_to_completion()}
    rng = np.random.default_rng(seed + 999)
    for s in specs:
        toks = out[s["rid"]]
        if len(toks) > 2 and rng.random() < 0.35:
            s["eos"] = int(toks[int(rng.integers(1, len(toks) - 1))])


def _crypto_reqs():
    return [
        CryptoRequest(rid=100, op="modexp", a=12345, b=777, n=99991),
        CryptoRequest(rid=101, op="modmul", a=4321, b=8765, n=99991),
        CryptoRequest(rid=102, op="modexp", a=999, b=1025, n=65537),
    ]


def _run_differential(cfg, params, seed, n):
    specs = _workload(cfg, seed, n)
    _time_eos(cfg, params, specs, seed)
    ctx = CryptoContext(n_limbs=8, exp_bits=16)

    def mk_reqs():
        llm = [Request(rid=s["rid"], prompt=list(s["prompt"]),
                       max_new=s["max_new"], eos=s["eos"]) for s in specs]
        return llm + _crypto_reqs()

    eng_b = make_engine(cfg, params, paged=True, n_pages=N_PG + 4,
                        prefill_buckets=BUCKETS, rns_verify=True,
                        crypto_slots=2, crypto_ctx=ctx)
    done_b, rows_b = run_with_row_snapshots(eng_b, mk_reqs())
    eng_c = make_engine(cfg, params, rns_verify=True, crypto_slots=2,
                        crypto_ctx=ctx)
    done_c, rows_c = run_with_row_snapshots(eng_c, mk_reqs())

    assert sorted(done_b) == sorted(done_c)
    llm_rids = [s["rid"] for s in specs]
    for rid in llm_rids:
        assert done_b[rid].out == done_c[rid].out, f"rid {rid} tokens"
        (bk, bv), (ck, cv) = rows_b[rid], rows_c[rid]
        np.testing.assert_array_equal(bk, ck, err_msg=f"rid {rid} K")
        np.testing.assert_array_equal(bv, cv, err_msg=f"rid {rid} V")
    # crypto lane: engines agree with each other AND the python oracle
    for cr in _crypto_reqs():
        want = (pow(cr.a, cr.b, cr.n) if cr.op == "modexp"
                else (cr.a * cr.b) % cr.n)
        assert done_b[cr.rid].result == want
        assert done_c[cr.rid].result == want
    # every retirement's fingerprints verified clean on both engines
    # (verify_log also carries the crypto lane's RNS range checks)
    assert set(llm_rids) <= set(eng_b.verify_log)
    assert all(eng_b.verify_log.values())
    assert all(eng_c.verify_log.values())
    # the paged side actually exercised its machinery this round
    st = eng_b.bucket_stats()
    assert sum(st["hits"].values()) > 0 and st["fallbacks"] == 0
    pg = eng_b.page_stats()
    assert pg["fingerprints"]["failed"] == 0
    assert pg["pages_in_use"] == 0  # nothing leaked, scratch included
    return pg


def test_soak_differential_small_seed(cfg, params):
    """Tier-1 slice: one seeded round, sized to stay cheap."""
    _run_differential(cfg, params, seed=0, n=6)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_soak_differential_seeded(cfg, params, seed):
    """CI soak job rounds (``-m slow``): bigger traces, more slot churn,
    pool pressure with deferrals/evictions in the mix."""
    pg = _run_differential(cfg, params, seed=seed, n=12)
    # 12 requests over an 8-usable-page pool: pressure must have shown up
    assert pg["deferrals"] + pg["pages_evicted"] + pg["dedup_hits"] > 0
