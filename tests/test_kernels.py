"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Integer kernels must match the oracle EXACTLY (assert_array_equal — stricter
than allclose).  Sweeps shapes (including non-multiples of the block size),
channel counts, moduli bit-widths, and input dtypes.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import make_base
from repro.kernels import (
    compare_op,
    modmul_op,
    mrc_op,
    ref_compare,
    ref_modmul,
    ref_mrc,
)

NS = [2, 3, 6, 17]
BATCHES = [1, 7, 128, 300]
BITS = [8, 13, 15]


def _rand_residues(base, shape, rng):
    m = np.asarray(base.moduli_np)
    return rng.integers(0, m, size=shape + (base.n,)).astype(np.int32)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("batch", BATCHES)
def test_mrc_kernel_matches_oracle(n, batch):
    base = make_base(n, bits=15)
    rng = np.random.default_rng(n * 1000 + batch)
    x = jnp.asarray(_rand_residues(base, (batch,), rng))
    got = mrc_op(base, x, block_b=128, interpret=True)
    want = ref_mrc(base, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", BITS)
def test_mrc_kernel_bit_widths(bits):
    base = make_base(5, bits=bits)
    rng = np.random.default_rng(bits)
    x = jnp.asarray(_rand_residues(base, (64,), rng))
    got = mrc_op(base, x, block_b=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_mrc(base, x)))


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_mrc_kernel_dtypes(dtype):
    base = make_base(4, bits=15)
    rng = np.random.default_rng(0)
    x = jnp.asarray(_rand_residues(base, (32,), rng).astype(dtype))
    got = mrc_op(base, x, block_b=32, interpret=True)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_mrc(base, x)))


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("batch", BATCHES)
def test_modmul_kernel_matches_oracle(n, batch):
    base = make_base(n, bits=15)
    rng = np.random.default_rng(n + batch)
    x = jnp.asarray(_rand_residues(base, (batch,), rng))
    y = jnp.asarray(_rand_residues(base, (batch,), rng))
    got = modmul_op(base, x, y, block_b=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_modmul(base, x, y)))


def test_modmul_kernel_worst_case_products():
    """Largest residues: exercises the Barrett correction branches."""
    base = make_base(8, bits=15)
    m = np.asarray(base.moduli_np)
    x = jnp.asarray(np.broadcast_to(m - 1, (256, base.n)).astype(np.int32))
    got = modmul_op(base, x, x, block_b=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref_modmul(base, x, x)))


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("batch", BATCHES)
def test_compare_kernel_matches_oracle(n, batch):
    base = make_base(n, bits=15)
    rng = np.random.default_rng(7 * n + batch)
    x1 = jnp.asarray(_rand_residues(base, (batch,), rng))
    x2 = jnp.asarray(_rand_residues(base, (batch,), rng))
    # NOTE: random residue vectors are valid numbers in [0, M) by CRT, and
    # their m_a channels must be consistent — derive them exactly.
    from repro.core import rns_to_int

    a1 = jnp.asarray(
        np.asarray([rns_to_int(base, r) % base.ma for r in np.asarray(x1)], np.int32)
    )
    a2 = jnp.asarray(
        np.asarray([rns_to_int(base, r) % base.ma for r in np.asarray(x2)], np.int32)
    )
    got = compare_op(base, x1, a1, x2, a2, block_b=128, interpret=True)
    want = ref_compare(base, x1, a1, x2, a2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_compare_kernel_is_true_comparison(data):
    """End-to-end property: kernel verdict == integer >= (Theorem 1)."""
    base = make_base(4, bits=15)
    N1 = data.draw(st.integers(0, base.M - 1))
    N2 = data.draw(st.integers(0, base.M - 1))
    x1 = jnp.asarray(base.residues_of(N1)[None])
    x2 = jnp.asarray(base.residues_of(N2)[None])
    a1 = jnp.asarray([N1 % base.ma], dtype=jnp.int32)
    a2 = jnp.asarray([N2 % base.ma], dtype=jnp.int32)
    got = bool(compare_op(base, x1, a1, x2, a2, block_b=8, interpret=True)[0])
    assert got == (N1 >= N2)


def test_kernels_reject_wide_bases():
    base = make_base(3, bits=31)
    x = jnp.zeros((4, 3), dtype=jnp.int64)
    with pytest.raises(ValueError):
        mrc_op(base, x, interpret=True)


def test_codec_decode_kernel_matches_oracle():
    """Fused fold->MRC->Horner->sign->scale kernel vs the jnp codec path."""
    from repro.dist.grad_codec import GradCodec
    from repro.kernels import codec_decode_op

    codec = GradCodec.make(world=512)
    rng = np.random.default_rng(11)
    W = 64
    g = rng.standard_normal((W, 300)).astype(np.float32)
    packs = np.stack([np.asarray(codec.encode(jnp.asarray(r))) for r in g])
    summed = jnp.asarray(packs.sum(axis=0))          # what psum produces
    want = np.asarray(codec.decode(codec.fold(summed)))
    got = np.asarray(codec_decode_op(codec, summed, block_b=128,
                                     interpret=True))
    # the compensated limb sum makes the fused decode correctly rounded —
    # bitwise equal to the jnp f64 path, not merely close
    np.testing.assert_array_equal(got, want)


def test_codec_decode_kernel_extreme_values():
    from repro.dist.grad_codec import GradCodec
    from repro.kernels import codec_decode_op

    codec = GradCodec.make(world=512)
    # +-qmax summed over 512 replicas: the dynamic-range corners
    q = np.asarray([codec.qmax, -codec.qmax, 0, 1, -1], np.int64) * 512
    # encode clips per replica; emulate the summed corners directly:
    from repro.core.convert import tensor_to_rns
    res = tensor_to_rns(codec.base, jnp.asarray(q))
    xa = jnp.mod(jnp.asarray(q), codec.base.ma)
    xa = jnp.where(jnp.asarray(q) < 0,
                   jnp.mod(xa + codec.base.M_mod_ma, codec.base.ma), xa)
    summed = jnp.concatenate([res.astype(jnp.int32),
                              xa[..., None].astype(jnp.int32)], axis=-1)
    want = np.asarray(codec.decode(codec.fold(summed)))
    got = np.asarray(codec_decode_op(codec, summed, block_b=8, interpret=True))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_codec_encode_kernel_property(data):
    """Property: fused encode == jnp f64 encode BITWISE for arbitrary f32
    inputs (quantize, clip at qmax, signed embedding, redundant channel)."""
    from repro.dist.grad_codec import GradCodec
    from repro.kernels import codec_encode_op

    codec = GradCodec.make(world=data.draw(st.sampled_from([2, 32, 512])))
    vals = data.draw(st.lists(
        st.floats(-1e30, 1e30, width=32), min_size=1, max_size=64,
    ))
    g = jnp.asarray(np.asarray(vals, np.float32))
    np.testing.assert_array_equal(
        np.asarray(codec_encode_op(codec, g, block_b=32, interpret=True)),
        np.asarray(codec.encode(g)),
    )
