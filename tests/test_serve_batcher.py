"""Continuous-batching engine invariants (DESIGN.md §12).

The tier-1 contract of the serve subsystem:

* slot ISOLATION — a request's tokens (and its whole KV row) are
  bitwise-identical whether it streams alone or packed against staggered
  co-resident traffic, including requests admitted mid-decode;
* slot REUSE — retirement returns rows to the pool and later admissions
  recycle them;
* NO RETRACE — the engine's jitted graphs each compile exactly once no
  matter how occupancy churns (asserted via jit cache stats);
* RNS integrity — prompt-region fingerprints verify at retirement, and an
  injected wire-buffer corruption is detected and repaired in place
  through ``dist.fault.repair_packed``.
"""
import dataclasses

import numpy as np
import pytest

import jax

import repro  # noqa: F401
from conftest import CACHE_LEN, CHUNK, kv_row as _row, make_engine
from repro.configs import get_config
from repro.models import init_params
from repro.serve.batcher import ContinuousBatcher
from repro.serve.scheduler import Request, SlotScheduler


def _requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda rid, plen, max_new: Request(
        rid=rid, prompt=[int(t) for t in rng.integers(1, cfg.vocab, plen)],
        max_new=max_new,
    )
    # prompt lengths straddle the prefill chunk (3 < 8 < 11) so admission
    # exercises both the single-chunk and the multi-chunk path
    return [mk(0, 5, 8), mk(1, 11, 7), mk(2, 3, 9)]


_engine = make_engine  # shared factory (tests/conftest.py)


def _run_mixed(cfg, params):
    """Staggered admissions: r0 streams alone, r1 joins mid-decode, then
    r2 — with all three overlapping before any retirement."""
    eng = _engine(cfg, params)
    reqs = _requests(cfg)
    eng.submit(reqs[0])
    eng.try_admit()
    eng.step(), eng.step()
    eng.submit(reqs[1])
    eng.try_admit()
    eng.step()
    eng.submit(reqs[2])
    eng.try_admit()
    assert len(eng.sched.decoding_slots()) == 3  # genuine 3-way overlap
    while eng.sched.busy:
        eng.try_admit()
        eng.step()
    return eng, reqs


def test_mid_stream_admission_bitwise_vs_solo(cfg, params):
    eng, reqs = _run_mixed(cfg, params)
    mixed = {r.rid: list(r.out) for r in eng.sched.completed}
    assert sorted(mixed) == [0, 1, 2]
    for r in reqs:
        solo = _engine(cfg, params)
        solo_req = Request(rid=r.rid, prompt=list(r.prompt),
                           max_new=r.max_new)
        done = solo.run_to_completion()
        assert [q.rid for q in done] == []  # nothing submitted yet
        solo.submit(solo_req)
        done = solo.run_to_completion()
        assert done[0].out == mixed[r.rid]
        # the whole KV trajectory matches bitwise, not just the argmaxes
        mk, mv = _row(eng, r.slot_index, len(r.prompt), len(r.out))
        sk, sv = _row(solo, solo_req.slot_index, len(r.prompt),
                      len(solo_req.out))
        np.testing.assert_array_equal(mk, sk)
        np.testing.assert_array_equal(mv, sv)


def test_prefill_chunk_size_is_bitwise_invisible(cfg, params):
    outs = []
    for chunk in (4, 16):
        eng = _engine(cfg, params, prefill_chunk=chunk)
        for r in _requests(cfg):
            eng.submit(r)
        done = eng.run_to_completion()
        outs.append({r.rid: r.out for r in done})
    assert outs[0] == outs[1]


def test_slot_reuse_after_retirement(cfg, params):
    eng = _engine(cfg, params, n_slots=2)
    rng = np.random.default_rng(3)
    for i in range(5):
        eng.submit(Request(
            rid=i, prompt=[int(t) for t in rng.integers(1, cfg.vocab, 4)],
            max_new=3 + i % 3,
        ))
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == list(range(5))
    assert all(len(r.out) == r.max_new for r in done)
    by_slot = {}
    for r in done:
        by_slot.setdefault(r.slot_index, []).append(r.rid)
    assert set(by_slot) <= {0, 1}               # never more rows than slots
    assert max(len(v) for v in by_slot.values()) >= 2  # rows were recycled


def test_no_retrace_across_churn(cfg, params):
    eng, _ = _run_mixed(cfg, params)
    sizes = eng.jit_cache_sizes()
    assert sizes == {"decode": 1, "extend": 1, "insert": 1}, sizes


def test_eos_retires_early(cfg, params):
    eng = _engine(cfg, params)
    probe = Request(rid=0, prompt=[1, 2, 3], max_new=6)
    eng.submit(probe)
    first = eng.run_to_completion()[0].out[0]
    eng2 = _engine(cfg, params)
    eng2.submit(Request(rid=1, prompt=[1, 2, 3], max_new=6, eos=first))
    done = eng2.run_to_completion()
    assert done[0].out == [first]  # instant EOS: one token, slot freed


def test_rns_verify_and_injected_corruption_repair(cfg, params):
    eng = _engine(cfg, params, n_slots=2, rns_verify=True)
    for r in _requests(cfg):
        eng.submit(r)
    # one-token budget: retires inside admission, must still be verified
    eng.submit(Request(rid=9, prompt=[1, 2, 3], max_new=1))
    done = eng.run_to_completion()
    # every retirement verified its prompt-region fingerprint bitwise
    assert eng.verify_log == {r.rid: True for r in done}
    assert 9 in eng.verify_log
    assert all(eng.wire_ok(r.rid) for r in done)
    # inject a single-channel wire corruption: detected, located,
    # corrected in place, and the repaired buffer re-verifies against the
    # (recomputable) fingerprint encoding
    rid = done[0].rid
    stored = eng._wire[rid].residues.copy()
    eng.corrupt_wire(rid, channel=1, delta=3)
    assert not eng.wire_ok(rid)
    report = eng.repair_wire(rid)
    assert report == {"repaired": 1, "unrecoverable": 0}
    assert eng.wire_ok(rid)
    np.testing.assert_array_equal(np.asarray(eng._wire[rid].residues),
                                  np.asarray(stored))
    assert eng.jit_cache_sizes()["fingerprint"] == 1


def test_fingerprint_stays_valid_after_retirement(cfg, params):
    """A retired slot's fingerprint must keep verifying while other
    slots decode on (idle junk writes park OUTSIDE the row span), until
    the row is actually reused."""
    eng = _engine(cfg, params, n_slots=2, rns_verify=True)
    short = Request(rid=0, prompt=[1, 2, 3], max_new=2)
    long = Request(rid=1, prompt=[4, 5, 6], max_new=8)
    eng.submit(short), eng.submit(long)
    eng.try_admit()
    while short.t_done is None:
        eng.step()
    for _ in range(3):  # rid 0's row sits FREE while rid 1 decodes
        eng.step()
    assert eng.verify_request(short)


def test_drain_completed_releases_state(cfg, params):
    eng = _engine(cfg, params, n_slots=2, rns_verify=True)
    for r in _requests(cfg):
        eng.submit(r)
    eng.run_to_completion()
    done = eng.drain_completed()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert eng.sched.completed == [] and eng._wire == {}
    assert eng.verify_log == {}


def test_chunk_must_divide_cache_len(cfg, params):
    with pytest.raises(ValueError, match="must divide"):
        _engine(cfg, params, cache_len=30, prefill_chunk=8)


def test_duplicate_rid_rejected_under_rns_verify(cfg, params):
    """Verify state is keyed on rid; a collision must fail loudly at
    submit — before any slot is bound — instead of silently cross-wiring
    fingerprints or wedging an admitted slot."""
    eng = _engine(cfg, params, n_slots=2, rns_verify=True)
    eng.submit(Request(rid=7, prompt=[1, 2, 3], max_new=4))
    with pytest.raises(ValueError, match="already holds verify state"):
        eng.submit(Request(rid=7, prompt=[4, 5, 6], max_new=4))
    done = eng.run_to_completion()  # the engine is NOT wedged
    assert [r.rid for r in done] == [7]
    # after draining, the rid is reusable
    eng.drain_completed()
    eng.submit(Request(rid=7, prompt=[1, 2], max_new=2))
    assert len(eng.run_to_completion()) == 1


def test_unsupported_families_are_gated(params):
    ssm = get_config("mamba2-370m").smoke()
    with pytest.raises(NotImplementedError, match="linear-KV"):
        ContinuousBatcher(ssm, {}, n_slots=1, cache_len=16)
    dense = get_config("gemma-2b").smoke()
    quant = dataclasses.replace(dense, kv_quant=True)
    with pytest.raises(NotImplementedError, match="int8"):
        ContinuousBatcher(quant, {}, n_slots=1, cache_len=16)


def test_oversized_request_fails_at_submit(cfg, params):
    sch = SlotScheduler(n_slots=1, cache_len=8)
    with pytest.raises(ValueError, match="exceeds"):
        sch.submit(Request(rid=0, prompt=[1] * 6, max_new=4))


def test_windowed_arch_lowers_to_masked_cache(params):
    """gemma3's grouped ring cache lowers to the linear masked layout so
    slots stay spliceable; the engine still streams correctly."""
    cfg3 = get_config("gemma3-1b").smoke()
    assert cfg3.window and cfg3.window_cache
    p3 = init_params(cfg3, jax.random.key(2))
    eng = ContinuousBatcher(cfg3, p3, n_slots=2, cache_len=CACHE_LEN,
                            prefill_chunk=CHUNK)
    assert not eng.cfg.window_cache
    eng.submit(Request(rid=0, prompt=[4, 5, 6, 7], max_new=4))
    done = eng.run_to_completion()
    assert len(done[0].out) == 4


def test_sharded_cache_placement(cfg, params):
    """mesh= places the batched cache on cache_specs' layout (slots =
    the batch axis over 'data'; trivially replicated on one device)."""
    mesh = jax.make_mesh((1,), ("data",))
    eng = _engine(cfg, params, mesh=mesh)
    spec = eng.cache_pspecs["k"]
    assert len(spec) == 5  # (L, slots, S, g, hd) rule applied
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=3))
    assert len(eng.run_to_completion()[0].out) == 3
